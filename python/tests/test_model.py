"""L2 model-graph correctness: segment composition, VJP fidelity, shapes."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def init_flat(spec, seed=0, scale=0.1):
    key = jax.random.PRNGKey(seed)
    flat = []
    for _, _, _, shape in spec.all_param_specs():
        key, sub = jax.random.split(key)
        flat.append(jax.random.normal(sub, shape) * scale)
    return flat


@pytest.fixture(scope="module")
def rn():
    return M.build_rn18slim()


@pytest.fixture(scope="module")
def vit():
    return M.build_vitslim()


# ---------------------------------------------------------------------------
# Topology fidelity (paper checkpoint grids need these counts)
# ---------------------------------------------------------------------------


def test_rn_topology(rn):
    assert rn.num_segments == 10  # stem + 8 blocks + head
    kinds = [s.kind for s in rn.segments]
    assert kinds == ["stem"] + ["block"] * 8 + ["head"]
    # 16 block convolutions, as in the paper's checkpoint description
    convs = sum(
        1 for s in rn.segments for n, _ in s.param_specs if n in ("w1", "w2")
    )
    assert convs == 16


def test_vit_topology(vit):
    assert vit.num_segments == 14  # embed + 12 encoders + head
    assert sum(1 for s in vit.segments if s.kind == "encoder") == 12


def test_depth_indexing(rn):
    # l=1 is the head (back-end), l=L the stem (front-end) — paper §III-A.
    assert rn.depth_l(rn.num_segments - 1) == 1
    assert rn.depth_l(0) == rn.num_segments


@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_segment_shapes_chain(name):
    spec = M.MODELS[name]()
    for a, b in zip(spec.segments[:-1], spec.segments[1:]):
        assert a.out_shape == b.in_shape, f"{a.name} -> {b.name}"
    assert spec.segments[-1].out_shape == (spec.num_classes,)


# ---------------------------------------------------------------------------
# Composition: chained segment fwd == full logits fn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_segment_chain_equals_full_forward(name):
    spec = M.MODELS[name]()
    flat = init_flat(spec, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (4,) + spec.input_shape)
    counts = [len(s.param_specs) for s in spec.segments]
    h, off = x, 0
    for seg, c in zip(spec.segments, counts):
        h = seg.apply(flat[off : off + c], h)
        off += c
    full = spec.logits_fn()(*flat, x)[0]
    np.testing.assert_allclose(h, full, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Per-segment VJP == autodiff of the composed model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_streamed_backprop_matches_full_grad(name):
    """The Rust coordinator backprops segment-by-segment (bwd modules chained
    back-end-first). That stream must equal jax.grad of the whole model."""
    spec = M.MODELS[name]()
    flat = init_flat(spec, seed=3)
    counts = [len(s.param_specs) for s in spec.segments]
    bsz = 2
    x = jax.random.normal(jax.random.PRNGKey(4), (bsz,) + spec.input_shape)
    onehot = jax.nn.one_hot(jnp.arange(bsz) % spec.num_classes, spec.num_classes)

    # reference: grad of the composed loss
    def loss_fn(fl):
        return M.cross_entropy(spec.logits_fn()(*fl, x)[0], onehot)

    ref_grads = jax.grad(loss_fn)(flat)

    # streamed: cache activations fwd, then chain per-segment bwd
    acts, h, off = [], x, 0
    for seg, c in zip(spec.segments, counts):
        acts.append(h)
        h = seg.apply(flat[off : off + c], h)
        off += c
    gy = M.make_loss_grad_fn()(h, onehot)[0]
    offs = np.cumsum([0] + counts)
    got = [None] * len(flat)
    for k in reversed(range(len(spec.segments))):
        seg = spec.segments[k]
        bwd = M.make_segment_bwd_fn(seg)
        outs = bwd(*flat[offs[k] : offs[k + 1]], acts[k], gy)
        for i, gp in enumerate(outs[:-1]):
            got[offs[k] + i] = gp
        gy = outs[-1]
    for g_ref, g_got in zip(ref_grads, got):
        np.testing.assert_allclose(g_got, g_ref, rtol=5e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Train step sanity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_train_step_reduces_loss(name):
    spec = M.MODELS[name]()
    flat = init_flat(spec, seed=5)
    ts = M.make_train_step_fn(spec)
    bsz = 8
    x = jax.random.normal(jax.random.PRNGKey(6), (bsz,) + spec.input_shape)
    onehot = jax.nn.one_hot(jnp.arange(bsz) % spec.num_classes, spec.num_classes)
    losses = []
    for _ in range(5):
        out = ts(*flat, x, onehot, jnp.float32(0.2))
        flat = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_loss_grad_rowsums_zero():
    fn = M.make_loss_grad_fn()
    logits = jax.random.normal(jax.random.PRNGKey(7), (8, 20))
    onehot = jax.nn.one_hot(jnp.arange(8) % 20, 20)
    (g,) = fn(logits, onehot)
    np.testing.assert_allclose(g.sum(axis=-1), np.zeros(8), atol=1e-6)
    assert g.shape == (8, 20)


def test_group_norm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, 8, 8)) * 3 + 1
    y = M.group_norm(x, jnp.ones(8), jnp.zeros(8))
    yg = np.asarray(y).reshape(2, 8, 8, M.GN_GROUPS, 8 // M.GN_GROUPS)
    mu = yg.mean(axis=(1, 2, 4))
    assert np.abs(mu).max() < 1e-4


def test_cross_entropy_uniform():
    logits = jnp.zeros((4, 20))
    onehot = jax.nn.one_hot(jnp.arange(4), 20)
    assert abs(float(M.cross_entropy(logits, onehot)) - math.log(20.0)) < 1e-5
