"""L1 kernel correctness: Pallas vs pure-jnp oracle across a shape sweep.

The environment has no `hypothesis` package, so the sweep is an explicit
seeded parameter grid (same spirit: many shapes/dtypes, deterministic
reproduction via the printed seed/params on failure).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import conv, dampen, fimd, gemm, ref

SEEDS = [0, 1, 2]


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape) * scale


# ---------------------------------------------------------------------------
# GEMM patch engine
# ---------------------------------------------------------------------------

GEMM_SHAPES = [
    (8, 8, 8),
    (64, 64, 64),
    (64, 64, 20),     # head fc shape (N not tile-aligned)
    (37, 53, 29),     # fully unaligned
    (1, 64, 20),
    (128, 256, 64),
    (256, 19, 7),
]


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
def test_matmul_patch(seed, m, k, n):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x, y = rand(kx, (m, k)), rand(ky, (k, n))
    np.testing.assert_allclose(
        gemm.matmul_patch(x, y), ref.ref_matmul(x, y), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("seed", SEEDS[:2])
@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
def test_matmul_patch_k_streamed(seed, m, k, n):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed + 100))
    x, y = rand(kx, (m, k)), rand(ky, (k, n))
    np.testing.assert_allclose(
        gemm.matmul_patch_k(x, y), ref.ref_matmul(x, y), rtol=2e-5, atol=2e-5
    )


@pytest.mark.parametrize("bm,bn", [(8, 8), (32, 64), (128, 128), (256, 128)])
def test_matmul_patch_block_shapes(bm, bn):
    """Patch geometry is a tuning knob; results must be identical."""
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x, y = rand(kx, (96, 48)), rand(ky, (48, 40))
    np.testing.assert_allclose(
        gemm.matmul_patch(x, y, bm=bm, bn=bn),
        ref.ref_matmul(x, y),
        rtol=2e-5,
        atol=2e-5,
    )


def test_linear_custom_vjp_matches_autodiff_oracle():
    kx, ky, kg = jax.random.split(jax.random.PRNGKey(3), 3)
    x, w = rand(kx, (16, 24)), rand(ky, (24, 12))
    g = rand(kg, (16, 12))

    def pallas_loss(x, w):
        return (gemm.linear(x, w) * g).sum()

    def ref_loss(x, w):
        return (ref.ref_matmul(x, w) * g).sum()

    gx_p, gw_p = jax.grad(pallas_loss, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(ref_loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-5)


def test_mxu_utilization_bounds():
    assert gemm.mxu_utilization(128, 128, 64) == 1.0
    u = gemm.mxu_utilization(37, 53, 29)
    assert 0.0 < u <= 1.0


# ---------------------------------------------------------------------------
# FIMD IP (diagonal Fisher tile update)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("t", [1024, 4096, fimd.TILE])
def test_fimd_update(seed, t):
    kg, ka = jax.random.split(jax.random.PRNGKey(seed))
    g, a = rand(kg, (t,)), jnp.abs(rand(ka, (t,)))
    s = jnp.array([1.0 / 8])
    np.testing.assert_allclose(
        fimd.fimd_update(g, a, s), ref.ref_fimd_update(g, a, s), rtol=1e-6, atol=1e-7
    )


def test_fimd_accumulates_over_microbatches():
    """Streaming the kernel over M microbatches == one-shot mean of squares."""
    key = jax.random.PRNGKey(9)
    grads = rand(key, (8, fimd.TILE))
    acc = jnp.zeros((fimd.TILE,))
    s = jnp.array([1.0 / 8])
    for i in range(8):
        acc = fimd.fimd_update(grads[i], acc, s)
    np.testing.assert_allclose(acc, (grads**2).mean(axis=0), rtol=1e-5, atol=1e-6)


def test_fimd_zero_grad_is_identity():
    acc = jnp.arange(fimd.TILE, dtype=jnp.float32)
    out = fimd.fimd_update(jnp.zeros((fimd.TILE,)), acc, jnp.array([1.0]))
    np.testing.assert_allclose(out, acc)


# ---------------------------------------------------------------------------
# Dampening IP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("alpha,lam", [(10.0, 1.0), (25.0, 1.0), (50.0, 0.1), (0.5, 2.0)])
def test_dampen_tile(seed, alpha, lam):
    kt, kf, kd = jax.random.split(jax.random.PRNGKey(seed), 3)
    t = fimd.TILE
    theta = rand(kt, (t,))
    idf = jnp.abs(rand(kf, (t,)))
    idd = jnp.abs(rand(kd, (t,)))
    al, la = jnp.array([alpha]), jnp.array([lam])
    got_t, got_m = dampen.dampen_tile(theta, idf, idd, al, la)
    want_t, want_m = ref.ref_dampen(theta, idf, idd, al, la)
    np.testing.assert_allclose(got_t, want_t, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(got_m, want_m)


def test_dampen_properties():
    """Invariants of eq. (3)/(4): unselected params untouched; selected
    params shrink in magnitude (beta <= 1); mask is exactly the selection
    predicate."""
    key = jax.random.PRNGKey(11)
    t = fimd.TILE
    theta = rand(key, (t,))
    idf = jnp.abs(rand(jax.random.PRNGKey(12), (t,))) + 1e-6
    idd = jnp.abs(rand(jax.random.PRNGKey(13), (t,))) + 1e-6
    al, la = jnp.array([1.0]), jnp.array([1.0])
    out, mask = dampen.dampen_tile(theta, idf, idd, al, la)
    sel = np.asarray(idf > al[0] * idd)
    np.testing.assert_allclose(np.asarray(out)[~sel], np.asarray(theta)[~sel])
    assert np.all(np.abs(np.asarray(out)) <= np.abs(np.asarray(theta)) + 1e-7)
    np.testing.assert_allclose(np.asarray(mask), sel.astype(np.float32))


def test_dampen_alpha_monotone():
    """Larger alpha selects fewer parameters."""
    key = jax.random.PRNGKey(21)
    t = fimd.TILE
    theta = rand(key, (t,))
    idf = jnp.abs(rand(jax.random.PRNGKey(22), (t,)))
    idd = jnp.abs(rand(jax.random.PRNGKey(23), (t,)))
    counts = []
    for alpha in (0.1, 1.0, 10.0, 100.0):
        _, m = dampen.dampen_tile(theta, idf, idd, jnp.array([alpha]), jnp.array([1.0]))
        counts.append(float(m.sum()))
    assert counts == sorted(counts, reverse=True)


# ---------------------------------------------------------------------------
# im2col conv on the patch engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("b,hw,cin,cout,k", [(2, 8, 3, 8, 3), (1, 16, 8, 16, 3), (2, 8, 4, 4, 1)])
def test_conv2d_gemm(stride, b, hw, cin, cout, k):
    kx, kw = jax.random.split(jax.random.PRNGKey(b * 100 + hw))
    x = rand(kx, (b, hw, hw, cin))
    w = rand(kw, (k, k, cin, cout), scale=0.2)
    pad = k // 2
    np.testing.assert_allclose(
        conv.conv2d_gemm(x, w, stride, pad),
        ref.ref_conv2d(x, w, stride, pad),
        rtol=1e-4,
        atol=1e-4,
    )
