"""AOT artifact consistency: meta.json matches the model specs and the HLO
text files exist, are parseable-looking, and have the right entry arity.

Runs against the artifacts/ tree if present (make artifacts); otherwise the
export-path tests are skipped and only the in-process lowering tests run.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def art(*p):
    return os.path.join(ART, *p)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(art(".stamp")), reason="run `make artifacts` first"
)


def test_to_hlo_text_roundtrip_smoke():
    """Lower a trivial fn and sanity-check the HLO text format the Rust
    loader consumes (ENTRY + ROOT tuple)."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text
    assert "f32[4]" in text


@needs_artifacts
@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_meta_matches_spec(name):
    spec = M.MODELS[name]()
    with open(art(name, "meta.json")) as f:
        meta = json.load(f)
    assert meta["name"] == name
    assert meta["num_classes"] == spec.num_classes
    assert len(meta["segments"]) == spec.num_segments
    for seg, ms in zip(spec.segments, meta["segments"]):
        assert ms["name"] == seg.name
        assert [tuple(p["shape"]) for p in ms["params"]] == [
            s for _, s in seg.param_specs
        ]
        assert ms["macs_fwd_per_sample"] == seg.macs_fwd_per_sample


@needs_artifacts
@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_all_modules_exist_nonempty(name):
    with open(art(name, "meta.json")) as f:
        meta = json.load(f)
    files = [s["fwd"] for s in meta["segments"]]
    files += [s["bwd"] for s in meta["segments"]]
    files += list(meta["modules"].values())
    for fn in files:
        p = art(name, fn)
        assert os.path.exists(p), fn
        with open(p) as f:
            text = f.read()
        assert "ENTRY" in text, fn


@needs_artifacts
def test_shared_modules_exist():
    with open(art("shared", "shared.json")) as f:
        shared = json.load(f)
    assert shared["tile"] % 1024 == 0
    for fn in shared["modules"].values():
        assert os.path.exists(art("shared", fn)), fn


def _entry_param_count(text: str) -> int:
    """Count parameter instructions inside the ENTRY computation only
    (nested fusion computations also contain `parameter(i)` lines; ENTRY is
    the last computation in HLO text)."""
    entry = text[text.rindex("ENTRY") :]
    return entry.count(" parameter(")


@needs_artifacts
@pytest.mark.parametrize("name", ["rn18slim", "vitslim"])
def test_hlo_entry_arity(name):
    """fwd module must take (n_params + 1) args; bwd (n_params + 2)."""
    spec = M.MODELS[name]()
    with open(art(name, "meta.json")) as f:
        meta = json.load(f)
    for seg, ms in zip(spec.segments, meta["segments"]):
        n = len(seg.param_specs)
        with open(art(name, ms["fwd"])) as f:
            nparams = _entry_param_count(f.read())
        assert nparams == n + 1, (seg.name, nparams, n + 1)
        with open(art(name, ms["bwd"])) as f:
            nparams_b = _entry_param_count(f.read())
        assert nparams_b == n + 2, (seg.name, nparams_b)
