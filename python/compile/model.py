"""L2 — JAX model graphs for the FiCABU reproduction.

Two architecturally-faithful, width-reduced models (DESIGN.md §2):

* ``rn18slim`` — ResNet-18 topology: stem conv, 4 stages x 2 BasicBlocks
  (16 block convolutions, matching the paper's "16 convolutional layers"
  checkpoint grid), global-average-pool head. BatchNorm is replaced by
  GroupNorm so the model is stateless (no running statistics to ship across
  the AOT boundary); the unlearning mechanics only see per-layer parameter
  tensors either way.
* ``vitslim``  — ViT topology: 4x4 patch embedding + learned positional
  embedding, 12 pre-LN encoder blocks (the paper's checkpoint grid is every
  3 of 12), mean-pool + linear head.

Each model is a list of :class:`Segment` — the unit of the back-end-first
unlearning loop. Segment boundaries are where activations are cached and
where partial inference can resume, so every segment's ``apply`` is a pure
function ``(params, x) -> y``. The classifier head uses the Pallas patch
GEMM (`kernels.gemm.linear`), putting the L1 engine on the model path.

Depth convention (paper §III-A): l = 1 is the segment nearest the output
(the head), l = L the segment nearest the input (the stem / patch embed).
Segments are stored front-to-back (forward order); ``depth_l`` converts.
"""

import math
from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.gemm import linear

# ---------------------------------------------------------------------------
# Segment plumbing
# ---------------------------------------------------------------------------


@dataclass
class Segment:
    """One unlearning unit: a named pure function with named parameters."""

    name: str
    kind: str                              # stem | block | head | embed | encoder
    param_specs: List[Tuple[str, Tuple[int, ...]]]
    apply: Callable                        # (params: list[Array], x) -> y
    in_shape: Tuple[int, ...]              # per-sample shape (no batch dim)
    out_shape: Tuple[int, ...]
    macs_fwd_per_sample: int               # analytic MAC count, fwd, 1 sample

    @property
    def param_count(self) -> int:
        return sum(int(math.prod(s)) for _, s in self.param_specs)


@dataclass
class ModelSpec:
    name: str
    num_classes: int
    input_shape: Tuple[int, ...]           # per-sample, e.g. (32, 32, 3)
    segments: List[Segment] = field(default_factory=list)
    # attention heads of encoder segments (0 for conv models); exported in
    # meta.json — the Rust CpuBackend needs it to rebuild the head split
    heads: int = 0

    @property
    def num_segments(self) -> int:
        return len(self.segments)

    def depth_l(self, seg_index: int) -> int:
        """Paper depth index: head (last segment) -> l=1, stem -> l=L."""
        return self.num_segments - seg_index

    def logits_fn(self):
        """Full forward: (flat params..., x) -> logits, for AOT export."""
        counts = [len(s.param_specs) for s in self.segments]

        def fn(*args):
            args = _pin_args(args)
            x = args[-1]
            flat = list(args[:-1])
            off = 0
            for seg, c in zip(self.segments, counts):
                x = seg.apply(flat[off : off + c], x)
                off += c
            return (x,)

        return fn

    def all_param_specs(self):
        out = []
        for si, seg in enumerate(self.segments):
            for pname, shape in seg.param_specs:
                out.append((si, seg.name, pname, shape))
        return out


# ---------------------------------------------------------------------------
# Shared primitives (stateless)
# ---------------------------------------------------------------------------

GN_GROUPS = 4
GN_EPS = 1e-5
LN_EPS = 1e-5


def group_norm(x, gamma, beta, groups: int = GN_GROUPS):
    b, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(b, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + GN_EPS)).reshape(b, h, w, c)
    return xn * gamma + beta


def layer_norm(x, gamma, beta):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * gamma + beta


def conv2d(x, w, stride: int = 1):
    """SAME conv, NHWC/HWIO — the XLA-native path standing in for the VTA
    GEMM backbone (DESIGN.md §3); kernels/conv.py holds the explicit
    im2col+Pallas lowering, cross-checked in the kernel tests."""
    kh, _, _, _ = w.shape
    pad = kh // 2
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


# ---------------------------------------------------------------------------
# ResNet-18-slim
# ---------------------------------------------------------------------------


def _conv_macs(hw_out: int, cin: int, cout: int, k: int) -> int:
    return hw_out * hw_out * cout * cin * k * k


def build_rn18slim(num_classes: int = 20, width: int = 8,
                   img: int = 32) -> ModelSpec:
    """ResNet-18 topology at reduced width (stage widths w, 2w, 4w, 8w)."""
    spec = ModelSpec("rn18slim", num_classes, (img, img, 3))
    w0 = width

    # --- stem ---
    def stem_apply(p, x):
        wv, g, b = p
        return jax.nn.relu(group_norm(conv2d(x, wv, 1), g, b))

    spec.segments.append(
        Segment(
            name="stem",
            kind="stem",
            param_specs=[("w", (3, 3, 3, w0)), ("gamma", (w0,)), ("beta", (w0,))],
            apply=stem_apply,
            in_shape=(img, img, 3),
            out_shape=(img, img, w0),
            macs_fwd_per_sample=_conv_macs(img, 3, w0, 3),
        )
    )

    # --- 4 stages x 2 BasicBlocks ---
    stage_widths = [w0, 2 * w0, 4 * w0, 8 * w0]
    hw = img
    cin = w0
    for s, cout in enumerate(stage_widths):
        for b in range(2):
            stride = 2 if (s > 0 and b == 0) else 1
            down = (stride != 1) or (cin != cout)
            hw_out = hw // stride

            params = [
                ("w1", (3, 3, cin, cout)),
                ("g1", (cout,)),
                ("b1", (cout,)),
                ("w2", (3, 3, cout, cout)),
                ("g2", (cout,)),
                ("b2", (cout,)),
            ]
            if down:
                params += [("wd", (1, 1, cin, cout)), ("gd", (cout,)), ("bd", (cout,))]

            def block_apply(p, x, stride=stride, down=down):
                w1, g1, b1, w2, g2, b2 = p[:6]
                h = jax.nn.relu(group_norm(conv2d(x, w1, stride), g1, b1))
                h = group_norm(conv2d(h, w2, 1), g2, b2)
                if down:
                    wd, gd, bd = p[6:]
                    sc = group_norm(conv2d(x, wd, stride), gd, bd)
                else:
                    sc = x
                return jax.nn.relu(h + sc)

            macs = (
                _conv_macs(hw_out, cin, cout, 3)
                + _conv_macs(hw_out, cout, cout, 3)
                + (_conv_macs(hw_out, cin, cout, 1) if down else 0)
            )
            spec.segments.append(
                Segment(
                    name=f"s{s + 1}b{b + 1}",
                    kind="block",
                    param_specs=params,
                    apply=block_apply,
                    in_shape=(hw, hw, cin),
                    out_shape=(hw_out, hw_out, cout),
                    macs_fwd_per_sample=macs,
                )
            )
            hw, cin = hw_out, cout

    # --- head: GAP + Pallas-GEMM linear ---
    cfin = stage_widths[-1]

    def head_apply(p, x):
        wv, b = p
        pooled = x.mean(axis=(1, 2))
        return linear(pooled, wv) + b

    spec.segments.append(
        Segment(
            name="head",
            kind="head",
            param_specs=[("w", (cfin, num_classes)), ("b", (num_classes,))],
            apply=head_apply,
            in_shape=(hw, hw, cfin),
            out_shape=(num_classes,),
            macs_fwd_per_sample=cfin * num_classes,
        )
    )
    return spec


# ---------------------------------------------------------------------------
# ViT-slim
# ---------------------------------------------------------------------------


def build_vitslim(
    num_classes: int = 20,
    dim: int = 32,
    depth: int = 12,
    heads: int = 4,
    mlp_ratio: int = 2,
    patch: int = 4,
    img: int = 32,
) -> ModelSpec:
    spec = ModelSpec("vitslim", num_classes, (img, img, 3), heads=heads)
    tokens = (img // patch) ** 2
    hdim = dim // heads
    mlp = dim * mlp_ratio

    # --- patch embed (+ learned positional embedding) ---
    def embed_apply(p, x):
        wv, b, pos = p
        bsz = x.shape[0]
        xp = x.reshape(bsz, img // patch, patch, img // patch, patch, 3)
        xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(bsz, tokens, patch * patch * 3)
        return xp @ wv + b + pos

    spec.segments.append(
        Segment(
            name="embed",
            kind="embed",
            param_specs=[
                ("w", (patch * patch * 3, dim)),
                ("b", (dim,)),
                ("pos", (tokens, dim)),
            ],
            apply=embed_apply,
            in_shape=(img, img, 3),
            out_shape=(tokens, dim),
            macs_fwd_per_sample=tokens * patch * patch * 3 * dim,
        )
    )

    # --- encoder blocks (pre-LN) ---
    def enc_apply(p, x):
        ln1g, ln1b, wqkv, bqkv, wproj, bproj, ln2g, ln2b, w1, b1, w2, b2 = p
        bsz, t, d = x.shape
        h = layer_norm(x, ln1g, ln1b)
        qkv = h @ wqkv + bqkv
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads_view(a):
            return a.reshape(bsz, t, heads, hdim).transpose(0, 2, 1, 3)

        q, k, v = heads_view(q), heads_view(k), heads_view(v)
        att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / math.sqrt(hdim), axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(bsz, t, d)
        x = x + o @ wproj + bproj
        h2 = layer_norm(x, ln2g, ln2b)
        h2 = jax.nn.gelu(h2 @ w1 + b1) @ w2 + b2
        return x + h2

    enc_macs = (
        tokens * dim * 3 * dim                 # qkv
        + 2 * heads * tokens * tokens * hdim   # scores + AV
        + tokens * dim * dim                   # proj
        + 2 * tokens * dim * mlp               # mlp
    )
    for i in range(depth):
        spec.segments.append(
            Segment(
                name=f"enc{i + 1}",
                kind="encoder",
                param_specs=[
                    ("ln1g", (dim,)),
                    ("ln1b", (dim,)),
                    ("wqkv", (dim, 3 * dim)),
                    ("bqkv", (3 * dim,)),
                    ("wproj", (dim, dim)),
                    ("bproj", (dim,)),
                    ("ln2g", (dim,)),
                    ("ln2b", (dim,)),
                    ("w1", (dim, mlp)),
                    ("b1", (mlp,)),
                    ("w2", (mlp, dim)),
                    ("b2", (dim,)),
                ],
                apply=enc_apply,
                in_shape=(tokens, dim),
                out_shape=(tokens, dim),
                macs_fwd_per_sample=enc_macs,
            )
        )

    # --- head: LN + mean-pool + Pallas-GEMM linear ---
    def head_apply(p, x):
        g, b, wv, bv = p
        h = layer_norm(x, g, b).mean(axis=1)
        return linear(h, wv) + bv

    spec.segments.append(
        Segment(
            name="head",
            kind="head",
            param_specs=[
                ("lng", (dim,)),
                ("lnb", (dim,)),
                ("w", (dim, num_classes)),
                ("b", (num_classes,)),
            ],
            apply=head_apply,
            in_shape=(tokens, dim),
            out_shape=(num_classes,),
            macs_fwd_per_sample=dim * num_classes,
        )
    )
    return spec


# ---------------------------------------------------------------------------
# Losses / training step (exported whole-model modules)
# ---------------------------------------------------------------------------


def cross_entropy(logits, onehot):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(onehot * logp).sum(axis=-1).mean()


def _pin_args(args):
    """Defeat argument DCE in the StableHLO->XLA conversion.

    The xla_client converter drops ENTRY parameters whose *values* are
    unused (e.g. a bias in its own VJP) and silently renumbers the rest,
    which would desynchronise the Rust caller's positional argument
    binding. An optimization_barrier makes every argument live without
    changing any result."""
    return jax.lax.optimization_barrier(tuple(args))


def make_loss_grad_fn():
    """(logits[B,C], onehot[B,C]) -> dlogits for mean NLL — the gradient the
    FIMD stream starts from."""

    def fn(logits, onehot):
        logits, onehot = _pin_args((logits, onehot))
        b = logits.shape[0]
        return ((jax.nn.softmax(logits, axis=-1) - onehot) / b,)

    return fn


def make_train_step_fn(spec: ModelSpec):
    """One SGD step: (flat params..., x, onehot, lr) -> (new params..., loss)."""
    counts = [len(s.param_specs) for s in spec.segments]
    n_params = sum(counts)

    def forward(flat, x):
        off = 0
        for seg, c in zip(spec.segments, counts):
            x = seg.apply(flat[off : off + c], x)
            off += c
        return x

    def fn(*args):
        args = _pin_args(args)
        flat = list(args[:n_params])
        x, onehot, lr = args[n_params], args[n_params + 1], args[n_params + 2]

        def loss_fn(fl):
            return cross_entropy(forward(fl, x), onehot)

        loss, grads = jax.value_and_grad(loss_fn)(flat)
        new = [p - lr * g for p, g in zip(flat, grads)]
        return tuple(new) + (loss,)

    return fn


def make_segment_fwd_fn(seg: Segment):
    def fn(*args):
        args = _pin_args(args)
        return (seg.apply(list(args[:-1]), args[-1]),)

    return fn


def make_segment_bwd_fn(seg: Segment):
    """(params..., x, gy) -> (param grads..., gx) via VJP through the
    segment. Because the head uses the custom-VJP Pallas linear, its
    backward also runs on the patch engine."""
    n = len(seg.param_specs)

    def fn(*args):
        args = _pin_args(args)
        params = list(args[:n])
        x, gy = args[n], args[n + 1]

        def f(ps, xx):
            return seg.apply(ps, xx)

        _, vjp = jax.vjp(f, params, x)
        gparams, gx = vjp(gy)
        return tuple(gparams) + (gx,)

    return fn


MODELS = {
    "rn18slim": build_rn18slim,
    "vitslim": build_vitslim,
}
