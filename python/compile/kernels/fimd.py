"""L1 Pallas kernel for the FIMD IP (diagonal Fisher estimation).

Paper §IV-A, Fig. 5a: the FIMD module consumes gradient tiles produced by
the GEMM engine, squares each element and accumulates across the batch
dimension to produce the forget-set importance ``I_Df`` (eq. 2). The RTL
is a double-buffered LOAD -> SQUARE -> ACCUMULATE -> STORE 4-stage pipeline;
in Pallas the same schedule is a 1-D tile grid whose consecutive steps are
pipelined automatically, with SQUARE+ACCUMULATE fused on the VPU.

The kernel is stateless across calls: the accumulator tile is an explicit
input/output, so the Rust coordinator streams (grad tile, acc tile) pairs
through one compiled module per unlearning pass — mirroring the DMA-burst
organisation of the hardware IP.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One DMA burst / scratchpad line of the Unlearning Engine. 8192 f32 = 32 KiB,
# half of the 64 KB on-chip SRAM of the prototype (paper §IV-A), leaving the
# other half to the double buffer.
TILE = 8192
BLOCK = 1024  # VPU-friendly inner block (8 x 128 lanes)


def fimd_update(grad, acc, scale):
    """One FIMD accumulation step: ``acc + scale * grad**2`` (elementwise).

    Args:
      grad:  f32[TILE] gradient burst for a parameter chunk.
      acc:   f32[TILE] running importance accumulator for the same chunk.
      scale: f32[1] microbatch weight (1/num_microbatches), broadcast.

    Returns:
      f32[TILE] updated accumulator.
    """
    (t,) = grad.shape
    assert t % BLOCK == 0, f"tile {t} must be a multiple of {BLOCK}"

    def kernel(g_ref, a_ref, s_ref, o_ref):
        # SQUARE + ACCUMULATE stages, fused; LOAD/STORE are the BlockSpec
        # streams on either side.
        g = g_ref[...]
        o_ref[...] = a_ref[...] + s_ref[0] * g * g

    return pl.pallas_call(
        kernel,
        grid=(t // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(grad, acc, scale)
