"""im2col convolution on the patch-GEMM engine.

The FiCABU processor runs convolutions on its GEMM backbone by lowering
them to matrix multiplies (the standard VTA flow). This module provides the
same lowering on top of the Pallas patch GEMM: extract (kh*kw*cin) patches,
multiply by the reshaped filter, fold back to NHWC.

Used by the kernel test-suite and the GEMM benches; inside the exported
model graphs we let XLA's native conv lowering play the role of the VTA
backbone (DESIGN.md §3) — the paper's *novel* IPs (FIMD, Dampening) are the
Pallas kernels on the unlearning hot path.
"""

import jax
import jax.numpy as jnp

from .gemm import matmul_patch


def im2col(x, kh: int, kw: int, stride: int, padding: int):
    """NHWC -> (B*Ho*Wo, kh*kw*C) patch matrix."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    ho = (h + 2 * padding - kh) // stride + 1
    wo = (w + 2 * padding - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp,
                (0, i, j, 0),
                (b, i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(patch.reshape(b * ho * wo, c))
    return jnp.concatenate(cols, axis=1), (b, ho, wo)


def conv2d_gemm(x, w, stride: int = 1, padding: int = 1):
    """2-D convolution via im2col + patch GEMM.

    Args:
      x: f32[B,H,W,Cin] NHWC input.
      w: f32[kh,kw,Cin,Cout] HWIO filter.
    """
    kh, kw, cin, cout = w.shape
    cols, (b, ho, wo) = im2col(x, kh, kw, stride, padding)
    wmat = w.transpose(0, 1, 2, 3).reshape(kh * kw * cin, cout)
    out = matmul_patch(cols, wmat)
    return out.reshape(b, ho, wo, cout)
