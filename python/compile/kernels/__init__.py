from . import conv, dampen, fimd, gemm, ref  # noqa: F401
