"""Pure-jnp oracles for every L1 kernel.

These are the CORE correctness references: pytest sweeps shapes/dtypes and
asserts the Pallas kernels match these to float tolerance. Nothing here is
ever exported to HLO.
"""

import jax.numpy as jnp


def ref_matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def ref_fimd_update(grad, acc, scale):
    return acc + scale[0] * grad * grad


def ref_dampen(theta, i_df, i_d, alpha, lam):
    sel = i_df > alpha[0] * i_d
    beta = jnp.minimum(lam[0] * i_d / jnp.maximum(i_df, 1e-30), 1.0)
    return jnp.where(sel, beta * theta, theta), sel.astype(jnp.float32)


def ref_conv2d(x, w, stride: int = 1, padding: int = 1):
    import jax.lax as lax

    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
