"""L1 Pallas patch-streaming GEMM — the VTA-backbone analogue.

The FiCABU processor executes all matrix arithmetic on a GEMM engine that
streams fixed-size *patches* (tiles) from memory (paper §IV-A, Fig. 5c).
On TPU the analogous schedule is a Pallas grid over (M, N[, K]) tiles with
BlockSpecs expressing the HBM->VMEM movement; the MXU plays the PE array.

All kernels are lowered with ``interpret=True`` so the emitted HLO runs on
any PJRT backend (CPU here); real-TPU lowering would emit a Mosaic
custom-call instead (see DESIGN.md §6).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default patch shape. 128 matches the MXU systolic dimension; the VTA
# prototype in the paper uses 16x16 INT8 patches — the *streaming schedule*
# is what we reproduce, the patch size is a tuning knob (see bench_gemm).
DEF_BM = 128
DEF_BN = 128
DEF_BK = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(a, rows: int, cols: int):
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def matmul_patch(x, y, *, bm: int = DEF_BM, bn: int = DEF_BN):
    """Patch GEMM with full-K rows streamed per grid step.

    Grid is (M/bm, N/bn); each step loads an (bm, K) row-band of ``x`` and a
    (K, bn) column-band of ``y`` into VMEM and issues one MXU matmul.
    Suitable when K fits VMEM (true for every layer in the slim models).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), k
    xp, yp = _pad2(x, mp, kp), _pad2(y, kp, np_)

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def matmul_patch_k(x, y, *, bm: int = DEF_BM, bn: int = DEF_BN, bk: int = DEF_BK):
    """Patch GEMM with a K-streamed accumulation grid.

    Grid is (M/bm, N/bn, K/bk); the output block is revisited across the K
    axis and accumulated in place — the Pallas analogue of the VTA
    load/compute/store queue overlap (double buffering is the automatic
    pipelining of consecutive grid steps).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp, yp = _pad2(x, mp, kp), _pad2(y, kp, np_)
    nk = kp // bk

    def kernel(x_ref, y_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        )

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def linear(x, w):
    """``x @ w`` on the patch-GEMM engine, differentiable.

    Pallas kernels carry no autodiff rule, so the VJP is defined manually —
    both the forward and the two backward products run on the same patch
    engine, exactly as the processor would schedule them.
    """
    return matmul_patch(x, w)


def _linear_fwd(x, w):
    return linear(x, w), (x, w)


def _linear_bwd(res, g):
    x, w = res
    return matmul_patch(g, w.T), matmul_patch(x.T, g)


linear.defvjp(_linear_fwd, _linear_bwd)


def vmem_bytes(bm: int, bn: int, k: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one ``matmul_patch`` grid step."""
    return dtype_bytes * (bm * k + k * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int = DEF_BM, bn: int = DEF_BN):
    """Fraction of MXU work that is useful (non-padding) for a given GEMM."""
    mp, np_ = _ceil_to(m, min(bm, _ceil_to(m, 8))), _ceil_to(n, min(bn, _ceil_to(n, 8)))
    return (m * n * k) / float(mp * np_ * k)
