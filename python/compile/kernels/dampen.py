"""L1 Pallas kernel for the Dampening IP (selection + beta + update).

Paper §IV-A, Fig. 5b: for each parameter the IP compares ``I_Df`` against
``alpha * I_D`` (eq. 3), generates ``beta = min(lambda * I_D / I_Df, 1)``
(eq. 4) in the beta GENERATOR when selected, and updates the value by
multiplication. The RTL is a double-buffered 5-stage pipeline
LOAD -> COMPARE -> betaCALC -> MULTIPLY -> STORE; here all four compute
stages fuse into one VPU pass over the tile, and the LOAD/STORE stages are
the BlockSpec streams.

Balanced Dampening (paper eq. 5) is realised by the *coordinator* scaling
``(alpha, lambda)`` by the depth profile S(l) before issuing the tile — the
kernel itself stays layer-agnostic, exactly like the hardware IP.

Outputs both the updated parameters and the selection mask; the mask feeds
Fig. 3 (layer-wise selected-parameter distribution) and the MAC accounting.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fimd import BLOCK, TILE  # same burst geometry as the FIMD IP


def dampen_tile(theta, i_df, i_d, alpha, lam):
    """One Dampening pass over a parameter burst.

    Args:
      theta: f32[T] parameter chunk.
      i_df:  f32[T] forget-set importance for the chunk.
      i_d:   f32[T] stored global importance for the chunk.
      alpha: f32[1] selection threshold (already S(l)-scaled by L3).
      lam:   f32[1] dampening constant  (already S(l)-scaled by L3).

    Returns:
      (f32[T] updated theta, f32[T] selection mask in {0,1}).
    """
    (t,) = theta.shape
    assert t % BLOCK == 0, f"tile {t} must be a multiple of {BLOCK}"

    def kernel(t_ref, f_ref, d_ref, a_ref, l_ref, o_ref, m_ref):
        th = t_ref[...]
        idf = f_ref[...]
        idd = d_ref[...]
        # COMPARE
        sel = idf > a_ref[0] * idd
        # betaCALC — guard the divide; unselected lanes are masked anyway.
        beta = jnp.minimum(l_ref[0] * idd / jnp.maximum(idf, 1e-30), 1.0)
        # MULTIPLY
        o_ref[...] = jnp.where(sel, beta * th, th)
        m_ref[...] = sel.astype(jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=(t // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(theta, i_df, i_d, alpha, lam)
