"""AOT export: lower every L2 module to HLO *text* under artifacts/.

Python runs exactly once (``make artifacts``); afterwards the Rust binary
is self-contained. Interchange is HLO text, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model we export:
  fwd_<k>.hlo.txt    segment k forward, batch = BATCH          (params..., x) -> (y,)
  bwd_<k>.hlo.txt    segment k VJP, batch = MICROBATCH         (params..., x, gy) -> (grads..., gx)
  logits.hlo.txt     full forward, batch = BATCH               (params..., x) -> (logits,)
  train_step.hlo.txt one SGD step, batch = BATCH               (params..., x, onehot, lr) -> (params'..., loss)
  loss_grad.hlo.txt  dlogits of mean NLL, batch = MICROBATCH   (logits, onehot) -> (dlogits,)
  meta.json          segment/param/shape/MAC inventory for the Rust side

Shared (model-independent) engine modules:
  shared/fimd.hlo.txt    FIMD IP tile update        (grad, acc, scale) -> (acc',)
  shared/dampen.hlo.txt  Dampening IP tile pass     (theta, idf, id, alpha, lam) -> (theta', mask)
  shared/gemm.hlo.txt    patch-GEMM engine demo     (x, y) -> (out,)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.dampen import dampen_tile
from .kernels.fimd import TILE, fimd_update
from .kernels.gemm import matmul_patch_k
from .model import (
    MODELS,
    ModelSpec,
    make_loss_grad_fn,
    make_segment_bwd_fn,
    make_segment_fwd_fn,
    make_train_step_fn,
)

BATCH = 64        # forget-batch size N (paper §II) and eval batch
MICROBATCH = 8    # Fisher micro-batch: grads of 8-sample slices are squared
                  # and averaged; preserves the relative magnitudes that the
                  # selection rule consumes (DESIGN.md §2)
GEMM_DEMO = 256   # shared gemm module dimensions


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, arg_specs, path: str) -> None:
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def export_model(spec: ModelSpec, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    meta = {
        "name": spec.name,
        "num_classes": spec.num_classes,
        "input_shape": list(spec.input_shape),
        "batch": BATCH,
        "microbatch": MICROBATCH,
        "tile": TILE,
        "heads": spec.heads,
        "segments": [],
        "modules": {
            "logits": "logits.hlo.txt",
            "train_step": "train_step.hlo.txt",
            "loss_grad": "loss_grad.hlo.txt",
        },
    }

    for k, seg in enumerate(spec.segments):
        pspecs = [f32(s) for _, s in seg.param_specs]
        fwd_name = f"fwd_{k:02d}.hlo.txt"
        bwd_name = f"bwd_{k:02d}.hlo.txt"
        lower_to_file(
            make_segment_fwd_fn(seg),
            pspecs + [f32((BATCH,) + seg.in_shape)],
            os.path.join(out_dir, fwd_name),
        )
        lower_to_file(
            make_segment_bwd_fn(seg),
            pspecs
            + [f32((MICROBATCH,) + seg.in_shape), f32((MICROBATCH,) + seg.out_shape)],
            os.path.join(out_dir, bwd_name),
        )
        meta["segments"].append(
            {
                "name": seg.name,
                "kind": seg.kind,
                "params": [
                    {"name": n, "shape": list(s)} for n, s in seg.param_specs
                ],
                "in_shape": list(seg.in_shape),
                "out_shape": list(seg.out_shape),
                "macs_fwd_per_sample": seg.macs_fwd_per_sample,
                "fwd": fwd_name,
                "bwd": bwd_name,
            }
        )
        print(f"  [{spec.name}] segment {k:2d} {seg.name:8s} "
              f"params={seg.param_count:7d} macs/sample={seg.macs_fwd_per_sample}")

    all_pspecs = [f32(s) for _, s in sum(
        ([p for p in seg.param_specs] for seg in spec.segments), [])]
    lower_to_file(
        spec.logits_fn(),
        all_pspecs + [f32((BATCH,) + spec.input_shape)],
        os.path.join(out_dir, "logits.hlo.txt"),
    )
    lower_to_file(
        make_train_step_fn(spec),
        all_pspecs
        + [
            f32((BATCH,) + spec.input_shape),
            f32((BATCH, spec.num_classes)),
            f32(()),
        ],
        os.path.join(out_dir, "train_step.hlo.txt"),
    )
    lower_to_file(
        make_loss_grad_fn(),
        [f32((MICROBATCH, spec.num_classes)), f32((MICROBATCH, spec.num_classes))],
        os.path.join(out_dir, "loss_grad.hlo.txt"),
    )
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print(f"  [{spec.name}] logits/train_step/loss_grad + meta.json written")


def export_shared(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    lower_to_file(
        lambda g, a, s: (fimd_update(g, a, s),),
        [f32((TILE,)), f32((TILE,)), f32((1,))],
        os.path.join(out_dir, "fimd.hlo.txt"),
    )
    lower_to_file(
        lambda t, idf, idd, al, la: dampen_tile(t, idf, idd, al, la),
        [f32((TILE,)), f32((TILE,)), f32((TILE,)), f32((1,)), f32((1,))],
        os.path.join(out_dir, "dampen.hlo.txt"),
    )
    lower_to_file(
        lambda x, y: (matmul_patch_k(x, y),),
        [f32((GEMM_DEMO, GEMM_DEMO)), f32((GEMM_DEMO, GEMM_DEMO))],
        os.path.join(out_dir, "gemm.hlo.txt"),
    )
    with open(os.path.join(out_dir, "shared.json"), "w") as f:
        json.dump(
            {
                "tile": TILE,
                "gemm_demo": GEMM_DEMO,
                "modules": {
                    "fimd": "fimd.hlo.txt",
                    "dampen": "dampen.hlo.txt",
                    "gemm": "gemm.hlo.txt",
                },
            },
            f,
            indent=1,
        )
    print("  [shared] fimd/dampen/gemm + shared.json written")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--models", default="rn18slim,vitslim")
    args = ap.parse_args()

    export_shared(os.path.join(args.out, "shared"))
    for name in args.models.split(","):
        spec = MODELS[name]()
        export_model(spec, os.path.join(args.out, name))
    # build stamp for make
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print("artifacts complete")


if __name__ == "__main__":
    main()
