//! Edge serving scenario: concurrent clients submit typed forget
//! requests — single identities, multi-identity events, and per-sample
//! erasure — to a multi-worker unlearning fleet. The dispatcher
//! coalesces requests with equal canonical spec keys into one execution
//! with fan-out replies, sheds load when the bounded queue fills, and
//! rolls per-worker latency histograms up into fleet statistics.
//!
//! Run: `cargo run --release --example edge_serving`

use ficabu::config::SharedMeta;
use ficabu::coordinator::{Fleet, FleetConfig, Pacing, Reply, WorkerSpec};
use ficabu::exp::{self, tables::mode_config, DatasetKind, Mode, PrepareOpts};
use ficabu::unlearn::ForgetSpec;

fn main() -> anyhow::Result<()> {
    let prep = exp::prepare(
        "rn18slim",
        DatasetKind::PinsFace,
        &PrepareOpts::default(),
    )?;
    let cfg = mode_config(&prep, Mode::Ficabu, None);
    let erased_samples: Vec<usize> = prep.train.class_indices(9).into_iter().take(6).collect();
    let spec = WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: SharedMeta::resolve()?,
        params: prep.params,
        global: prep.global,
        train: prep.train,
        cfg,
        precision: prep.precision,
    };
    let fleet = Fleet::start(
        spec,
        FleetConfig {
            workers: 2,
            queue_cap: 16,
            deadline: None,
            batch_max: 2,
            pacing: Pacing::Host,
        },
    )?;

    println!("=== edge serving: 3 clients x 2 forget requests on a 2-worker fleet ===\n");

    // Three clients, two requests each, covering the spec grammar:
    // client 0 forgets two single identities, client 1 forgets an
    // identity and a two-identity event, client 2 erases specific
    // samples and repeats client 0's second identity *as a single-id
    // multi-class spec* — if the two requests overlap in the queue they
    // coalesce (canonical keys equal) into one execution with fan-out
    // replies.
    let requests: [[ForgetSpec; 2]; 3] = [
        [ForgetSpec::Class(0), ForgetSpec::Class(1)],
        [ForgetSpec::Class(2), ForgetSpec::Classes(vec![5, 3])],
        [ForgetSpec::Samples(erased_samples), ForgetSpec::Classes(vec![1])],
    ];
    let mut ok = 0;
    std::thread::scope(|s| -> anyhow::Result<()> {
        let fleet = &fleet;
        let mut joins = Vec::new();
        for specs in requests {
            joins.push(s.spawn(move || {
                specs.map(|spec| (spec.clone(), fleet.submit(spec).recv()))
            }));
        }
        for j in joins {
            for (spec, reply) in j.join().expect("client thread") {
                match reply.expect("fleet answers every admitted request") {
                    Reply::Done(sm) => {
                        ok += 1;
                        println!(
                            "{:16} Df {:5.1}%  Dr {:5.1}%  stop l={:<8} MACs {:7.4}%  energy {:8.4} mJ ({:6.3}% of SSD)  sim {:7.1} ms  queue {:6.1} ms  service {:7.1} ms",
                            spec.to_string(),
                            100.0 * sm.forget_acc,
                            100.0 * sm.retain_acc,
                            format!("{:?}", sm.stop_depth),
                            sm.macs_vs_ssd_pct,
                            sm.sim_energy_mj,
                            sm.sim_energy_vs_ssd_pct,
                            sm.sim_ms,
                            sm.timing.queue_ms,
                            sm.timing.service_ms,
                        );
                    }
                    Reply::Failed(e) => println!("{spec}: FAILED ({e})"),
                    Reply::Backpressure { queue_len, queue_cap } => {
                        println!("{spec}: shed (queue {queue_len}/{queue_cap})")
                    }
                    Reply::Expired { missed_by_ms } => {
                        println!("{spec}: expired ({missed_by_ms:.0} ms late)")
                    }
                }
            }
        }
        Ok(())
    })?;

    let stats = fleet.shutdown()?;
    let total = stats.merged();
    println!(
        "\nfleet stats: admitted {} coalesced {} served {} failures {} passes {}",
        stats.admitted, stats.coalesced, total.served, total.failures, total.batches
    );
    println!(
        "latency: queue p50 {:.1} ms p99 {:.1} ms | service p50 {:.1} ms p99 {:.1} ms",
        total.queue_hist.p50_ms(),
        total.queue_hist.p99_ms(),
        total.service_hist.p50_ms(),
        total.service_hist.p99_ms()
    );
    assert_eq!(ok, 6, "all requests must succeed");
    // 6 requests, every one either executed or coalesced onto one
    assert_eq!(total.served + stats.coalesced, 6);
    println!("edge serving OK");
    Ok(())
}
