//! Edge serving scenario (Fig. 1 right): concurrent clients submit
//! forget-identity requests to the on-device coordinator; the single
//! Unlearning Engine services them FIFO and reports per-request quality,
//! MACs, simulated energy, and queue/service latency.
//!
//! Run: `cargo run --release --example edge_serving`

use std::time::Instant;

use ficabu::coordinator::{EdgeServer, Request};
use ficabu::exp::{self, tables::mode_config, DatasetKind, Mode, PrepareOpts};
use ficabu::hwsim::mem::Precision;
use ficabu::hwsim::{BaselineProcessor, FicabuProcessor};

fn main() -> anyhow::Result<()> {
    let prep = exp::prepare(
        "rn18slim",
        DatasetKind::PinsFace,
        &PrepareOpts::default(),
    )?;
    let cfg = mode_config(&prep, Mode::Ficabu, None);
    let tile = prep.model.meta.tile;
    let mut server = EdgeServer::new(
        prep.model,
        prep.params,
        prep.global,
        prep.fimd,
        prep.damp,
        prep.train,
        cfg,
        FicabuProcessor::new(tile, Precision::Int8),
        BaselineProcessor::new(tile, Precision::Int8),
    );

    // three clients, each requesting two identities be forgotten
    let (tx, rx) = std::sync::mpsc::channel();
    let mut clients = Vec::new();
    for c in 0..3usize {
        let tx = tx.clone();
        clients.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            for r in 0..2usize {
                let class = c * 2 + r;
                let (rtx, rrx) = std::sync::mpsc::channel();
                tx.send((Instant::now(), Request::Unlearn { class, reply: rtx })).unwrap();
                replies.push((class, rrx));
            }
            replies
                .into_iter()
                .map(|(c, r)| (c, r.recv().unwrap()))
                .collect::<Vec<_>>()
        }));
    }
    // stats probe
    let stats_rx = {
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send((Instant::now(), Request::Stats { reply: rtx })).unwrap();
        rrx
    };
    drop(tx);

    server.serve(rx)?;

    println!("=== edge serving: 3 clients x 2 forget requests (PinsFace-like) ===\n");
    let mut ok = 0;
    for client in clients {
        for (class, reply) in client.join().unwrap() {
            match reply {
                Ok(s) => {
                    ok += 1;
                    println!(
                        "identity {class}: Df {:5.1}%  Dr {:5.1}%  stop l={:<8} MACs {:7.4}%  energy {:8.4} mJ ({:6.3}% of SSD)  queue {:6.1} ms  service {:7.1} ms",
                        100.0 * s.forget_acc,
                        100.0 * s.retain_acc,
                        format!("{:?}", s.stop_depth),
                        s.macs_vs_ssd_pct,
                        s.sim_energy_mj,
                        s.sim_energy_vs_ssd_pct,
                        s.timing.queue_ms,
                        s.timing.service_ms,
                    );
                }
                Err(e) => println!("identity {class}: FAILED ({e})"),
            }
        }
    }
    if let Ok(st) = stats_rx.recv() {
        println!(
            "\nserver stats at probe: served {} failures {} mean queue {:.1} ms mean service {:.1} ms",
            st.served, st.failures, st.mean_queue_ms(), st.mean_service_ms()
        );
    }
    assert_eq!(ok, 6, "all requests must succeed");
    println!("edge serving OK");
    Ok(())
}
