//! Edge serving scenario: concurrent clients submit typed forget
//! requests — single identities, multi-identity events, and per-sample
//! erasure — to a multi-worker unlearning fleet. The dispatcher
//! coalesces requests with equal canonical spec keys into one execution
//! with fan-out replies, sheds load when the bounded queue fills, and
//! rolls per-worker latency histograms up into fleet statistics.
//!
//! Part two puts the *same* fleet on the wire: an HTTP/1.1 front-end is
//! bound on a loopback port and driven by a hand-rolled socket client —
//! the JSON request/response contracts (`POST /forget`, `GET /stats`,
//! `GET /healthz`) end to end, including a 400 for an out-of-range spec.
//!
//! Run: `cargo run --release --example edge_serving`

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use ficabu::config::SharedMeta;
use ficabu::coordinator::{
    Fleet, FleetConfig, HttpConfig, HttpServer, Pacing, Reply, WorkerSpec,
};
use ficabu::exp::{self, tables::mode_config, DatasetKind, Mode, PrepareOpts};
use ficabu::unlearn::ForgetSpec;
use ficabu::util::json::Json;

/// Minimal one-shot HTTP client: one connection per request
/// (`Connection: close`), returns the status code and parsed JSON body.
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> anyhow::Result<(u16, Json)> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nhost: edge\r\nconnection: close\r\n\
         content-length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("malformed status line in `{text}`"))?
        .parse()?;
    let payload = text.split("\r\n\r\n").nth(1).unwrap_or("").trim();
    Ok((status, Json::parse(payload)?))
}

fn main() -> anyhow::Result<()> {
    let prep = exp::prepare(
        "rn18slim",
        DatasetKind::PinsFace,
        &PrepareOpts::default(),
    )?;
    let cfg = mode_config(&prep, Mode::Ficabu, None);
    let num_classes = prep.model.meta.num_classes;
    let num_samples = prep.train.len();
    let erased_samples: Vec<usize> = prep.train.class_indices(9).into_iter().take(6).collect();
    let spec = WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: SharedMeta::resolve()?,
        params: prep.params,
        global: prep.global,
        train: prep.train,
        cfg,
        precision: prep.precision,
    };
    let fleet = Arc::new(Fleet::start(
        spec,
        FleetConfig {
            workers: 2,
            queue_cap: 16,
            deadline: None,
            batch_max: 2,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        },
    )?);

    println!("=== edge serving: 3 clients x 2 forget requests on a 2-worker fleet ===\n");

    // Three clients, two requests each, covering the spec grammar:
    // client 0 forgets two single identities, client 1 forgets an
    // identity and a two-identity event, client 2 erases specific
    // samples and repeats client 0's second identity *as a single-id
    // multi-class spec* — if the two requests overlap in the queue they
    // coalesce (canonical keys equal) into one execution with fan-out
    // replies.
    let requests: [[ForgetSpec; 2]; 3] = [
        [ForgetSpec::Class(0), ForgetSpec::Class(1)],
        [ForgetSpec::Class(2), ForgetSpec::Classes(vec![5, 3])],
        [ForgetSpec::Samples(erased_samples), ForgetSpec::Classes(vec![1])],
    ];
    let mut ok = 0;
    std::thread::scope(|s| -> anyhow::Result<()> {
        let fleet = &fleet;
        let mut joins = Vec::new();
        for specs in requests {
            joins.push(s.spawn(move || {
                specs.map(|spec| (spec.clone(), fleet.submit(spec).recv()))
            }));
        }
        for j in joins {
            for (spec, reply) in j.join().expect("client thread") {
                match reply.expect("fleet answers every admitted request") {
                    Reply::Done(sm) => {
                        ok += 1;
                        println!(
                            "{:16} Df {:5.1}%  Dr {:5.1}%  stop l={:<8} MACs {:7.4}%  energy {:8.4} mJ ({:6.3}% of SSD)  sim {:7.1} ms  queue {:6.1} ms  service {:7.1} ms",
                            spec.to_string(),
                            100.0 * sm.forget_acc,
                            100.0 * sm.retain_acc,
                            format!("{:?}", sm.stop_depth),
                            sm.macs_vs_ssd_pct,
                            sm.sim_energy_mj,
                            sm.sim_energy_vs_ssd_pct,
                            sm.sim_ms,
                            sm.timing.queue_ms,
                            sm.timing.service_ms,
                        );
                    }
                    Reply::Failed(e) => println!("{spec}: FAILED ({e})"),
                    Reply::Backpressure { queue_len, queue_cap } => {
                        println!("{spec}: shed (queue {queue_len}/{queue_cap})")
                    }
                    Reply::Expired { missed_by_ms } => {
                        println!("{spec}: expired ({missed_by_ms:.0} ms late)")
                    }
                }
            }
        }
        Ok(())
    })?;
    assert_eq!(ok, 6, "all requests must succeed");

    println!("\n=== over the wire: HTTP front-end on the same fleet ===\n");
    let srv = HttpServer::bind(
        "127.0.0.1:0",
        Arc::clone(&fleet),
        HttpConfig { bounds: Some((num_classes, num_samples)), ..HttpConfig::default() },
    )?;
    let addr = srv.local_addr();

    let (status, j) = http(addr, "GET", "/healthz", "")?;
    println!("GET  /healthz          -> {status} {j}");
    assert_eq!(status, 200);

    // the CLI grammar as a JSON string...
    let (status, j) = http(addr, "POST", "/forget", r#"{"spec": "classes:3,5"}"#)?;
    let sm = j.req("summary")?;
    println!(
        "POST /forget classes:3,5 -> {status} spec={} Df {:.1}% service {:.0} ms",
        sm.req("spec")?.as_str().unwrap_or("?"),
        100.0 * sm.req("forget_acc")?.as_f64().unwrap_or(f64::NAN),
        sm.req("service_ms")?.as_f64().unwrap_or(f64::NAN),
    );
    assert_eq!(status, 200);

    // ...and the structured object form, with a per-request deadline
    let (status, j) = http(
        addr,
        "POST",
        "/forget",
        r#"{"spec": {"class": 7}, "deadline_ms": 600000}"#,
    )?;
    println!(
        "POST /forget class:7     -> {status} code={}",
        j.req("code")?.as_str().unwrap_or("?")
    );
    assert_eq!(status, 200);

    // out-of-range spec: rejected at admission with a machine-readable 400
    let (status, j) = http(addr, "POST", "/forget", r#"{"spec": "class:9999"}"#)?;
    println!(
        "POST /forget class:9999  -> {status} code={} ({})",
        j.req("code")?.as_str().unwrap_or("?"),
        j.req("error")?.as_str().unwrap_or("?")
    );
    assert_eq!(status, 400);

    let (status, j) = http(addr, "GET", "/stats", "")?;
    let rollup = j.req("rollup")?;
    println!(
        "GET  /stats              -> {status} served={} service_p99_ms={:.0}",
        rollup.req("served")?.as_i64().unwrap_or(-1),
        rollup.req("service_p99_ms")?.as_f64().unwrap_or(f64::NAN),
    );
    assert_eq!(status, 200);

    srv.shutdown();
    let fleet = Arc::try_unwrap(fleet)
        .ok()
        .expect("http shutdown releases every fleet handle");
    let stats = fleet.shutdown()?;
    let total = stats.merged();
    println!(
        "\nfleet stats: admitted {} coalesced {} served {} failures {} passes {}",
        stats.admitted, stats.coalesced, total.served, total.failures, total.batches
    );
    println!(
        "latency: queue p50 {:.1} ms p99 {:.1} ms | service p50 {:.1} ms p99 {:.1} ms",
        total.queue_hist.p50_ms(),
        total.queue_hist.p99_ms(),
        total.service_hist.p50_ms(),
        total.service_hist.p99_ms()
    );
    // 6 in-process requests + 2 wire executions, every one either
    // executed or coalesced onto one (the 400 never reached the queue)
    assert_eq!(total.served + stats.coalesced, 8);
    println!("edge serving OK");
    Ok(())
}
