//! Fig. 4 — uniform scaling vs the sigmoid Balanced-Dampening profile.
//!
//! Prints S(l) for the uniform baseline and for sigmoid profiles at a few
//! (c_m, b_r), including the paper's calibration (c_m from the smoothed
//! SSD selection extrema, b_r = 10) computed live on rn18slim.
//!
//! Run: `cargo run --release --example fig4`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::unlearn::Schedule;

fn print_profile(label: &str, s: &Schedule, big_l: usize) {
    let prof = s.profile(big_l);
    print!("{label:24}");
    for v in &prof {
        print!(" {v:5.2}");
    }
    println!();
}

fn main() -> anyhow::Result<()> {
    let big_l = 10; // rn18slim depth
    print!("{:24}", "l =");
    for l in 1..=big_l {
        print!(" {l:5}");
    }
    println!("   (l=1 back-end ... l=L front-end)");

    print_profile("uniform (SSD)", &Schedule::Uniform, big_l);
    for (cm, br) in [(5.5, 10.0), (3.0, 10.0), (8.0, 10.0), (5.5, 4.0)] {
        print_profile(
            &format!("sigmoid cm={cm} br={br}"),
            &Schedule::Sigmoid { cm, br },
            big_l,
        );
    }

    // live calibration from an SSD selection profile (paper §III-B)
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &PrepareOpts::default())?;
    let ssd = exp::run_mode(&prep, 0, Mode::Ssd, None)?;
    let sel = ssd.report.unwrap().selected_per_depth;
    println!("\nSSD selected per depth: {sel:?}");
    let cal = Schedule::from_selection_distribution(&sel, 10.0);
    if let Schedule::Sigmoid { cm, br } = &cal {
        println!("calibrated: c_m = {cm:.2}, b_r = {br}");
    }
    print_profile("calibrated profile", &cal, big_l);
    println!("\npaper shape: S(l) = 1 at the back-end rising to b_r at the front-end,");
    println!("mirroring the selection distribution in reverse.");
    Ok(())
}
