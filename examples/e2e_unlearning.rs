//! End-to-end driver (DESIGN.md deliverable): proves all three layers
//! compose on a real small workload.
//!
//! 1. Trains the slim ResNet-18 from scratch *from the Rust binary* by
//!    repeatedly executing the AOT-compiled `train_step` HLO (L2 graph
//!    calling the L1 Pallas head kernel), logging the loss curve.
//! 2. Computes the stored global importance I_D through the FIMD engine
//!    module (the L1 Pallas FIMD kernel compiled to HLO).
//! 3. Runs the full FiCABU unlearning pipeline for several classes and
//!    reports the paper's headline metrics (Df -> random guess, Dr
//!    preserved, editing-MACs and simulated-energy collapse vs SSD).
//!
//! Run: `cargo run --release --example e2e_unlearning`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::hwsim::mem::Precision;
use ficabu::metrics::rpr::rpr;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let opts = PrepareOpts {
        train_steps: 160,
        retrain: true, // always train live in the e2e driver
        verbose: true,
        ..Default::default()
    };
    println!("=== phase 1: training rn18slim on synthetic CIFAR-20 (live) ===");
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts)?;
    println!("loss curve ({} steps):", prep.loss_curve.len());
    for (i, chunk) in prep.loss_curve.chunks(20).enumerate() {
        let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
        println!("  steps {:3}-{:3}: mean loss {:.4}", i * 20, i * 20 + chunk.len() - 1, mean);
    }
    let all: Vec<usize> = (0..prep.train.len()).collect();
    let train_acc = ficabu::metrics::eval_accuracy(&prep.model, &prep.params, &prep.train, &all)?;
    println!("final train accuracy: {:.2}%", 100.0 * train_acc);

    println!("\n=== phase 2: unlearning sweep (SSD vs FiCABU) ===");
    let classes = [0usize, 1, 2];
    let mut sum_es = 0.0;
    for &class in &classes {
        let ssd = exp::run_mode(&prep, class, Mode::Ssd, None)?;
        let fic = exp::run_mode(
            &prep,
            class,
            Mode::Ficabu,
            ssd.report.as_ref().map(|r| r.selected_per_depth.as_slice()),
        )?;
        let base = exp::run_mode(&prep, class, Mode::Baseline, None)?;
        let (e_fic, e_ssd, es) = exp::tables::hardware_cost(
            &prep,
            fic.report.as_ref().unwrap(),
            ssd.report.as_ref().unwrap(),
            Precision::Int8,
        );
        sum_es += es;
        println!(
            "class {class}: Df {:.1}->{:.1}% | Dr {:.1}->{:.1}% (SSD {:.1}%) | RPR {:+.1} | MACs {:.3}% | energy {:.2} -> {:.2} mJ (ES {:.2}%)",
            100.0 * base.df,
            100.0 * fic.df,
            100.0 * base.dr,
            100.0 * fic.dr,
            100.0 * ssd.dr,
            rpr(base.dr, ssd.dr, fic.dr),
            fic.macs_vs_ssd_pct,
            e_ssd,
            e_fic,
            100.0 * es,
        );
    }
    println!(
        "\nmean simulated energy savings: {:.2}%  (paper: 93.52% CIFAR-20)",
        100.0 * sum_es / classes.len() as f64
    );
    println!("e2e driver complete in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
