//! Table II — Balanced Dampening vs baseline and SSD.
//!
//! BD replaces the fixed (alpha, lambda) with the sigmoid depth profile
//! S(l) (calibrated per §III-B from the SSD selection distribution,
//! b_r = 10). Metrics: Dr, Df, dDr (drop vs baseline) and RPR (eq. 7).
//!
//! Run: `cargo run --release --example table2 [-- --avg-classes N]`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::metrics::rpr::rpr;
use ficabu::util::cli::Args;

struct Row {
    label: String,
    base_dr: f64,
    ssd_dr: f64,
    ssd_df: f64,
    bd_dr: f64,
    bd_df: f64,
}

impl Row {
    fn print(&self) {
        let d_ssd = 100.0 * (self.base_dr - self.ssd_dr);
        let d_bd = 100.0 * (self.base_dr - self.bd_dr);
        println!(
            "{:10} SSD: Dr {:6.2} Df {:6.2} dDr {:5.2} | BD: Dr {:6.2} Df {:6.2} dDr {:5.2} | RPR {:+7.2}",
            self.label,
            100.0 * self.ssd_dr,
            100.0 * self.ssd_df,
            d_ssd,
            100.0 * self.bd_dr,
            100.0 * self.bd_df,
            d_bd,
            rpr(self.base_dr, self.ssd_dr, self.bd_dr),
        );
    }
}

fn run_class(prep: &exp::Prepared, class: usize, label: &str) -> anyhow::Result<Row> {
    let base = exp::run_mode(prep, class, Mode::Baseline, None)?;
    let ssd = exp::run_mode(prep, class, Mode::Ssd, None)?;
    // calibrate the sigmoid from this class's SSD selection profile
    let sel = ssd.report.as_ref().map(|r| r.selected_per_depth.clone());
    let bd = exp::run_mode(prep, class, Mode::Bd, sel.as_deref())?;
    Ok(Row {
        label: label.to_string(),
        base_dr: base.dr,
        ssd_dr: ssd.dr,
        ssd_df: ssd.df,
        bd_dr: bd.dr,
        bd_df: bd.df,
    })
}

fn section(prep: &exp::Prepared, named: &[(usize, &str)], avg_classes: usize) -> anyhow::Result<()> {
    println!("--- {} / {} (b_r = 10, c_m from SSD selection) ---",
        prep.model.meta.name, prep.kind.tag());
    for &(c, label) in named {
        run_class(prep, c, label)?.print();
    }
    let classes: Vec<usize> = (named.len()..named.len() + avg_classes).collect();
    let rows: Vec<Row> = classes
        .iter()
        .map(|&c| run_class(prep, c, &format!("c{c}")))
        .collect::<anyhow::Result<_>>()?;
    let n = rows.len() as f64;
    Row {
        label: format!("Avg({avg_classes})"),
        base_dr: rows.iter().map(|r| r.base_dr).sum::<f64>() / n,
        ssd_dr: rows.iter().map(|r| r.ssd_dr).sum::<f64>() / n,
        ssd_df: rows.iter().map(|r| r.ssd_df).sum::<f64>() / n,
        bd_dr: rows.iter().map(|r| r.bd_dr).sum::<f64>() / n,
        bd_df: rows.iter().map(|r| r.bd_df).sum::<f64>() / n,
    }
    .print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    args.declare(&["avg-classes", "steps"]);
    args.finish()?;
    let avg_classes = args.usize_or("avg-classes", 4)?;
    let opts = PrepareOpts { train_steps: args.usize_or("steps", 240)?, ..Default::default() };
    let named = [(0usize, "Rocket*"), (1usize, "MR*")];

    println!("=== Table II(a): CIFAR-20-like ===");
    let rn = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts)?;
    section(&rn, &named, avg_classes)?;
    drop(rn);
    let opts_vit = PrepareOpts { train_steps: 400, lr: 0.15, ..opts.clone() };
    let vit = exp::prepare("vitslim", DatasetKind::Cifar20, &opts_vit)?;
    section(&vit, &named, avg_classes)?;
    drop(vit);

    println!("\n=== Table II(b): PinsFace-like ===");
    let pins = exp::prepare("rn18slim", DatasetKind::PinsFace, &opts)?;
    section(&pins, &[], avg_classes.max(2))?;

    println!("\npaper shape: BD matches SSD forget accuracy with smaller dDr (positive RPR).");
    Ok(())
}
