//! Quickstart: train a slim ResNet-18 from the Rust binary, forget one
//! class with FiCABU, verify random-guess forget accuracy and preserved
//! retain accuracy — in ~2 minutes on CPU.
//!
//! Run: `cargo run --release --example quickstart`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};

fn main() -> anyhow::Result<()> {
    // 1. Prepare: synthesizes the CIFAR-20-like corpus, trains via the AOT
    //    train_step module (or loads the cached checkpoint), computes the
    //    stored global importance I_D.
    let opts = PrepareOpts { train_steps: 120, ..Default::default() };
    let prep = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts)?;
    println!(
        "model: {} ({} segments, {} params)",
        prep.model.meta.name,
        prep.model.meta.num_segments(),
        prep.model.meta.total_params()
    );

    // 2. Pre-unlearning state.
    let class = 3;
    let before = exp::run_mode(&prep, class, Mode::Baseline, None)?;
    println!(
        "before: retain {:.1}%  forget {:.1}%",
        100.0 * before.dr,
        100.0 * before.df
    );

    // 3. Forget the class with the full FiCABU method (Context-Adaptive
    //    Unlearning + Balanced Dampening).
    let after = exp::run_mode(&prep, class, Mode::Ficabu, None)?;
    println!(
        "after:  retain {:.1}%  forget {:.1}%  (target tau = {:.0}%)",
        100.0 * after.dr,
        100.0 * after.df,
        100.0 * prep.kind.tau()
    );
    println!(
        "editing MACs: {:.3}% of SSD{}",
        after.macs_vs_ssd_pct,
        after
            .stop_depth
            .map(|l| format!(", early stop at depth l = {l}"))
            .unwrap_or_default()
    );

    assert!(after.df <= prep.kind.tau() + 1e-9, "forgetting missed target");
    assert!(after.dr >= before.dr - 0.05, "retain accuracy collapsed");
    println!("quickstart OK");
    Ok(())
}
