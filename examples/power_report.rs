//! Table III — FPGA resource utilization and 45 nm power breakdown.
//!
//! The per-block constants are the paper's own Table III values (the
//! calibration of our power model — see DESIGN.md §2); this binary prints
//! the breakdown with derived shares and the aggregates the paper calls
//! out (Unlearning Engine share, specialized-IP share).
//!
//! Run: `cargo run --release --example power_report`

use ficabu::hwsim::PowerModel;

fn main() {
    let p = PowerModel::default();
    println!("=== Table III: FiCABU processor resources & power (45 nm) ===\n");
    println!("{:32} {:>8} {:>8} {:>10} {:>7}", "block", "LUTs", "FFs", "P [mW]", "share");
    println!("{}", "-".repeat(70));
    for r in &p.rows {
        println!(
            "{:32} {:>8} {:>8} {:>10.2} {:>6.2}%",
            r.name,
            r.luts,
            r.ffs,
            r.mw,
            100.0 * r.mw / p.total_mw()
        );
    }
    println!("{}", "-".repeat(70));
    println!(
        "{:32} {:>8} {:>8} {:>10.2}",
        "TOTAL",
        p.total_luts(),
        p.total_ffs(),
        p.total_mw()
    );
    println!();
    println!(
        "Unlearning Engine (VTA + IPs): {:.2} mW ({:.1}% of system)",
        p.unlearning_engine_mw(),
        100.0 * p.unlearning_engine_mw() / p.total_mw()
    );
    println!(
        "Specialized IPs (FIMD + Dampening): {:.2} mW ({:.2}% of system), {} LUTs ({:.1}%), {} FFs ({:.1}%)",
        p.block_mw("Specialized IPs"),
        100.0 * p.block_mw("Specialized IPs") / p.total_mw(),
        2_185,
        100.0 * 2_185.0 / p.total_luts() as f64,
        785,
        100.0 * 785.0 / p.total_ffs() as f64,
    );
    println!(
        "Baseline processor (no IPs): {:.2} mW",
        p.baseline_total_mw()
    );
    println!("\npaper: IPs add only 0.44% power / 3.1% LUTs while enabling the");
    println!("streaming pipeline that sustains GEMM-rate throughput.");
}
