//! Fig. 5c — patch-level GEMM -> FIMD -> DAMPENING streaming timeline.
//!
//! Schedules a short patch stream through the three-stage pipeline and
//! renders a Gantt view, demonstrating that the IP latencies hide inside
//! the GEMM patch window (the property that lets the processor sustain
//! GEMM-rate throughput, §IV-A).
//!
//! Run: `cargo run --release --example pipeline_trace`

use ficabu::hwsim::mem::Precision;
use ficabu::hwsim::FicabuProcessor;

fn main() {
    let proc_ = FicabuProcessor::new(8192, Precision::Int8);
    // one VTA patch window vs the IP work for that patch's outputs
    let per_patch = [64u64, 24, 16]; // GEMM, FIMD, DAMP cycles per patch
    let n = 6;
    let events = proc_.trace(n, per_patch);
    let horizon = events.iter().map(|e| e.3).max().unwrap();
    let scale = 72.0 / horizon as f64;
    let names = ["GEMM", "FIMD", "DAMP"];

    println!("=== Fig 5c: patch-level streaming pipeline ({n} patches) ===\n");
    for s in 0..3 {
        print!("{:5} ", names[s]);
        let mut line = vec![' '; 74];
        for &(st, p, b, e) in events.iter().filter(|ev| ev.0 == s) {
            let _ = st;
            let b = (b as f64 * scale) as usize;
            let e = ((e as f64 * scale) as usize).max(b + 1);
            let ch = char::from_digit(p as u32 % 10, 10).unwrap();
            for c in line.iter_mut().take(e.min(74)).skip(b) {
                *c = ch;
            }
        }
        println!("{}", line.iter().collect::<String>());
    }
    println!("\n(cycle horizon {horizon}; digits are patch ids)");

    // steady-state throughput check: cadence equals the GEMM window
    let gemm_events: Vec<_> = events.iter().filter(|e| e.0 == 0).collect();
    let cadence = gemm_events[1].2 - gemm_events[0].2;
    println!("steady-state cadence = {cadence} cycles = one GEMM patch window");
    println!("FIMD+DAMP latency per patch = {} cycles, hidden inside the window",
        per_patch[1] + per_patch[2]);
    assert_eq!(cadence, per_patch[0]);
    println!("pipeline trace OK");
}
