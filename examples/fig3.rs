//! Fig. 3 — layer-wise distribution of parameters selected by SSD.
//!
//! Runs an SSD pass per model and prints the selected-parameter count and
//! share per depth l (l = 1 at the classifier). The paper's observation —
//! selection concentrates toward the back-end — motivates both CAU and BD.
//!
//! Run: `cargo run --release --example fig3`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};

fn bar(frac: f64, width: usize) -> String {
    let n = (frac * width as f64).round() as usize;
    "#".repeat(n.min(width))
}

fn section(prep: &exp::Prepared, class: usize) -> anyhow::Result<()> {
    let ssd = exp::run_mode(prep, class, Mode::Ssd, None)?;
    let report = ssd.report.unwrap();
    let meta = &prep.model.meta;
    let total: u64 = report.selected_per_depth.iter().sum();
    println!(
        "--- {} / {} (class {class}, {total} selected of {} params) ---",
        meta.name,
        prep.kind.tag(),
        meta.total_params()
    );
    println!("l   segment   params   selected  share-of-layer");
    for (i, &sel) in report.selected_per_depth.iter().enumerate() {
        let l = i + 1;
        let k = meta.seg_index(l);
        let seg = &meta.segments[k];
        let frac_layer = sel as f64 / seg.param_count().max(1) as f64;
        println!(
            "{l:2}  {:8} {:8} {sel:9}  {:6.2}% {}",
            seg.name,
            seg.param_count(),
            100.0 * frac_layer,
            bar(frac_layer, 40)
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let opts = PrepareOpts::default();
    let rn = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts)?;
    section(&rn, 0)?;
    drop(rn);
    let opts_vit = PrepareOpts { train_steps: 400, lr: 0.15, ..opts };
    let vit = exp::prepare("vitslim", DatasetKind::Cifar20, &opts_vit)?;
    section(&vit, 0)?;
    println!("\npaper shape: selection share rises toward the back-end (small l).");
    Ok(())
}
