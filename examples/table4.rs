//! Table IV — end-to-end FiCABU processor evaluation, INT8 models.
//!
//! SSD runs on the simulated *baseline* processor (no specialized IPs:
//! Fisher/dampening serialized on the Rocket core at 11.7x/7.9x the IP
//! cycle cost); FiCABU (CAU + BD combined) runs on the simulated FiCABU
//! processor (streaming GEMM->FIMD->DAMP pipeline). Reported: Dr, Df,
//! editing MACs vs SSD, RPR, and energy savings ES.
//!
//! Run: `cargo run --release --example table4 [-- --avg-classes N]`

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::hwsim::mem::Precision;
use ficabu::metrics::rpr::rpr;
use ficabu::util::cli::Args;

fn section(kind: DatasetKind, avg_classes: usize, steps: usize) -> anyhow::Result<()> {
    let opts = PrepareOpts { train_steps: steps, int8: true, ..Default::default() };
    let prep = exp::prepare("rn18slim", kind, &opts)?;
    println!(
        "--- INT8 rn18slim / {} ({} classes averaged) ---",
        kind.tag(),
        avg_classes
    );
    let (mut b_dr, mut b_df) = (0.0, 0.0);
    let (mut s_dr, mut s_df) = (0.0, 0.0);
    let (mut f_dr, mut f_df, mut f_macs) = (0.0, 0.0, 0.0);
    let (mut e_fic_sum, mut e_ssd_sum) = (0.0, 0.0);
    for class in 0..avg_classes {
        let base = exp::run_mode(&prep, class, Mode::Baseline, None)?;
        let ssd = exp::run_mode(&prep, class, Mode::Ssd, None)?;
        let sel = ssd.report.as_ref().map(|r| r.selected_per_depth.clone());
        let fic = exp::run_mode(&prep, class, Mode::Ficabu, sel.as_deref())?;
        let (e_fic, e_ssd, _) = exp::tables::hardware_cost(
            &prep,
            fic.report.as_ref().unwrap(),
            ssd.report.as_ref().unwrap(),
            Precision::Int8,
        );
        b_dr += base.dr;
        b_df += base.df;
        s_dr += ssd.dr;
        s_df += ssd.df;
        f_dr += fic.dr;
        f_df += fic.df;
        f_macs += fic.macs_vs_ssd_pct;
        e_fic_sum += e_fic;
        e_ssd_sum += e_ssd;
    }
    let n = avg_classes as f64;
    let (b_dr, b_df) = (b_dr / n, b_df / n);
    let (s_dr, s_df) = (s_dr / n, s_df / n);
    let (f_dr, f_df, f_macs) = (f_dr / n, f_df / n, f_macs / n);
    let es = 1.0 - e_fic_sum / e_ssd_sum;
    println!("metric      Baseline     SSD      FiCABU");
    println!("Dr [%]       {:7.2}  {:7.2}  {:7.2}", 100.0 * b_dr, 100.0 * s_dr, 100.0 * f_dr);
    println!("Df [%]       {:7.2}  {:7.2}  {:7.2}", 100.0 * b_df, 100.0 * s_df, 100.0 * f_df);
    println!("MACs [%]           -   100.00  {:8.4}", f_macs);
    println!("RPR [%]            -        -  {:8.2}", rpr(b_dr, s_dr, f_dr));
    println!(
        "energy [mJ]        -  {:8.3} {:8.3}   ES {:6.2}%",
        e_ssd_sum / n,
        e_fic_sum / n,
        100.0 * es
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    args.declare(&["avg-classes", "steps"]);
    args.finish()?;
    let avg = args.usize_or("avg-classes", 4)?;
    let steps = args.usize_or("steps", 240)?;
    println!("=== Table IV: FiCABU processor, INT8 ResNet-18 ===\n");
    section(DatasetKind::Cifar20, avg, steps)?;
    println!();
    section(DatasetKind::PinsFace, avg, steps)?;
    println!("\npaper shape: random-guess Df, positive RPR, energy to ~6.5% (CIFAR-20)");
    println!("and ~0.13% (PinsFace) of the SSD-on-baseline cost.");
    Ok(())
}
