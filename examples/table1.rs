//! Table I — Context-Adaptive Unlearning vs baseline and SSD.
//!
//! (a) CIFAR-20-like: RN + ViT, two named classes (analogues of Rocket and
//!     Mushroom) and the average over further classes.
//! (b) PinsFace-like: RN, class average.
//!
//! Metrics per cell: Dr, Df, MIA (percent) and editing MACs relative to
//! SSD (= 100), including checkpoint overhead.
//!
//! Run: `cargo run --release --example table1 [-- --avg-classes N]`

use ficabu::exp::{self, ClassResult, DatasetKind, Mode, PrepareOpts};
use ficabu::util::cli::Args;

fn cell(r: &ClassResult) -> String {
    format!(
        "Dr {:6.2}  Df {:6.2}  MIA {:6.2}  MACs {:8.3}",
        100.0 * r.dr,
        100.0 * r.df,
        100.0 * r.mia,
        if r.mode == Mode::Baseline { f64::NAN } else { r.macs_vs_ssd_pct }
    )
}

fn mean(rs: &[ClassResult]) -> ClassResult {
    let n = rs.len() as f64;
    let mut out = rs[0].clone();
    out.dr = rs.iter().map(|r| r.dr).sum::<f64>() / n;
    out.df = rs.iter().map(|r| r.df).sum::<f64>() / n;
    out.mia = rs.iter().map(|r| r.mia).sum::<f64>() / n;
    out.macs_vs_ssd_pct = rs.iter().map(|r| r.macs_vs_ssd_pct).sum::<f64>() / n;
    out
}

fn section(
    prep: &exp::Prepared,
    named: &[(usize, &str)],
    avg_classes: usize,
) -> anyhow::Result<()> {
    println!(
        "--- {} / {} (alpha,lambda = {:?}, tau = {:.0}%) ---",
        prep.model.meta.name,
        prep.kind.tag(),
        prep.kind.ssd_params(&prep.model.meta.name),
        100.0 * prep.kind.tau()
    );
    for &(class, label) in named {
        for mode in [Mode::Baseline, Mode::Ssd, Mode::Cau] {
            let r = exp::run_mode(prep, class, mode, None)?;
            println!("{label:8} {:8} {}", mode.name(), cell(&r));
        }
    }
    // average over the remaining classes
    let classes: Vec<usize> = (named.len()..named.len() + avg_classes).collect();
    for mode in [Mode::Baseline, Mode::Ssd, Mode::Cau] {
        let rs: Vec<ClassResult> = classes
            .iter()
            .map(|&c| exp::run_mode(prep, c, mode, None))
            .collect::<anyhow::Result<_>>()?;
        println!("{:8} {:8} {}", format!("Avg({avg_classes})"), mode.name(), cell(&mean(&rs)));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    args.declare(&["avg-classes", "steps"]);
    args.finish()?;
    let avg_classes = args.usize_or("avg-classes", 4)?;
    let opts = PrepareOpts { train_steps: args.usize_or("steps", 240)?, ..Default::default() };

    println!("=== Table I(a): CIFAR-20-like ===");
    let named = [(0usize, "Rocket*"), (1usize, "MR*")];
    let rn = exp::prepare("rn18slim", DatasetKind::Cifar20, &opts)?;
    section(&rn, &named, avg_classes)?;
    drop(rn);
    let opts_vit = PrepareOpts { train_steps: 400, lr: 0.15, ..opts.clone() };
    let vit = exp::prepare("vitslim", DatasetKind::Cifar20, &opts_vit)?;
    section(&vit, &named, avg_classes)?;
    drop(vit);

    println!("\n=== Table I(b): PinsFace-like ===");
    let pins = exp::prepare("rn18slim", DatasetKind::PinsFace, &opts)?;
    section(&pins, &[], avg_classes.max(2))?;

    println!("\npaper shape: Df -> random guess; Dr within ~1pt of SSD;");
    println!("CAU editing MACs << 100 with PinsFace <= CIFAR-20.");
    Ok(())
}
