//! API stub for the `xla` (PJRT) bindings.
//!
//! The FiCABU workspace builds fully offline; the real `xla` crate links
//! `xla_extension`, which no plain toolchain or CI runner can satisfy.
//! This stub carries just enough of the crate's API surface for the
//! feature-gated `ficabu::runtime::xla` backend to *compile* — every
//! entry point returns [`Error::Unavailable`] at runtime with a message
//! explaining how to enable real PJRT execution (vendor the real `xla`
//! crate and point the workspace `[patch]` / path dependency at it).
//!
//! Keeping the backend compiling (rather than `cfg`-ing it out of
//! existence) means `cargo check --features backend-xla` exercises the
//! conversion and caching code in CI even where PJRT itself cannot run.

use std::fmt;
use std::path::Path;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub is linked instead of the real bindings.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla bindings unavailable: this build links the offline API stub; \
             vendor the real `xla` crate (see README) to enable PJRT execution"
        )
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Element types of array literals (subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side literal value.
#[derive(Debug, Clone)]
pub struct Literal {}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        ElementType::F32
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal {}
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

/// Parsed HLO module (text form).
#[derive(Debug, Clone)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Compiled executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo.txt").is_err());
    }
}
