//! Offline stand-in for the `anyhow` crate.
//!
//! The repo builds with zero network access, so instead of a registry
//! dependency this path crate provides the small `anyhow` surface the
//! workspace actually uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result` and `Option`, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error chains render like anyhow's: `{}` prints the outermost
//! context, `{:#}` the full `outer: ...: root` chain, `{:?}` a
//! "Caused by" listing.
//!
//! If the real `anyhow` ever becomes available in the build environment,
//! swapping the `[dependencies]` entry back to the registry version is a
//! drop-in change — no source edits needed.

use std::fmt;

/// A context-carrying error. Deliberately does **not** implement
/// `std::error::Error` (mirroring anyhow) so the blanket `From` below
/// cannot overlap with the reflexive `From<Error> for Error`.
pub struct Error {
    /// Outermost context first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional layer of context (new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        // qualified: the crate-root `Ok` helper shadows the prelude here
        std::result::Result::Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Equivalent of `Ok(value)` with the error type pinned to
/// [`Error`] — mirrors `anyhow::Ok`, which makes `?`-using doc tests
/// and closures inferable without a turbofish.
#[allow(non_snake_case)]
pub fn Ok<T>(t: T) -> Result<T> {
    Result::Ok(t)
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false (anyhow's
/// `ensure!`, minus its fancy condition decomposition).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("loading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e:#}"), "empty");
        assert_eq!(Some(3).context("never").unwrap(), 3);
    }

    #[test]
    fn with_context_on_error_result() {
        // `.with_context` on a Result<_, Error> relayers the chain
        let base: Result<()> = Err(anyhow!("root {}", 42));
        let e = base.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert_eq!(e.root_cause(), "root 42");
    }

    #[test]
    fn ok_helper_pins_the_error_type() {
        fn f() -> Result<u32> {
            let v = crate::Ok(41)?;
            crate::Ok(v + 1)
        }
        assert_eq!(f().unwrap(), 42);
    }

    #[test]
    fn bail_macro() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{:#}", f(-2).unwrap_err()), "negative input -2");
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0);
            ensure!(x % 2 == 0, "odd input {x}");
            Ok(x)
        }
        assert_eq!(f(4).unwrap(), 4);
        assert!(format!("{:#}", f(-2).unwrap_err()).contains("x >= 0"));
        assert_eq!(format!("{:#}", f(3).unwrap_err()), "odd input 3");
    }
}
