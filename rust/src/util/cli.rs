//! Tiny CLI argument parser (no `clap` in the offline vendor tree).
//!
//! Grammar: `ficabu <command> [--flag] [--key value]...`. Unknown keys are
//! an error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> anyhow::Result<Args> {
        let mut it = argv.into_iter();
        let mut out = Args::default();
        out.command = it.next().unwrap_or_else(|| "help".to_string());
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --key, got `{a}`"))?
                .to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                out.kv.insert(key, rest[i + 1].clone());
                i += 2;
            } else {
                out.flags.push(key);
                i += 1;
            }
        }
        Ok(out)
    }

    /// Declare a key as known (for validation via [`Args::finish`]).
    pub fn declare(&mut self, keys: &[&str]) -> &mut Self {
        self.known.extend(keys.iter().map(|s| s.to_string()));
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: expected number, got `{v}`")),
        }
    }

    /// Error on any key/flag that was never declared.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !self.known.iter().any(|n| n == k) {
                anyhow::bail!(
                    "unknown option --{k} for `{}` (known: {})",
                    self.command,
                    self.known.join(", ")
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let mut a = Args::parse(argv("train --model rn18slim --steps 100 --verbose")).unwrap();
        a.declare(&["model", "steps", "verbose"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.get("model"), Some("rn18slim"));
        assert_eq!(a.usize_or("steps", 1).unwrap(), 100);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv("unlearn")).unwrap();
        assert_eq!(a.str_or("model", "rn18slim"), "rn18slim");
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.f64_or("alpha", 10.0).unwrap(), 10.0);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut a = Args::parse(argv("train --oops 1")).unwrap();
        a.declare(&["model"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number() {
        let a = Args::parse(argv("x --steps abc")).unwrap();
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(argv("cmd stray")).is_err());
    }
}
