//! Offline substrates: JSON, PRNG, CLI (no serde/rand/clap in the vendor
//! tree — see DESIGN.md §5).

pub mod cli;
pub mod json;
pub mod prng;
