//! Lazy JSON path scanning: pull one field out of a document without
//! building a tree.
//!
//! The HTTP admission path needs exactly two fields (`spec`,
//! `deadline_ms`) out of each request body. [`Json::parse`] would
//! allocate a `String`/`Vec` per node of the whole document first;
//! [`path`] instead walks the bytes, comparing keys in place and
//! *skipping* every value that is not on the requested path (strings are
//! framed without unescaping, containers without materializing), then
//! returns the raw text span of the target. Only that fragment is ever
//! parsed — the miniserde + lazy-scan split of ADR-002, where partial
//! field extraction is an order of magnitude cheaper than tree building
//! (`serve/http-loopback/parse-*` in `bench_serve` measures ours).
//!
//! The laziness is a real trade: bytes *after* the target are never
//! inspected, so a structurally broken sibling behind it goes unnoticed.
//! Errors on the traversed prefix carry the document byte offset and
//! context like the full parser's.

use super::{Json, JsonError, MAX_DEPTH};

/// A value located by [`path`]: the raw JSON text of the value plus its
/// byte offset in the scanned document.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Raw<'a> {
    text: &'a str,
    offset: usize,
}

impl<'a> Raw<'a> {
    /// The value's raw JSON text (e.g. `"class:3"` including quotes, or
    /// `{"classes":[1,4]}`).
    pub fn text(&self) -> &'a str {
        self.text
    }

    /// Byte offset of the value within the scanned document.
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Parse just this fragment into a [`Json`] tree. Error offsets are
    /// rebased onto the enclosing document.
    pub fn parse(&self) -> Result<Json, JsonError> {
        Json::parse(self.text).map_err(|mut e| {
            e.pos += self.offset;
            e
        })
    }

    /// The fragment as a number, if it is a JSON number literal.
    pub fn as_f64(&self) -> Option<f64> {
        let first = self.text.bytes().next()?;
        if first == b'-' || first.is_ascii_digit() {
            self.text.parse().ok()
        } else {
            None
        }
    }

    /// The fragment as an exact integer (plain integer literals only —
    /// `3.0`/`4e2` are rejected, matching [`Json::as_i64`]'s intent).
    pub fn as_i64(&self) -> Option<i64> {
        self.text.parse().ok()
    }

    /// The fragment as an unescaped string, if it is a JSON string
    /// (`None` for other value kinds or invalid escapes).
    pub fn as_str(&self) -> Option<String> {
        if !self.text.starts_with('"') {
            return None;
        }
        match self.parse().ok()? {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Scan `src` for the value at `keys` (object keys, outermost first)
/// without building a tree. `Ok(None)` when any key on the path is
/// absent; an error only if the bytes the scan had to traverse are
/// malformed.
pub fn path<'a>(src: &'a str, keys: &[&str]) -> Result<Option<Raw<'a>>, JsonError> {
    let mut s = Skip { b: src.as_bytes(), pos: 0 };
    for k in keys {
        if s.find(k)?.is_none() {
            return Ok(None);
        }
    }
    s.ws();
    let start = s.pos;
    s.value()?;
    // value boundaries are always ASCII token edges, so byte slicing
    // the source str cannot split a UTF-8 character
    Ok(Some(Raw { text: &src[start..s.pos], offset: start }))
}

/// [`path`] + number read; an error (with offset) if the field exists
/// but is not a number.
pub fn path_f64(src: &str, keys: &[&str]) -> Result<Option<f64>, JsonError> {
    match path(src, keys)? {
        None => Ok(None),
        Some(raw) => raw.as_f64().map(Some).ok_or_else(|| {
            JsonError::at(
                raw.offset(),
                format!("`{}` is not a number", keys.join(".")),
                src.as_bytes(),
            )
        }),
    }
}

/// [`path`] + string read; an error (with offset) if the field exists
/// but is not a string.
pub fn path_str(src: &str, keys: &[&str]) -> Result<Option<String>, JsonError> {
    match path(src, keys)? {
        None => Ok(None),
        Some(raw) => raw.as_str().map(Some).ok_or_else(|| {
            JsonError::at(
                raw.offset(),
                format!("`{}` is not a string", keys.join(".")),
                src.as_bytes(),
            )
        }),
    }
}

/// Byte walker that frames values without materializing them.
struct Skip<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Skip<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::at(self.pos, msg, self.b)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    /// Enter the object at the cursor and position on the value of
    /// `key`; `Ok(None)` if the key is absent (cursor then past the
    /// object). Keys are compared on raw bytes — escaped keys never
    /// match, which is fine for our plain-ASCII wire contracts.
    fn find(&mut self, key: &str) -> Result<Option<()>, JsonError> {
        self.ws();
        self.expect(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(None);
        }
        loop {
            self.ws();
            let kstart = self.pos;
            self.string()?;
            let raw_key = &self.b[kstart + 1..self.pos - 1];
            self.ws();
            self.expect(b':')?;
            self.ws();
            if raw_key == key.as_bytes() {
                return Ok(Some(()));
            }
            self.value()?;
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(None),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    /// Skip one complete value of any kind.
    fn value(&mut self) -> Result<(), JsonError> {
        self.ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'"' => self.string(),
            b'{' | b'[' => self.container(),
            b't' => self.lit("true"),
            b'f' => self.lit("false"),
            b'n' => self.lit("null"),
            b'-' | b'0'..=b'9' => {
                self.pos += 1;
                while matches!(
                    self.peek(),
                    Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                ) {
                    self.pos += 1;
                }
                Ok(())
            }
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    /// Skip a string: only framing matters, so an escape skips exactly
    /// one byte (the byte after `\` is never a bare `"`).
    fn string(&mut self) -> Result<(), JsonError> {
        self.expect(b'"')?;
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(()),
                b'\\' => {
                    self.bump().ok_or_else(|| self.err("eof in escape"))?;
                }
                _ => {}
            }
        }
    }

    /// Skip the `{...}` / `[...]` container at the cursor. Iterative —
    /// an explicit stack of expected closers, not recursion, so hostile
    /// nesting (`[{[{...` one level per two body bytes) cannot overflow
    /// the thread stack; past [`MAX_DEPTH`] it is an error. Strings
    /// inside are framed properly so braces in text don't miscount.
    fn container(&mut self) -> Result<(), JsonError> {
        let mut closers = Vec::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in container"))? {
                b'"' => self.string()?,
                c @ (b'{' | b'[') => {
                    if closers.len() >= MAX_DEPTH {
                        return Err(self.err(&format!("nesting deeper than {MAX_DEPTH}")));
                    }
                    closers.push(if c == b'{' { b'}' } else { b']' });
                    self.pos += 1;
                }
                c @ (b'}' | b']') => {
                    if closers.pop() != Some(c) {
                        return Err(self.err(&format!("mismatched `{}`", c as char)));
                    }
                    self.pos += 1;
                    if closers.is_empty() {
                        return Ok(());
                    }
                }
                _ => self.pos += 1,
            }
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BODY: &str = r#"{
        "client": {"id": "edge-17", "note": "b}r[ace \" soup"},
        "tags": [1, [2, {"x": "}"}], 3],
        "spec": "classes:4,1",
        "deadline_ms": 250,
        "nested": {"deep": {"leaf": true}}
    }"#;

    #[test]
    fn scans_top_level_fields_past_decoys() {
        assert_eq!(path_str(BODY, &["spec"]).unwrap().as_deref(), Some("classes:4,1"));
        assert_eq!(path_f64(BODY, &["deadline_ms"]).unwrap(), Some(250.0));
    }

    #[test]
    fn scans_nested_paths() {
        let raw = path(BODY, &["nested", "deep", "leaf"]).unwrap().unwrap();
        assert_eq!(raw.text(), "true");
        assert_eq!(path(BODY, &["client", "id"]).unwrap().unwrap().as_str().unwrap(), "edge-17");
    }

    #[test]
    fn absent_keys_are_none_not_errors() {
        assert_eq!(path(BODY, &["missing"]).unwrap(), None);
        assert_eq!(path(BODY, &["nested", "missing"]).unwrap(), None);
        assert_eq!(path("{}", &["spec"]).unwrap(), None);
    }

    #[test]
    fn raw_fragment_parses_with_document_offsets() {
        let raw = path(BODY, &["tags"]).unwrap().unwrap();
        let j = raw.parse().unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 3);
        // offsets point into the original document
        assert_eq!(&BODY[raw.offset()..raw.offset() + 1], "[");
    }

    #[test]
    fn object_valued_target() {
        let raw = path(r#"{"spec": {"classes": [4, 1]}}"#, &["spec"]).unwrap().unwrap();
        assert_eq!(raw.text(), r#"{"classes": [4, 1]}"#);
        assert!(raw.as_str().is_none());
        assert_eq!(raw.parse().unwrap().get("classes").unwrap().usize_list().unwrap(), vec![4, 1]);
    }

    #[test]
    fn escaped_strings_frame_correctly() {
        let src = r#"{"a": "quote \" and brace } inside", "b": 7}"#;
        assert_eq!(path_f64(src, &["b"]).unwrap(), Some(7.0));
        assert_eq!(path_str(src, &["a"]).unwrap().unwrap(), "quote \" and brace } inside");
    }

    #[test]
    fn type_mismatch_errors_carry_offsets() {
        let src = r#"{"deadline_ms": "soon"}"#;
        let e = path_f64(src, &["deadline_ms"]).unwrap_err();
        assert_eq!(e.pos, 16);
        assert!(e.msg.contains("deadline_ms"));
        let e = path_str(src, &["deadline_ms"]).unwrap();
        assert_eq!(e, Some("soon".to_string()));
    }

    #[test]
    fn malformed_prefix_errors_offset() {
        let e = path(r#"{"a": nope, "spec": 1}"#, &["spec"]).unwrap_err();
        assert_eq!(e.pos, 6);
        assert!(!e.context.is_empty());
        assert!(path("[1,2]", &["spec"]).is_err(), "top level must be an object");
        assert!(path(r#"{"spec""#, &["spec"]).is_err());
    }

    #[test]
    fn hostile_nesting_is_an_error_not_a_stack_overflow() {
        // one container level per ~6 bytes, scaled to the 64 KiB default
        // body cap: skipping this must error, not abort the process
        let deep = format!(r#"{{"a": {}null, "spec": 1}}"#, r#"[{"x":"#.repeat(16 * 1024));
        let e = path(&deep, &["spec"]).unwrap_err();
        assert!(e.msg.contains("nesting"), "{}", e.msg);
        // within the cap, deep-but-sane nesting still skips fine
        let ok = format!(r#"{{"a": {}1{}, "spec": 7}}"#, "[".repeat(100), "]".repeat(100));
        assert_eq!(path_f64(&ok, &["spec"]).unwrap(), Some(7.0));
    }

    #[test]
    fn mismatched_brackets_error_while_skipping() {
        assert!(path(r#"{"a": [1, 2}, "spec": 1}"#, &["spec"]).is_err());
    }

    #[test]
    fn bytes_after_the_target_are_not_inspected() {
        // lazy trade: garbage behind the target goes unnoticed
        let src = r#"{"spec": "class:3", "broken": nope}"#;
        assert_eq!(path_str(src, &["spec"]).unwrap().as_deref(), Some("class:3"));
    }

    #[test]
    fn agrees_with_the_tree_parser() {
        let j = Json::parse(BODY).unwrap();
        assert_eq!(
            path_str(BODY, &["spec"]).unwrap().as_deref(),
            j.get("spec").and_then(|v| v.as_str())
        );
        assert_eq!(
            path_f64(BODY, &["deadline_ms"]).unwrap(),
            j.get("deadline_ms").and_then(|v| v.as_f64())
        );
    }
}
