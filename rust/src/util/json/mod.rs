//! Minimal JSON parser/emitter.
//!
//! The offline vendor tree carries no `serde`, so the `meta.json` /
//! `shared.json` interchange (and run reports) use this hand-rolled
//! implementation. It supports the full JSON grammar minus `\u` surrogate
//! pairs (sufficient for our ASCII artifacts) and preserves object key
//! order, which keeps emitted reports diffable.
//!
//! [`scan`] adds a lazy path-scanning layer (miniserde/ADR-002 style):
//! extracting one or two fields from a request body skips over every
//! other value byte-by-byte instead of building a tree, which is what
//! the HTTP admission path uses.

pub mod scan;

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved via insertion-ordered Vec; `BTreeMap` index for
    /// O(log n) lookup would be overkill at our sizes.
    Obj(Vec<(String, Json)>),
}

/// Parse/scan failure: the byte offset where the input stopped making
/// sense, plus a short snippet of the surrounding bytes so wire-facing
/// 400 bodies can say *where* a request was malformed.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
    /// Up to [`CONTEXT_BYTES`] of input around `pos` (lossy UTF-8).
    pub context: String,
}

/// Bytes of input quoted around the error position in
/// [`JsonError::context`].
pub const CONTEXT_BYTES: usize = 24;

/// Maximum container nesting accepted by [`Json::parse`] and the
/// [`scan`] skipper. Attacker-controlled request bodies can nest one
/// level per two bytes (`[{[{...`), and unbounded recursion turns that
/// into a stack overflow — which aborts the whole process, not just a
/// thread. 128 is far beyond any real wire payload of ours.
pub const MAX_DEPTH: usize = 128;

impl JsonError {
    /// Build an error at `pos`, quoting the surrounding input.
    pub fn at(pos: usize, msg: impl Into<String>, src: &[u8]) -> JsonError {
        let lo = pos.saturating_sub(CONTEXT_BYTES / 2);
        let hi = (pos + CONTEXT_BYTES / 2).min(src.len());
        let context = String::from_utf8_lossy(&src[lo.min(src.len())..hi]).into_owned();
        JsonError { pos, msg: msg.into(), context }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.context.is_empty() {
            write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
        } else {
            write!(
                f,
                "json parse error at byte {}: {} (near `{}`)",
                self.pos, self.msg, self.context
            )
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Integer read: `Some` only for numbers that are exactly an `i64`
    /// (no fractional part, in range) — the wire layer must not silently
    /// truncate `3.7` or `1e20` into an index.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            // `i64::MAX as f64` rounds up to 2^63, so the upper bound is
            // exclusive; `i64::MIN as f64` is exactly -2^63.
            Json::Num(n) if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n < i64::MAX as f64 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: `obj.req("key")?` with a contextual error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn usize_list(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    /// Build an object from pairs (emission helper).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// String constructor (emission helper). The writer escapes quotes,
    /// backslashes, and every control character, so arbitrary text —
    /// error messages quoting raw request bytes included — round-trips.
    pub fn string(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::at(self.pos, msg, self.b)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    /// `depth` counts enclosing containers; recursion is bounded by
    /// [`MAX_DEPTH`] so hostile nesting errors instead of blowing the
    /// thread stack (fatal: overflow aborts the process).
    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.skip_ws();
        if depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH}")));
        }
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(depth),
            b'{' => self.object(depth),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c => {
                    // Re-decode UTF-8 multibyte sequences byte-at-a-time.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            out.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Lookup helper retained for potential large-object use; documents the
/// trade-off against the ordered-Vec representation above.
pub type JsonIndex = BTreeMap<String, Json>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"rn18slim","batch":64,"xs":[1,2.5,-3],"ok":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // within the cap: parses fine
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // a 64 KiB-body-sized hostile nest must be a JsonError, not a
        // stack overflow (which would abort the process)
        let hostile = "[{\"a\":".repeat(8 * 1024);
        let e = Json::parse(&hostile).unwrap_err();
        assert!(e.msg.contains("nesting"), "{}", e.msg);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".to_string())
        );
        // raw UTF-8 passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn usize_list() {
        let j = Json::parse("[32, 32, 3]").unwrap();
        assert_eq!(j.usize_list().unwrap(), vec![32, 32, 3]);
    }

    #[test]
    fn bool_and_i64_getters() {
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Num(1.0).as_bool(), None);
        assert_eq!(Json::Num(42.0).as_i64(), Some(42));
        assert_eq!(Json::Num(-7.0).as_i64(), Some(-7));
        // fractional and out-of-range numbers are not integers
        assert_eq!(Json::Num(3.7).as_i64(), None);
        assert_eq!(Json::Num(1e20).as_i64(), None);
        assert_eq!(Json::Num(f64::NAN).as_i64(), None);
        assert_eq!(Json::Str("5".into()).as_i64(), None);
    }

    #[test]
    fn string_constructor_escapes_control_chars_on_write() {
        let j = Json::string("tab\there \"quoted\" \\ nl\n bell\u{7} nul\u{0}");
        let emitted = j.to_string();
        assert_eq!(
            emitted,
            "\"tab\\there \\\"quoted\\\" \\\\ nl\\n bell\\u0007 nul\\u0000\""
        );
        // escape-correct: the emitted text parses back to the same value
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn errors_carry_offset_and_context() {
        let e = Json::parse(r#"{"spec": bogus}"#).unwrap_err();
        assert_eq!(e.pos, 9);
        assert!(e.context.contains("bogus"), "context = {:?}", e.context);
        let shown = e.to_string();
        assert!(shown.contains("byte 9") && shown.contains("bogus"), "{shown}");
    }
}
