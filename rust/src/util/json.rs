//! Minimal JSON parser/emitter.
//!
//! The offline vendor tree carries no `serde`, so the `meta.json` /
//! `shared.json` interchange (and run reports) use this hand-rolled
//! implementation. It supports the full JSON grammar minus `\u` surrogate
//! pairs (sufficient for our ASCII artifacts) and preserves object key
//! order, which keeps emitted reports diffable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved via insertion-ordered Vec; `BTreeMap` index for
    /// O(log n) lookup would be overkill at our sizes.
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: `obj.req("key")?` with a contextual error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn usize_list(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected number"))
            })
            .collect()
    }

    /// Build an object from pairs (emission helper).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte `{}`", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c => {
                    // Re-decode UTF-8 multibyte sequences byte-at-a-time.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.push((k, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Lookup helper retained for potential large-object use; documents the
/// trade-off against the ordered-Vec representation above.
pub type JsonIndex = BTreeMap<String, Json>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"rn18slim","batch":64,"xs":[1,2.5,-3],"ok":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let emitted = j.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".to_string())
        );
        // raw UTF-8 passthrough
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn usize_list() {
        let j = Json::parse("[32, 32, 3]").unwrap();
        assert_eq!(j.usize_list().unwrap(), vec![32, 32, 3]);
    }
}
