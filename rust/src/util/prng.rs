//! Deterministic PRNG (PCG-XSH-RR 64/32) + sampling helpers.
//!
//! No `rand` crate in the offline vendor tree; everything that needs
//! randomness (data synthesis, parameter init, MIA shadow splits, property
//! tests) goes through this so runs are reproducible from a single seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in [0, n) via Lemire rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u32;
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n.wrapping_neg() % n {
                return (m >> 32) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; cache omitted
    /// for simplicity — generation is never on the hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Pcg32::seeded(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Pcg32::seeded(13);
        let picks = r.choose_k(100, 10);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }
}
