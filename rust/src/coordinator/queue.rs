//! Request-queue statistics for the edge serving loop.

#[derive(Debug, Clone, Copy, Default)]
pub struct Timing {
    pub queue_ms: f64,
    pub service_ms: f64,
}

#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    pub served: u64,
    pub failures: u64,
    pub total_queue_ms: f64,
    pub total_service_ms: f64,
    pub max_queue_ms: f64,
    pub max_service_ms: f64,
}

impl QueueStats {
    pub fn record(&mut self, t: &Timing) {
        self.served += 1;
        self.total_queue_ms += t.queue_ms;
        self.total_service_ms += t.service_ms;
        if t.queue_ms > self.max_queue_ms {
            self.max_queue_ms = t.queue_ms;
        }
        if t.service_ms > self.max_service_ms {
            self.max_service_ms = t.service_ms;
        }
    }

    pub fn mean_queue_ms(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_queue_ms / self.served as f64
        }
    }

    pub fn mean_service_ms(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.total_service_ms / self.served as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut s = QueueStats::default();
        s.record(&Timing { queue_ms: 2.0, service_ms: 10.0 });
        s.record(&Timing { queue_ms: 4.0, service_ms: 30.0 });
        assert_eq!(s.served, 2);
        assert_eq!(s.mean_queue_ms(), 3.0);
        assert_eq!(s.mean_service_ms(), 20.0);
        assert_eq!(s.max_service_ms, 30.0);
    }

    #[test]
    fn empty_stats_zero() {
        let s = QueueStats::default();
        assert_eq!(s.mean_queue_ms(), 0.0);
        assert_eq!(s.mean_service_ms(), 0.0);
    }
}
