//! Request-queue statistics for the serving fleet.
//!
//! Each worker owns one [`QueueStats`]; the dispatcher rolls them up with
//! [`QueueStats::merge`] when a stats probe or shutdown snapshot asks for
//! the fleet-wide view. Latency distributions are tracked in power-of-two
//! [`LatencyHistogram`] buckets so p50/p95/p99 survive the merge without
//! storing per-request samples.
//!
//! [`QueueStats::percentile_fields`] is the single naming authority for
//! the percentile readout: `bench_serve` arms and the HTTP `GET /stats`
//! body both emit exactly these names.

use crate::util::json::Json;

/// Queue + service latency of one completed request (milliseconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct Timing {
    pub queue_ms: f64,
    pub service_ms: f64,
}

/// Number of power-of-two latency buckets: bucket 0 is `[0, 1)` ms,
/// bucket `b >= 1` is `[2^(b-1), 2^b)` ms, and the last bucket absorbs
/// everything above `2^26` ms (~18 hours), `+inf` included.
pub const HIST_BUCKETS: usize = 28;

/// Fixed-size log2 latency histogram (milliseconds).
///
/// Quantiles are read back as the *upper edge* of the bucket holding
/// the requested rank: at most 2x above the true value for latencies
/// >= 1 ms, floored at 1.0 ms below that (sub-millisecond latencies all
/// share bucket 0) — the usual trade for a mergeable constant-size
/// histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; HIST_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; HIST_BUCKETS] }
    }
}

impl LatencyHistogram {
    fn bucket(ms: f64) -> usize {
        if ms.is_nan() || ms <= 0.0 {
            return 0;
        }
        if ms.is_infinite() {
            return HIST_BUCKETS - 1;
        }
        let mut b = 0usize;
        let mut upper = 1.0f64;
        while ms >= upper && b < HIST_BUCKETS - 1 {
            upper *= 2.0;
            b += 1;
        }
        b
    }

    /// Upper edge of bucket `b` in ms.
    fn upper_edge(b: usize) -> f64 {
        let mut upper = 1.0f64;
        for _ in 0..b {
            upper *= 2.0;
        }
        upper
    }

    pub fn record(&mut self, ms: f64) {
        self.counts[Self::bucket(ms)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Latency at quantile `q` in `[0, 1]` (upper bucket edge; 0.0 when
    /// the histogram is empty).
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        // rank of the requested quantile, 1-based, at least 1
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::upper_edge(b);
            }
        }
        Self::upper_edge(HIST_BUCKETS - 1)
    }

    pub fn p50_ms(&self) -> f64 {
        self.quantile_ms(0.50)
    }

    pub fn p95_ms(&self) -> f64 {
        self.quantile_ms(0.95)
    }

    pub fn p99_ms(&self) -> f64 {
        self.quantile_ms(0.99)
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }
}

/// Per-worker serving statistics.
///
/// Failed requests contribute to the timing aggregates exactly like
/// successful ones (they occupied the queue and the engine just the
/// same); only deadline sheds stay out of the latency accounting, since
/// they were never serviced.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    /// Requests serviced to a successful reply.
    pub served: u64,
    /// Requests serviced to an error reply.
    pub failures: u64,
    /// Requests shed at claim time because their deadline had passed.
    pub shed_deadline: u64,
    /// Requests whose execution panicked (each also counts as a
    /// failure; the panic is caught, the reply is `Failed`, and the
    /// worker replica is respawned).
    pub panics: u64,
    /// Times this worker's replica was successfully rebuilt after a
    /// panic.
    pub respawns: u64,
    /// Worker passes (one pass services a claimed batch).
    pub batches: u64,
    /// Largest batch claimed in one pass.
    pub max_batch: u64,
    pub total_queue_ms: f64,
    pub total_service_ms: f64,
    pub max_queue_ms: f64,
    pub max_service_ms: f64,
    pub queue_hist: LatencyHistogram,
    pub service_hist: LatencyHistogram,
}

impl QueueStats {
    /// Record one serviced request. `ok = false` counts a failure, but
    /// the timing still enters every aggregate: an errored request held
    /// the engine for its full service time.
    pub fn record(&mut self, t: &Timing, ok: bool) {
        if ok {
            self.served += 1;
        } else {
            self.failures += 1;
        }
        self.total_queue_ms += t.queue_ms;
        self.total_service_ms += t.service_ms;
        if t.queue_ms > self.max_queue_ms {
            self.max_queue_ms = t.queue_ms;
        }
        if t.service_ms > self.max_service_ms {
            self.max_service_ms = t.service_ms;
        }
        self.queue_hist.record(t.queue_ms);
        self.service_hist.record(t.service_ms);
    }

    /// Record one batch claim of `n` requests.
    pub fn record_batch(&mut self, n: usize) {
        self.batches += 1;
        if n as u64 > self.max_batch {
            self.max_batch = n as u64;
        }
    }

    /// Record a request shed at claim time (deadline already missed).
    pub fn record_shed(&mut self) {
        self.shed_deadline += 1;
    }

    /// Requests that reached the engine (successes + failures).
    pub fn completed(&self) -> u64 {
        self.served + self.failures
    }

    pub fn mean_queue_ms(&self) -> f64 {
        if self.completed() == 0 {
            0.0
        } else {
            self.total_queue_ms / self.completed() as f64
        }
    }

    pub fn mean_service_ms(&self) -> f64 {
        if self.completed() == 0 {
            0.0
        } else {
            self.total_service_ms / self.completed() as f64
        }
    }

    /// The percentile readout under its wire names — the exact fields
    /// `bench_serve` records per arm and `GET /stats` serves, so the
    /// bench artifact and the HTTP surface cannot drift apart.
    pub fn percentile_fields(&self) -> [(&'static str, f64); 4] {
        [
            ("queue_p50_ms", self.queue_hist.p50_ms()),
            ("queue_p99_ms", self.queue_hist.p99_ms()),
            ("service_p50_ms", self.service_hist.p50_ms()),
            ("service_p99_ms", self.service_hist.p99_ms()),
        ]
    }

    /// Wire form of this stats block (counters, means, maxima, and the
    /// [`QueueStats::percentile_fields`] readout).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("served", Json::from(self.served as usize)),
            ("failures", Json::from(self.failures as usize)),
            ("shed_deadline", Json::from(self.shed_deadline as usize)),
            ("panics", Json::from(self.panics as usize)),
            ("respawns", Json::from(self.respawns as usize)),
            ("batches", Json::from(self.batches as usize)),
            ("max_batch", Json::from(self.max_batch as usize)),
            ("mean_queue_ms", Json::from(self.mean_queue_ms())),
            ("mean_service_ms", Json::from(self.mean_service_ms())),
            ("max_queue_ms", Json::from(self.max_queue_ms)),
            ("max_service_ms", Json::from(self.max_service_ms)),
        ];
        for (k, v) in self.percentile_fields() {
            fields.push((k, Json::from(v)));
        }
        Json::obj(fields)
    }

    /// Fold `other` into `self` — the per-worker -> fleet rollup. Counts
    /// and totals add, maxima take the max, histograms add bucketwise, so
    /// merged quantiles are exact over the union of the inputs.
    pub fn merge(&mut self, other: &QueueStats) {
        self.served += other.served;
        self.failures += other.failures;
        self.shed_deadline += other.shed_deadline;
        self.panics += other.panics;
        self.respawns += other.respawns;
        self.batches += other.batches;
        self.max_batch = self.max_batch.max(other.max_batch);
        self.total_queue_ms += other.total_queue_ms;
        self.total_service_ms += other.total_service_ms;
        if other.max_queue_ms > self.max_queue_ms {
            self.max_queue_ms = other.max_queue_ms;
        }
        if other.max_service_ms > self.max_service_ms {
            self.max_service_ms = other.max_service_ms;
        }
        self.queue_hist.merge(&other.queue_hist);
        self.service_hist.merge(&other.service_hist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let mut s = QueueStats::default();
        s.record(&Timing { queue_ms: 2.0, service_ms: 10.0 }, true);
        s.record(&Timing { queue_ms: 4.0, service_ms: 30.0 }, true);
        assert_eq!(s.served, 2);
        assert_eq!(s.mean_queue_ms(), 3.0);
        assert_eq!(s.mean_service_ms(), 20.0);
        assert_eq!(s.max_service_ms, 30.0);
    }

    #[test]
    fn empty_stats_zero() {
        let s = QueueStats::default();
        assert_eq!(s.mean_queue_ms(), 0.0);
        assert_eq!(s.mean_service_ms(), 0.0);
        assert_eq!(s.queue_hist.p50_ms(), 0.0);
    }

    #[test]
    fn failures_contribute_to_timing() {
        let mut s = QueueStats::default();
        s.record(&Timing { queue_ms: 2.0, service_ms: 10.0 }, true);
        s.record(&Timing { queue_ms: 6.0, service_ms: 50.0 }, false);
        assert_eq!(s.served, 1);
        assert_eq!(s.failures, 1);
        assert_eq!(s.completed(), 2);
        // the failed request's latency is visible in every aggregate
        assert_eq!(s.mean_queue_ms(), 4.0);
        assert_eq!(s.mean_service_ms(), 30.0);
        assert_eq!(s.max_service_ms, 50.0);
        assert_eq!(s.service_hist.count(), 2);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = LatencyHistogram::default();
        // bucket 0 = [0,1), bucket 1 = [1,2), bucket 4 = [8,16)
        h.record(0.5);
        h.record(1.5);
        h.record(9.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.quantile_ms(0.0), 1.0); // first bucket's upper edge
        assert_eq!(h.p50_ms(), 2.0);
        assert_eq!(h.p99_ms(), 16.0);
        // out-of-range inputs land in the edge buckets without
        // panicking: NaN/negatives at the bottom, +inf saturates the top
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 6);
        assert_eq!(h.quantile_ms(1.0), (1u64 << 27) as f64);
    }

    #[test]
    fn histogram_merge_matches_union() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        for ms in [0.2, 3.0, 5.0] {
            a.record(ms);
        }
        for ms in [100.0, 200.0] {
            b.record(ms);
        }
        let mut u = LatencyHistogram::default();
        for ms in [0.2, 3.0, 5.0, 100.0, 200.0] {
            u.record(ms);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_ms(q), u.quantile_ms(q));
        }
    }

    #[test]
    fn merge_arithmetic() {
        let mut a = QueueStats::default();
        a.record(&Timing { queue_ms: 1.0, service_ms: 10.0 }, true);
        a.record(&Timing { queue_ms: 3.0, service_ms: 20.0 }, false);
        a.record_batch(2);
        a.record_shed();
        a.panics += 1;
        let mut b = QueueStats::default();
        b.record(&Timing { queue_ms: 5.0, service_ms: 40.0 }, true);
        b.record_batch(3);
        b.respawns += 2;
        a.merge(&b);
        assert_eq!(a.served, 2);
        assert_eq!(a.failures, 1);
        assert_eq!(a.shed_deadline, 1);
        assert_eq!(a.panics, 1);
        assert_eq!(a.respawns, 2);
        assert_eq!(a.batches, 2);
        assert_eq!(a.max_batch, 3);
        assert_eq!(a.completed(), 3);
        assert_eq!(a.mean_queue_ms(), 3.0);
        assert_eq!(a.total_service_ms, 70.0);
        assert_eq!(a.max_service_ms, 40.0);
        assert_eq!(a.queue_hist.count(), 3);
        assert_eq!(a.service_hist.count(), 3);
    }
}
