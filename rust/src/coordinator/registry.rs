//! Multi-tenant model registry: `ModelId`-addressed, `Arc`-shared
//! compiled models behind one fleet.
//!
//! The registry is the tenancy seam of the serving layer. Each entry
//! pairs a *cold seed* (meta, frozen master parameters, stored global
//! importance, training corpus, operating-point config) with an
//! optional *warm* [`CompiledModel`] — the compiled graph plus
//! `Arc`-frozen masters that every fleet worker shares. Because
//! compiled modules are immutable `Send + Sync` programs
//! (`Arc<Executable>`, see [`runtime`](crate::runtime)), warming a
//! model compiles it **once per process**, not once per worker:
//! [`RegistryWorker`]s spin up in O(1) and borrow the shared graph on
//! first use. The [`ModelRegistry::builds`] counter increments only
//! when a graph is actually compiled, so tests and CI can pin the
//! no-per-worker-rebuild guarantee directly.
//!
//! Parameter semantics differ from the legacy per-worker replica: a
//! registry model's master store is **frozen** behind `Arc`. Each
//! request edits a private [`CowParams`](crate::model::CowParams)
//! overlay whose segment deltas are discarded after the summary is
//! taken, so a request's post-unlearn parameters are a pure function of
//! (worker seed, spec, master) — bitwise identical to a dedicated
//! single-model run, regardless of how tenants interleave.
//!
//! Warm entries are bounded by a warm capacity
//! ([`ModelRegistry::with_warm_cap`]): warming one model beyond the cap
//! evicts the least-recently-used other entry back to cold. Eviction
//! only drops the registry's own `Arc` — workers mid-request keep
//! serving their pinned graph and pick up the re-warmed one (checked
//! via `Arc::ptr_eq`) on their next request for that model.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{bail, Context, Result};

use crate::config::SharedMeta;
use crate::coordinator::dispatch::{UnlearnService, WorkerSpec};
use crate::coordinator::session::{execute_forget, ForgetContext};
use crate::coordinator::wal::config_fingerprint;
use crate::coordinator::Summary;
use crate::data::Dataset;
use crate::fisher::{FimdEngine, Importance};
use crate::hwsim::{BaselineProcessor, FicabuProcessor};
use crate::model::{CowParams, Model, ParamStore};
use crate::runtime::{meta_fingerprint, Precision, Runtime};
use crate::unlearn::{DampEngine, Ficabu, ForgetSpec};

/// Longest accepted model id (also the wire-path segment bound).
pub const MODEL_ID_MAX_LEN: usize = 64;

/// Validated tenant/model identifier: 1–64 chars of
/// `[A-Za-z0-9._-]`. The default id (`"default"`) is what a
/// registry-less fleet serves and what the legacy `POST /forget` body
/// resolves to when the fleet hosts a single model.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(String);

impl ModelId {
    pub fn new(id: impl Into<String>) -> Result<ModelId> {
        let id = id.into();
        if id.is_empty() || id.len() > MODEL_ID_MAX_LEN {
            bail!("model id must be 1..={MODEL_ID_MAX_LEN} chars, got {}", id.len());
        }
        if !id.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-') {
            bail!("model id {id:?} has chars outside [A-Za-z0-9._-]");
        }
        Ok(ModelId(id))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl Default for ModelId {
    fn default() -> ModelId {
        ModelId("default".to_string())
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// One warm model: the compiled graph plus everything a worker needs to
/// serve it, all shared immutably across the fleet. The master store is
/// frozen — per-request edits live in a [`CowParams`] overlay.
pub struct CompiledModel {
    pub id: ModelId,
    pub model: Model,
    /// Frozen master parameters every request's CoW overlay reads from.
    pub master: Arc<ParamStore>,
    pub global: Arc<Importance>,
    pub train: Arc<Dataset>,
    pub cfg: crate::unlearn::UnlearnConfig,
    /// [`config_fingerprint`] of `cfg` — the batch key's config half.
    pub config_hash: u64,
    pub precision: Precision,
    pub shared: SharedMeta,
}

/// Registry listing row (`GET /models`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub id: ModelId,
    /// Hex of the model topology fingerprint
    /// ([`meta_fingerprint`]) — the identity compiled modules cache
    /// under.
    pub spec_key: String,
    pub config_hash: u64,
    pub precision: Precision,
    /// Whether the compiled graph is currently resident.
    pub warm: bool,
}

impl ModelInfo {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("id", Json::string(self.id.to_string())),
            ("spec_key", Json::string(self.spec_key.clone())),
            ("config_hash", Json::string(format!("{:016x}", self.config_hash))),
            ("precision", Json::string(self.precision.name())),
            ("warm", Json::from(self.warm)),
        ])
    }
}

/// Cold half of a registry entry: the `Send` data a [`CompiledModel`]
/// is built from (the same bag a legacy worker replica travels as).
struct ModelSeed {
    spec: WorkerSpec,
    master: Arc<ParamStore>,
    global: Arc<Importance>,
    train: Arc<Dataset>,
    config_hash: u64,
    precision: Precision,
}

struct Slot {
    seed: ModelSeed,
    compiled: Option<Arc<CompiledModel>>,
    /// Registry tick of the last `get` — the LRU eviction order.
    last_used: u64,
}

struct Inner {
    entries: HashMap<ModelId, Slot>,
    tick: u64,
}

/// `ModelId`-keyed registry of compiled models, shared by every fleet
/// worker behind an `Arc`. See the module docs for the warm/cold and
/// copy-on-write semantics.
///
/// All methods take `&self`; the registry is `Send + Sync` and safe to
/// share across worker threads. Compilation happens under the internal
/// lock, so a model is compiled exactly once per warm cycle no matter
/// how many workers race to warm it.
pub struct ModelRegistry {
    rt: Runtime,
    inner: Mutex<Inner>,
    /// Graph compilations performed (register never compiles; `get` on
    /// a cold entry does, including re-warms after eviction). The
    /// shared-build counter CI pins: serving N workers × one model must
    /// leave this at 1.
    builds: AtomicU64,
    warm_cap: usize,
}

/// Default bound on concurrently-warm models.
pub const DEFAULT_WARM_CAP: usize = 8;

impl ModelRegistry {
    /// Registry over the given runtime (the runtime's executable cache
    /// is what makes cross-model module sharing possible).
    pub fn new(rt: Runtime) -> ModelRegistry {
        ModelRegistry {
            rt,
            inner: Mutex::new(Inner { entries: HashMap::new(), tick: 0 }),
            builds: AtomicU64::new(0),
            warm_cap: DEFAULT_WARM_CAP,
        }
    }

    /// Bound the number of concurrently-warm models (>= 1). Warming
    /// past the cap evicts the least-recently-used other entry.
    pub fn with_warm_cap(mut self, cap: usize) -> ModelRegistry {
        self.warm_cap = cap.max(1);
        self
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register a model under `id` from the same `Send` bag a legacy
    /// worker replica is built from. Registration is cold — no
    /// compilation happens until the first [`ModelRegistry::get`].
    /// Fails on a duplicate id.
    pub fn register(&self, id: ModelId, spec: WorkerSpec) -> Result<()> {
        spec.params.validate(&spec.meta)?;
        if spec.global.per_seg.len() != spec.meta.num_segments() {
            bail!(
                "model {id}: importance covers {} segments, model has {}",
                spec.global.per_seg.len(),
                spec.meta.num_segments()
            );
        }
        let mut inner = self.lock();
        if inner.entries.contains_key(&id) {
            bail!("model {id} is already registered");
        }
        let seed = ModelSeed {
            master: Arc::new(spec.params.clone()),
            global: Arc::new(spec.global.clone()),
            train: Arc::new(spec.train.clone()),
            config_hash: config_fingerprint(&spec.cfg),
            precision: spec.precision,
            spec,
        };
        inner.entries.insert(id, Slot { seed, compiled: None, last_used: 0 });
        Ok(())
    }

    /// Fetch (warming if cold) the compiled model for `id`. Warm hits
    /// are an `Arc` clone under the lock; cold entries compile the
    /// graph here — the only place [`ModelRegistry::builds`] advances —
    /// and may evict the LRU warm entry beyond the warm cap.
    pub fn get(&self, id: &ModelId) -> Result<Arc<CompiledModel>> {
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner
            .entries
            .get_mut(id)
            .with_context(|| format!("unknown model {id}"))?;
        slot.last_used = tick;
        if let Some(c) = &slot.compiled {
            return Ok(Arc::clone(c));
        }
        let seed = &slot.seed;
        let model = Model::load(&self.rt, seed.spec.meta.clone())?;
        self.builds.fetch_add(1, Ordering::SeqCst);
        let compiled = Arc::new(CompiledModel {
            id: id.clone(),
            model,
            master: Arc::clone(&seed.master),
            global: Arc::clone(&seed.global),
            train: Arc::clone(&seed.train),
            cfg: seed.spec.cfg.clone(),
            config_hash: seed.config_hash,
            precision: seed.precision,
            shared: seed.spec.shared.clone(),
        });
        slot.compiled = Some(Arc::clone(&compiled));
        // Evict the LRU warm entries beyond the cap (never the one just
        // warmed: it has the newest tick).
        while inner.entries.values().filter(|s| s.compiled.is_some()).count() > self.warm_cap {
            let lru = inner
                .entries
                .iter()
                .filter(|(_, s)| s.compiled.is_some())
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match lru {
                Some(k) => inner.entries.get_mut(&k).unwrap().compiled = None,
                None => break,
            }
        }
        Ok(compiled)
    }

    /// Demote `id` to cold, dropping the registry's handle on its
    /// compiled graph. Returns whether it was warm. Workers holding the
    /// `Arc` keep serving; their next request re-warms.
    pub fn evict(&self, id: &ModelId) -> bool {
        let mut inner = self.lock();
        match inner.entries.get_mut(id) {
            Some(slot) => slot.compiled.take().is_some(),
            None => false,
        }
    }

    pub fn contains(&self, id: &ModelId) -> bool {
        self.lock().entries.contains_key(id)
    }

    /// The sole registered model, when exactly one is (what a
    /// model-less legacy `POST /forget` resolves to).
    pub fn sole(&self) -> Option<ModelId> {
        let inner = self.lock();
        if inner.entries.len() == 1 {
            inner.entries.keys().next().cloned()
        } else {
            None
        }
    }

    /// Config fingerprint of `id`'s operating point, if registered.
    pub fn config_hash(&self, id: &ModelId) -> Option<u64> {
        self.lock().entries.get(id).map(|s| s.seed.config_hash)
    }

    pub fn len(&self) -> usize {
        self.lock().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registry listing, sorted by id (`GET /models`).
    pub fn list(&self) -> Vec<ModelInfo> {
        let inner = self.lock();
        let mut rows: Vec<ModelInfo> = inner
            .entries
            .iter()
            .map(|(id, slot)| ModelInfo {
                id: id.clone(),
                spec_key: format!("{:016x}", meta_fingerprint(&slot.seed.spec.meta)),
                config_hash: slot.seed.config_hash,
                precision: slot.seed.precision,
                warm: slot.compiled.is_some(),
            })
            .collect();
        rows.sort_by(|a, b| a.id.cmp(&b.id));
        rows
    }

    /// Graph compilations so far — the shared-build counter. One model
    /// served by any number of workers holds this at 1 until an
    /// eviction forces a re-warm.
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::SeqCst)
    }

    /// The runtime whose executable cache backs every compiled model.
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

/// Per-model engine state a [`RegistryWorker`] keeps between requests:
/// the pinned compiled model plus the (cheap, cache-hitting) engine
/// pair and hwsim processors. Rebuilt when the registry's entry no
/// longer matches the pin (`Arc::ptr_eq`), i.e. after evict + re-warm.
struct ModelEngines {
    entry: Arc<CompiledModel>,
    fimd: FimdEngine,
    damp: DampEngine,
    strategy: Ficabu,
    ficabu_hw: FicabuProcessor,
    baseline_hw: BaselineProcessor,
}

/// The registry-backed fleet worker: a thin, O(1)-startup service that
/// borrows shared compiled graphs from a [`ModelRegistry`] and serves
/// each request against a fresh [`CowParams`] overlay of the model's
/// frozen master. Construction compiles nothing; engines materialize
/// per model on first request (module loads hit the shared runtime
/// cache).
pub struct RegistryWorker {
    registry: Arc<ModelRegistry>,
    /// Forget-batch sampler seed, identical to the legacy replica's
    /// (`0xedbe ^ (worker_id << 17)`), so a registry run is bitwise
    /// comparable to a dedicated single-model fleet of the same shape.
    seed: u64,
    engines: HashMap<ModelId, ModelEngines>,
}

impl RegistryWorker {
    pub fn new(registry: Arc<ModelRegistry>, worker_id: usize) -> RegistryWorker {
        RegistryWorker {
            registry,
            seed: 0xedbe ^ ((worker_id as u64) << 17),
            engines: HashMap::new(),
        }
    }

    fn engines_for(&mut self, id: &ModelId) -> Result<&mut ModelEngines> {
        let entry = self.registry.get(id)?;
        let stale = match self.engines.get(id) {
            Some(e) => !Arc::ptr_eq(&e.entry, &entry),
            None => true,
        };
        if stale {
            let rt = self.registry.runtime();
            let fimd = FimdEngine::new(rt, &entry.shared)?;
            let damp = DampEngine::new(rt, &entry.shared)?;
            let strategy = Ficabu::from_config(entry.cfg.clone());
            let tile = entry.model.meta.tile;
            let ficabu_hw = FicabuProcessor::new(tile, entry.precision);
            let baseline_hw = BaselineProcessor::new(tile, entry.precision);
            self.engines.insert(
                id.clone(),
                ModelEngines { entry, fimd, damp, strategy, ficabu_hw, baseline_hw },
            );
        }
        Ok(self.engines.get_mut(id).expect("just inserted"))
    }
}

impl UnlearnService for RegistryWorker {
    /// Model-less entry point: resolves the registry's sole model (the
    /// dispatcher always calls [`UnlearnService::unlearn_model`]).
    fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary> {
        let id = self
            .registry
            .sole()
            .context("fleet hosts multiple models; address one with unlearn_model")?;
        self.unlearn_model(&id, spec)
    }

    fn unlearn_model(&mut self, model: &ModelId, spec: &ForgetSpec) -> Result<Summary> {
        let seed = self.seed;
        let eng = self.engines_for(model)?;
        // Fresh overlay per request: reads fall through to the frozen
        // master, writes stay private, the delta dies with the summary.
        let mut params = CowParams::new(Arc::clone(&eng.entry.master));
        let ctx = ForgetContext {
            model: &eng.entry.model,
            global: &eng.entry.global,
            fimd: &eng.fimd,
            damp: &eng.damp,
            train: &eng.entry.train,
            strategy: &eng.strategy,
            ficabu_hw: &eng.ficabu_hw,
            baseline_hw: &eng.baseline_hw,
            seed,
        };
        let mut s = execute_forget(&ctx, &mut params, spec)?;
        s.model = model.clone();
        s.config_hash = eng.entry.config_hash;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelMeta;
    use crate::data::{cifar20_like, DatasetCfg};
    use crate::unlearn::UnlearnConfig;

    fn spec_for(seed: u64) -> WorkerSpec {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let params = ParamStore::init(&meta, seed);
        let mut global = Importance::zeros_like(&meta);
        global.floor(1e-6);
        let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (train, _) = cifar20_like(&cfg);
        WorkerSpec {
            shared: SharedMeta::builtin(),
            params,
            global,
            train,
            cfg: UnlearnConfig::default(),
            precision: Precision::F32,
            meta,
        }
    }

    #[test]
    fn model_id_validation() {
        assert!(ModelId::new("tenant-7.v2_a").is_ok());
        assert!(ModelId::new("").is_err());
        assert!(ModelId::new("a/b").is_err());
        assert!(ModelId::new("x".repeat(MODEL_ID_MAX_LEN + 1)).is_err());
        assert_eq!(ModelId::default().as_str(), "default");
    }

    #[test]
    fn register_is_cold_and_get_compiles_once() {
        let reg = ModelRegistry::new(Runtime::cpu().unwrap());
        let id = ModelId::new("m1").unwrap();
        reg.register(id.clone(), spec_for(11)).unwrap();
        assert_eq!(reg.builds(), 0, "registration must not compile");
        assert!(!reg.list()[0].warm);
        let a = reg.get(&id).unwrap();
        let b = reg.get(&id).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "warm hits share one compiled model");
        assert_eq!(reg.builds(), 1, "one build no matter how many gets");
        assert!(reg.list()[0].warm);
        assert!(reg.register(id.clone(), spec_for(11)).is_err(), "duplicate id");
        assert!(reg.get(&ModelId::new("nope").unwrap()).is_err());
    }

    #[test]
    fn evict_rewarns_with_a_fresh_arc_and_counts_the_build() {
        let reg = ModelRegistry::new(Runtime::cpu().unwrap());
        let id = ModelId::new("m1").unwrap();
        reg.register(id.clone(), spec_for(13)).unwrap();
        let a = reg.get(&id).unwrap();
        assert!(reg.evict(&id));
        assert!(!reg.evict(&id), "already cold");
        assert!(!reg.list()[0].warm);
        let b = reg.get(&id).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "re-warm builds a fresh entry");
        assert_eq!(reg.builds(), 2);
        // the evicted Arc stays serviceable for a pinned worker
        assert_eq!(a.model.meta.name, b.model.meta.name);
    }

    #[test]
    fn warm_cap_evicts_lru() {
        let reg = ModelRegistry::new(Runtime::cpu().unwrap()).with_warm_cap(1);
        let m1 = ModelId::new("m1").unwrap();
        let m2 = ModelId::new("m2").unwrap();
        reg.register(m1.clone(), spec_for(1)).unwrap();
        reg.register(m2.clone(), spec_for(2)).unwrap();
        reg.get(&m1).unwrap();
        reg.get(&m2).unwrap();
        let warm: Vec<bool> = reg.list().iter().map(|i| i.warm).collect();
        assert_eq!(warm, vec![false, true], "warming m2 evicted LRU m1");
    }

    #[test]
    fn sole_resolves_only_single_entry_registries() {
        let reg = ModelRegistry::new(Runtime::cpu().unwrap());
        assert_eq!(reg.sole(), None);
        let m1 = ModelId::new("m1").unwrap();
        reg.register(m1.clone(), spec_for(1)).unwrap();
        assert_eq!(reg.sole(), Some(m1));
        reg.register(ModelId::new("m2").unwrap(), spec_for(2)).unwrap();
        assert_eq!(reg.sole(), None);
    }
}
