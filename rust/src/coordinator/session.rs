//! The serving facade: one model + parameter replica + engine pair
//! behind `session.forget(spec)`.
//!
//! [`UnlearnSession`] is what every serving surface is built on — the
//! fleet's per-worker replica ([`EdgeServer`]), the sequential
//! single-device loop, the CLI `unlearn`/`serve` subcommands, the
//! benches. It owns the model, the live parameter store, the stored
//! global importance, the FIMD/Dampening engines, the hwsim processor
//! pair, and the pluggable [`Strategy`]; requests arrive as typed
//! [`ForgetSpec`]s.
//!
//! # Example
//!
//! Build a session over a builtin topology and forget two classes in
//! one event (untrained weights and a `tau = 1.0` first-checkpoint stop
//! keep this fast — a real deployment loads trained params and stored
//! importance, see `exp::prepare`):
//!
//! ```
//! use ficabu::config::ModelMeta;
//! use ficabu::coordinator::UnlearnSession;
//! use ficabu::data::{cifar20_like, DatasetCfg};
//! use ficabu::fisher::Importance;
//! use ficabu::model::{Model, ParamStore};
//! use ficabu::runtime::Runtime;
//! use ficabu::unlearn::{Cau, ForgetSpec};
//!
//! let rt = Runtime::cpu()?;
//! let meta = ModelMeta::builtin("rn18slim")?;
//! let model = Model::load(&rt, meta.clone())?;
//! let params = ParamStore::init(&meta, 42);
//! let mut global = Importance::zeros_like(&meta);
//! global.floor(1e-6);
//! let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
//! let (train, _) = cifar20_like(&cfg);
//!
//! let mut session = UnlearnSession::builder()
//!     .model(model)
//!     .params(params)
//!     .global(global)
//!     .train(train)
//!     .strategy(Cau::new(10.0, 1.0, vec![1], 1.0)) // tau = 1.0: stop at depth 1
//!     .build()?;
//!
//! let summary = session.forget(&ForgetSpec::Classes(vec![1, 3]))?;
//! assert_eq!(summary.stop_depth, Some(1));
//! assert_eq!(summary.spec, ForgetSpec::Classes(vec![1, 3]));
//! # anyhow::Ok(())
//! ```

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::SharedMeta;
use crate::coordinator::dispatch::WorkerSpec;
use crate::coordinator::registry::ModelId;
use crate::coordinator::wal::config_fingerprint;
use crate::coordinator::{Summary, Timing};
use crate::audit::Attestation;
use crate::data::Dataset;
use crate::fisher::{FimdEngine, Importance};
use crate::hwsim::{BaselineProcessor, FicabuProcessor};
use crate::metrics::{self, ThresholdAttack};
use crate::model::macs::ssd_ledger;
use crate::model::{Model, ParamAccess, ParamStore};
use crate::runtime::{Precision, Runtime};
use crate::unlearn::{
    run_strategy, DampEngine, Ficabu, ForgetSpec, Strategy, UnlearnConfig, UnlearnReport,
};
use crate::util::prng::Pcg32;

/// Per-worker serving core: one trained model + stored global importance
/// + engine pair + hwsim processors, executing one [`Strategy`]. One
/// session serves requests sequentially; concurrency lives in
/// [`Fleet`](crate::coordinator::Fleet), which runs one of these per
/// worker thread.
pub struct UnlearnSession {
    pub model: Model,
    pub params: ParamStore,
    pub global: Importance,
    pub fimd: FimdEngine,
    pub damp: DampEngine,
    pub train: Dataset,
    strategy: Box<dyn Strategy>,
    pub ficabu_hw: FicabuProcessor,
    pub baseline_hw: BaselineProcessor,
    /// Base seed of the forget-batch sampler. Each request draws from a
    /// fresh `Pcg32` seeded by `seed ^ spec-key hash`, so the batch is a
    /// pure function of (session seed, canonical spec): replicas stay
    /// decorrelated by seed, and a crash-recovery replay of the same
    /// spec reproduces the exact same edit.
    seed: u64,
}

/// The fleet-facing name for a session: each worker thread builds one
/// replica from a `Send` [`WorkerSpec`] and serves it sequentially.
pub type EdgeServer = UnlearnSession;

/// Builder for [`UnlearnSession`]. `model`, `params`, `global`, and
/// `train` are required; engines default to fresh ones on the
/// environment's runtime, the strategy defaults to
/// [`Ficabu::from_config`] over the default [`UnlearnConfig`], and the
/// hwsim precision defaults to the store's native precision.
#[derive(Default)]
pub struct UnlearnSessionBuilder {
    model: Option<Model>,
    params: Option<ParamStore>,
    global: Option<Importance>,
    engines: Option<(FimdEngine, DampEngine)>,
    train: Option<Dataset>,
    strategy: Option<Box<dyn Strategy>>,
    precision: Option<Precision>,
    seed: Option<u64>,
}

impl UnlearnSessionBuilder {
    pub fn model(mut self, model: Model) -> Self {
        self.model = Some(model);
        self
    }

    pub fn params(mut self, params: ParamStore) -> Self {
        self.params = Some(params);
        self
    }

    /// Stored global importance `I_D`.
    pub fn global(mut self, global: Importance) -> Self {
        self.global = Some(global);
        self
    }

    /// Reuse existing engines instead of building fresh ones.
    pub fn engines(mut self, fimd: FimdEngine, damp: DampEngine) -> Self {
        self.engines = Some((fimd, damp));
        self
    }

    /// The training corpus forget batches and eval splits come from.
    pub fn train(mut self, train: Dataset) -> Self {
        self.train = Some(train);
        self
    }

    /// The unlearning method to execute (see [`Strategy`]).
    pub fn strategy(mut self, strategy: impl Strategy + 'static) -> Self {
        self.strategy = Some(Box::new(strategy));
        self
    }

    /// Shorthand for [`Self::strategy`] with the default stages over a
    /// travelled parameter bag (the fleet replica path).
    pub fn config(self, cfg: UnlearnConfig) -> Self {
        self.strategy(Ficabu::from_config(cfg))
    }

    /// hwsim precision (default: the store's native precision).
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Seed for the forget-batch sampler (decorrelates replicas).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn build(self) -> Result<UnlearnSession> {
        let model = self.model.context("UnlearnSession: model is required")?;
        let params = self.params.context("UnlearnSession: params are required")?;
        let global = self.global.context("UnlearnSession: global importance is required")?;
        let train = self.train.context("UnlearnSession: train dataset is required")?;
        params.validate(&model.meta)?;
        if global.per_seg.len() != model.meta.num_segments() {
            bail!(
                "UnlearnSession: importance covers {} segments, model has {}",
                global.per_seg.len(),
                model.meta.num_segments()
            );
        }
        let (fimd, damp) = match self.engines {
            Some(pair) => pair,
            None => {
                let rt = Runtime::from_env()?;
                let shared = SharedMeta::resolve()?;
                (FimdEngine::new(&rt, &shared)?, DampEngine::new(&rt, &shared)?)
            }
        };
        let strategy = self
            .strategy
            .unwrap_or_else(|| Box::new(Ficabu::from_config(UnlearnConfig::default())));
        let precision = self.precision.unwrap_or_else(|| Model::store_precision(&params));
        let tile = model.meta.tile;
        Ok(UnlearnSession {
            model,
            params,
            global,
            fimd,
            damp,
            train,
            strategy,
            ficabu_hw: FicabuProcessor::new(tile, precision),
            baseline_hw: BaselineProcessor::new(tile, precision),
            seed: self.seed.unwrap_or(0xedbe),
        })
    }
}

impl UnlearnSession {
    pub fn builder() -> UnlearnSessionBuilder {
        UnlearnSessionBuilder::default()
    }

    /// Build a replica from a `Send` spec — called inside the worker
    /// thread. Compiled modules are immutable `Send + Sync` programs
    /// nowadays (the registry path shares one graph across workers);
    /// the legacy replica still clones its *parameter store* per worker
    /// because it edits parameters in place. Replicas are re-entrant by
    /// construction: every engine buffer and counter is owned per
    /// instance, nothing is shared across workers.
    pub fn from_spec(spec: &WorkerSpec, worker_id: usize) -> Result<UnlearnSession> {
        let rt = Runtime::from_env()?;
        let model = Model::load(&rt, spec.meta.clone())?;
        let fimd = FimdEngine::new(&rt, &spec.shared)?;
        let damp = DampEngine::new(&rt, &spec.shared)?;
        UnlearnSession::builder()
            .model(model)
            .params(spec.params.clone())
            .global(spec.global.clone())
            .engines(fimd, damp)
            .train(spec.train.clone())
            .config(spec.cfg.clone())
            .precision(spec.precision)
            .seed(0xedbe ^ ((worker_id as u64) << 17))
            .build()
    }

    /// Reseed the forget-batch sampler (used to decorrelate replicas).
    pub fn with_seed(mut self, seed: u64) -> UnlearnSession {
        self.seed = seed;
        self
    }

    /// The method this session executes.
    pub fn strategy(&self) -> &dyn Strategy {
        self.strategy.as_ref()
    }

    /// The strategy's serializable parameter bag. Its fingerprint
    /// ([`config_fingerprint`]) is the `config_hash` stamped on every
    /// [`Summary`] and used in the fleet's batch key.
    pub fn config(&self) -> &UnlearnConfig {
        self.strategy.config()
    }

    /// Execute one unlearning event against this session's live
    /// parameter store and report quality + simulated hardware cost.
    /// `Summary::timing` is zeroed here; the dispatcher fills it.
    pub fn forget(&mut self, spec: &ForgetSpec) -> Result<Summary> {
        let ctx = ForgetContext {
            model: &self.model,
            global: &self.global,
            fimd: &self.fimd,
            damp: &self.damp,
            train: &self.train,
            strategy: self.strategy.as_ref(),
            ficabu_hw: &self.ficabu_hw,
            baseline_hw: &self.baseline_hw,
            seed: self.seed,
        };
        let mut s = execute_forget(&ctx, &mut self.params, spec)?;
        s.config_hash = config_fingerprint(self.strategy.config());
        Ok(s)
    }

    /// Serve requests from an iterator, sequentially, on the caller's
    /// thread — the single-device deployment of Fig. 1, kept for direct
    /// embedding. Returns one timed summary per request.
    pub fn serve_sequential(
        &mut self,
        specs: impl IntoIterator<Item = ForgetSpec>,
    ) -> Vec<Result<Summary, String>> {
        specs
            .into_iter()
            .map(|spec| {
                let t0 = Instant::now();
                self.forget(&spec)
                    .map(|mut s| {
                        s.timing =
                            Timing { queue_ms: 0.0, service_ms: t0.elapsed().as_secs_f64() * 1e3 };
                        s
                    })
                    .map_err(|e| format!("{e:#}"))
            })
            .collect()
    }
}

/// Borrowed view of everything one forget execution needs *besides* the
/// parameters being edited. Both serving cores build one per request:
/// [`UnlearnSession`] over its owned drifting [`ParamStore`], and
/// [`RegistryWorker`](crate::coordinator::registry::RegistryWorker)
/// over a per-request [`CowParams`](crate::model::CowParams) overlay of
/// a frozen `Arc` master.
pub(crate) struct ForgetContext<'a> {
    pub model: &'a Model,
    pub global: &'a Importance,
    pub fimd: &'a FimdEngine,
    pub damp: &'a DampEngine,
    pub train: &'a Dataset,
    pub strategy: &'a dyn Strategy,
    pub ficabu_hw: &'a FicabuProcessor,
    pub baseline_hw: &'a BaselineProcessor,
    pub seed: u64,
}

/// One unlearning event against `params` (owned store or CoW overlay —
/// any [`ParamAccess`]): sample the forget batch, run the strategy,
/// read out quality, and cost the run on the hwsim pair. The returned
/// [`Summary`] carries the default model id and a zero `config_hash`;
/// callers stamp their own tenancy fields.
pub(crate) fn execute_forget(
    ctx: &ForgetContext<'_>,
    params: &mut dyn ParamAccess,
    spec: &ForgetSpec,
) -> Result<Summary> {
    let meta = &ctx.model.meta;
    let spec = spec.canonical();
    // bounds vs the *model head* — pool() below only checks the
    // dataset's own class count, which may exceed the head's
    spec.validate(meta.num_classes, ctx.train.len())?;
    let pool = spec.pool(ctx.train)?;
    // the retain split is the complement of the pool, subsampled to
    // edge budget; computed up front so the attestation below can
    // probe quality on both sides of the edit
    let retain_idx: Vec<usize> =
        ForgetSpec::retain_of(&pool, ctx.train.len()).into_iter().step_by(4).collect();
    // pre-edit probes for the audit attestation: quality on both
    // splits plus the forget set's loss profile
    let forget_acc_before = metrics::eval_accuracy(ctx.model, &*params, ctx.train, &pool)?;
    let retain_acc_before = metrics::eval_accuracy(ctx.model, &*params, ctx.train, &retain_idx)?;
    let forget_losses_before =
        metrics::per_sample_losses(ctx.model, &*params, ctx.train, &pool)?;
    // Per-request sampler: deterministic in (seed, spec) — required
    // for durable replay to reproduce the pre-crash edit bitwise.
    let mut rng = Pcg32::seeded(ctx.seed ^ spec.key().hash64());
    let (x, labels) = ctx.train.batch_from_pool(&pool, meta.batch, &mut rng)?;
    let report: UnlearnReport = run_strategy(
        ctx.model,
        params,
        &x,
        &labels,
        ctx.global,
        ctx.fimd,
        ctx.damp,
        ctx.strategy,
    )?;

    // post-edit quality readout on the same splits
    let forget_acc = metrics::eval_accuracy(ctx.model, &*params, ctx.train, &pool)?;
    let retain_acc = metrics::eval_accuracy(ctx.model, &*params, ctx.train, &retain_idx)?;

    // Membership-inference attestation: calibrate a threshold attack
    // on the post-edit losses (members = retained samples, non-members
    // = the forgotten samples), then probe the forget set's pre- vs
    // post-edit losses. Successful unlearning drives the member-rate
    // down — the per-link evidence the audit chain records.
    let forget_losses_after = metrics::per_sample_losses(ctx.model, &*params, ctx.train, &pool)?;
    let retain_losses_after =
        metrics::per_sample_losses(ctx.model, &*params, ctx.train, &retain_idx)?;
    let attack = ThresholdAttack::fit(&retain_losses_after, &forget_losses_after);
    let attest = Attestation {
        strategy: ctx.strategy.name().to_string(),
        precision: report.precision.name().to_string(),
        seed: ctx.seed,
        forget_acc_before,
        retain_acc_before,
        mia_before: attack.member_rate(&forget_losses_before),
        mia_after: attack.member_rate(&forget_losses_after),
    };

    // hardware cost: this run on FiCABU vs the SSD ledger on baseline
    // (same executed precision, so the f32-gradient lane penalty and
    // byte widths apply to both sides of the comparison)
    let fic = ctx.ficabu_hw.cost(&report);
    let ssd_ref_report = UnlearnReport {
        ledger: ssd_ledger(meta, meta.batch),
        fimd_elems: meta.total_params() as u64 * (meta.batch / meta.microbatch) as u64,
        damp_elems: meta.total_params() as u64,
        act_cache_bytes: report.act_cache_bytes,
        precision: report.precision,
        ..Default::default()
    };
    let ssd = ctx.baseline_hw.cost(&ssd_ref_report);

    Ok(Summary {
        spec,
        model: ModelId::default(),
        config_hash: 0,
        forget_acc,
        retain_acc,
        stop_depth: report.stop_depth,
        macs_vs_ssd_pct: 100.0 * report.ledger.editing_total() as f64
            / ssd_ref_report.ledger.editing_total() as f64,
        sim_energy_mj: fic.energy_mj,
        sim_energy_vs_ssd_pct: 100.0 * fic.energy_mj / ssd.energy_mj,
        sim_ms: fic.seconds * 1e3,
        rolled_back: report.rolled_back,
        timing: Timing::default(),
        wal_seq: None,
        attest: Some(attest),
    })
}
