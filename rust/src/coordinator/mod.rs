//! Edge serving layer: typed forget requests over a multi-worker
//! unlearning fleet.
//!
//! The paper's Fig. 1 (right) deploys one Unlearning Engine on the edge
//! device. This module grows that shape into a serving fleet for heavy
//! forget-request traffic, speaking [`ForgetSpec`] end to end:
//!
//! ```text
//!  clients ──► Fleet::submit(spec) ──► admission control ──► bounded FIFO
//!                 │   (coalesce on canonical SpecKey,          │
//!                 │    shed on full queue)                     ▼
//!                 │                        workers 0..N (one thread each)
//!                 ▼                         ├─ UnlearnSession replica 0
//!          Reply receiver ◄── fan-out ──────┤   (own ParamStore + engines
//!          (Done | Failed |                 ├─ UnlearnSession replica 1
//!           Backpressure | Expired)         └─ ...          + Strategy)
//! ```
//!
//! * [`UnlearnSession`] (alias [`EdgeServer`]) is the per-worker core:
//!   one model, one parameter replica, one FIMD/Dampening engine pair,
//!   one hwsim processor pair, one pluggable
//!   [`Strategy`](crate::unlearn::Strategy). Compiled modules are
//!   immutable `Send + Sync` programs behind `Arc`, shared through the
//!   runtime's executable cache; replicas are still built inside their
//!   worker thread from a `Send` [`WorkerSpec`] because each owns a
//!   drifting parameter store.
//! * [`ModelRegistry`] (see [`registry`]) is the multi-tenant shape:
//!   `ModelId`-keyed `Arc`-shared compiled models behind one fleet,
//!   O(1) worker spin-up ([`RegistryWorker`]), per-request copy-on-write
//!   parameter deltas against frozen masters, warm/cold eviction.
//! * [`Fleet`] (see [`dispatch`]) owns the shared queue: requests whose
//!   [`BatchKey`](dispatch::BatchKey) — (model, config fingerprint,
//!   canonical [`SpecKey`](crate::unlearn::SpecKey)) — matches a queued
//!   entry coalesce into a single execution with fan-out replies
//!   (`classes:4,1` and `classes:1,4` on one model are one event),
//!   workers claim batched passes that may mix tenants freely, a
//!   bounded queue sheds excess load with [`Reply::Backpressure`], and
//!   stale entries are shed against their deadline.
//! * [`QueueStats`] aggregates per-worker latency (mean/max plus
//!   p50/p95/p99 histograms for queue and service time) and merges into
//!   the fleet-wide rollup surfaced by [`Fleet::stats`] and the `serve`
//!   CLI.
//! * [`HttpServer`] (see [`http`]) puts the fleet on the wire: a
//!   zero-dependency HTTP/1.1 front-end speaking the JSON contracts
//!   (`POST /forget`, `GET /stats`, `GET /healthz`) with [`Reply`]
//!   outcomes mapped onto status codes (429 backpressure, 504 expired).
//!
//! Replica semantics: each worker's parameter store drifts independently
//! as it applies edits — the fleet models N devices serving a shared
//! request stream, not N consistent copies of one store. Coalescing is
//! therefore exact (one execution, one store) while cross-worker
//! convergence is out of scope here (see ROADMAP sharding).

pub mod checkpoint;
pub mod dispatch;
pub mod http;
pub mod queue;
pub mod registry;
pub mod session;
pub mod wal;

pub use dispatch::{
    BatchKey, Fleet, FleetConfig, FleetStats, Pacing, Reply, UnlearnService, WorkerSpec,
};
pub use http::{HttpConfig, HttpServer};
pub use queue::{LatencyHistogram, QueueStats, Timing};
pub use registry::{CompiledModel, ModelId, ModelInfo, ModelRegistry, RegistryWorker};
pub use session::{EdgeServer, UnlearnSession, UnlearnSessionBuilder};
pub use wal::{Durability, DurabilityConfig, DurabilityStats};

use anyhow::Result;

use crate::unlearn::ForgetSpec;
use crate::util::json::Json;

/// Outcome summary of one served unlearning event.
#[derive(Debug, Clone)]
pub struct Summary {
    /// The model this event ran against. Single-model fleets report the
    /// default id; registry fleets stamp the addressed tenant.
    pub model: ModelId,
    /// FNV-1a fingerprint of the serving
    /// [`UnlearnConfig`](crate::unlearn::UnlearnConfig) the event
    /// executed under — the same hash the dispatcher coalesces on and
    /// the ledger records.
    pub config_hash: u64,
    /// The canonical request this event executed.
    pub spec: ForgetSpec,
    pub forget_acc: f64,
    pub retain_acc: f64,
    pub stop_depth: Option<usize>,
    pub macs_vs_ssd_pct: f64,
    pub sim_energy_mj: f64,
    pub sim_energy_vs_ssd_pct: f64,
    /// Latency of this event on the simulated FiCABU processor
    /// (50 MHz prototype), from the hwsim pipeline model.
    pub sim_ms: f64,
    /// Whether the event's parameter edits were rolled back (always
    /// `false` on a `done` reply today — a failed event reports the
    /// rollback in its error message — but carried on the wire contract
    /// so partial-success modes can express it).
    pub rolled_back: bool,
    /// Filled in by the dispatcher: measured queue + service latency.
    pub timing: Timing,
    /// Ledger sequence number of the request (lowest across coalesced
    /// submissions) on a durable fleet; `None` otherwise. A caller can
    /// quote it against the ledger as proof its request was recorded.
    pub wal_seq: Option<u64>,
    /// Membership-inference attestation of this event
    /// ([`Attestation`](crate::audit::Attestation)): before/after
    /// accuracies and MIA member-rates on the forget set. `None` when
    /// the serving core cannot probe (e.g. a mock service). On a
    /// durable fleet this is what enters the audit chain.
    pub attest: Option<crate::audit::Attestation>,
}

impl Summary {
    /// Wire form of the summary — the `summary` payload of a `done`
    /// reply on the HTTP surface, with the spec in its canonical string
    /// grammar (`"classes:1,4"`, accepted back by
    /// [`ForgetSpec::from_json`]) and the measured timing flattened to
    /// `queue_ms`/`service_ms`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::string(self.model.to_string())),
            ("config_hash", Json::string(format!("{:016x}", self.config_hash))),
            ("spec", Json::string(self.spec.to_string())),
            ("forget_acc", Json::from(self.forget_acc)),
            ("retain_acc", Json::from(self.retain_acc)),
            (
                "stop_depth",
                self.stop_depth.map(Json::from).unwrap_or(Json::Null),
            ),
            ("macs_vs_ssd_pct", Json::from(self.macs_vs_ssd_pct)),
            ("sim_energy_mj", Json::from(self.sim_energy_mj)),
            ("sim_energy_vs_ssd_pct", Json::from(self.sim_energy_vs_ssd_pct)),
            ("sim_ms", Json::from(self.sim_ms)),
            ("rolled_back", Json::from(self.rolled_back)),
            ("queue_ms", Json::from(self.timing.queue_ms)),
            ("service_ms", Json::from(self.timing.service_ms)),
            ("wal_seq", self.wal_seq.map(|s| Json::from(s as usize)).unwrap_or(Json::Null)),
            ("attest", self.attest.as_ref().map(|a| a.to_json()).unwrap_or(Json::Null)),
        ])
    }
}

impl UnlearnService for UnlearnSession {
    fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary> {
        self.forget(spec)
    }

    fn params(&self) -> Option<&crate::model::ParamStore> {
        Some(&self.params)
    }
}

#[cfg(test)]
mod tests {
    // Queue statistics are unit-tested in queue.rs; the dispatcher
    // (spec-key coalescing, shedding, drain, stats rollup) in
    // tests/dispatch.rs against a mock service; session + fleet
    // end-to-end over class / multi-class / sample specs in
    // tests/spec_e2e.rs, examples/edge_serving.rs and
    // benches/bench_serve.rs; the HTTP front-end over a real loopback
    // socket in tests/http_e2e.rs.
    use super::*;

    fn summary() -> Summary {
        Summary {
            model: ModelId::default(),
            config_hash: 0xdead_beef_0042_0007,
            spec: ForgetSpec::Classes(vec![1, 4]),
            forget_acc: 0.05,
            retain_acc: 0.91,
            stop_depth: Some(2),
            macs_vs_ssd_pct: 12.5,
            sim_energy_mj: 1.25,
            sim_energy_vs_ssd_pct: 9.0,
            sim_ms: 430.0,
            rolled_back: false,
            timing: Timing { queue_ms: 3.0, service_ms: 80.0 },
            wal_seq: None,
            attest: None,
        }
    }

    #[test]
    fn reply_codes_are_stable() {
        // wire contract: these strings are what clients switch on
        assert_eq!(Reply::Done(summary()).code(), "done");
        assert_eq!(Reply::Failed("x".into()).code(), "failed");
        assert_eq!(Reply::Backpressure { queue_len: 3, queue_cap: 3 }.code(), "backpressure");
        assert_eq!(Reply::Expired { missed_by_ms: 7.0 }.code(), "expired");
    }

    #[test]
    fn reply_error_impl_propagates() {
        let e = anyhow::Error::from(Reply::Backpressure { queue_len: 2, queue_cap: 2 });
        assert!(e.to_string().contains("backpressure"));
        assert!(Reply::Expired { missed_by_ms: 12.0 }.to_string().contains("12 ms"));
    }

    #[test]
    fn summary_json_carries_the_canonical_spec_and_timing() {
        let j = summary().to_json();
        assert_eq!(j.get("spec").unwrap().as_str(), Some("classes:1,4"));
        assert_eq!(
            crate::unlearn::ForgetSpec::from_json(j.get("spec").unwrap()).unwrap(),
            ForgetSpec::Classes(vec![1, 4])
        );
        assert_eq!(j.get("stop_depth").unwrap().as_i64(), Some(2));
        assert_eq!(j.get("queue_ms").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("service_ms").unwrap().as_f64(), Some(80.0));
        // tenancy fields: model id + config fingerprint as fixed-width hex
        assert_eq!(j.get("model").unwrap().as_str(), Some("default"));
        assert_eq!(j.get("config_hash").unwrap().as_str(), Some("deadbeef00420007"));
    }

    #[test]
    fn reply_json_matches_code() {
        let j = Reply::Done(summary()).to_json();
        assert_eq!(j.get("code").unwrap().as_str(), Some("done"));
        assert!(j.get("summary").unwrap().get("forget_acc").is_some());
        let j = Reply::Backpressure { queue_len: 5, queue_cap: 8 }.to_json();
        assert_eq!(j.get("code").unwrap().as_str(), Some("backpressure"));
        assert_eq!(j.get("queue_len").unwrap().as_i64(), Some(5));
        assert_eq!(j.get("queue_cap").unwrap().as_i64(), Some(8));
        let j = Reply::Expired { missed_by_ms: 6.5 }.to_json();
        assert_eq!(j.get("missed_by_ms").unwrap().as_f64(), Some(6.5));
        let j = Reply::Failed("boom".into()).to_json();
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn stats_json_uses_the_bench_field_names() {
        let mut q = QueueStats::default();
        q.record(&Timing { queue_ms: 2.0, service_ms: 40.0 }, true);
        let j = q.to_json();
        // percentile_fields() is the naming authority bench_serve shares
        for (name, _) in q.percentile_fields() {
            assert!(j.get(name).is_some(), "missing {name}");
        }
        let fs = FleetStats {
            workers: 1,
            alive: 1,
            admitted: 1,
            coalesced: 0,
            shed_backpressure: 0,
            queue_depth: 0,
            per_worker: vec![q],
            per_model: vec![],
            durability: None,
        };
        let j = fs.to_json();
        assert_eq!(j.get("workers").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("rollup").unwrap().get("served").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("per_model").is_some(), "per-model rollup is on the wire");
        // supervision + durability are part of the wire contract
        assert_eq!(j.get("alive").unwrap().as_i64(), Some(1));
        assert!(j.get("rollup").unwrap().get("panics").is_some());
        assert!(j.get("rollup").unwrap().get("respawns").is_some());
        assert!(matches!(j.get("durability"), Some(Json::Null)), "null when not durable");
        let durable = FleetStats {
            durability: Some(DurabilityStats {
                generation: 2,
                wal_seq: 7,
                replayed: 1,
                checkpoints: 3,
            }),
            ..fs
        };
        let d = durable.to_json();
        let d = d.get("durability").unwrap();
        assert_eq!(d.get("generation").unwrap().as_i64(), Some(2));
        assert_eq!(d.get("wal_seq").unwrap().as_i64(), Some(7));
        assert_eq!(d.get("replayed").unwrap().as_i64(), Some(1));
        assert_eq!(d.get("checkpoints").unwrap().as_i64(), Some(3));
    }
}
