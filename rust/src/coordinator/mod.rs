//! Edge serving layer: a multi-worker unlearning fleet.
//!
//! The paper's Fig. 1 (right) deploys one Unlearning Engine on the edge
//! device. This module grows that shape into a serving fleet for heavy
//! forget-request traffic:
//!
//! ```text
//!  clients ──► Fleet::submit ──► admission control ──► bounded FIFO
//!                 │  (coalesce duplicates,              │
//!                 │   shed on full queue)               ▼
//!                 │                        workers 0..N (one thread each)
//!                 ▼                         ├─ EdgeServer replica 0
//!          Reply receiver ◄── fan-out ──────┤   (own ParamStore + engines)
//!          (Done | Failed |                 ├─ EdgeServer replica 1
//!           Backpressure | Expired)         └─ ...
//! ```
//!
//! * [`EdgeServer`] is the per-worker core: one model, one parameter
//!   replica, one FIMD/Dampening engine pair, one hwsim processor pair.
//!   Compiled modules hold `Rc` handles (not `Send`), so replicas are
//!   built *inside* their worker thread from a `Send` [`WorkerSpec`].
//! * [`Fleet`] (see [`dispatch`]) owns the shared queue: duplicate
//!   forget requests for one class coalesce into a single execution with
//!   fan-out replies, workers claim batched passes, a bounded queue
//!   sheds excess load with [`Reply::Backpressure`], and stale entries
//!   are shed against their deadline.
//! * [`QueueStats`] aggregates per-worker latency (mean/max plus
//!   p50/p95/p99 histograms for queue and service time) and merges into
//!   the fleet-wide rollup surfaced by [`Fleet::stats`] and the `serve`
//!   CLI.
//!
//! Replica semantics: each worker's parameter store drifts independently
//! as it applies edits — the fleet models N devices serving a shared
//! request stream, not N consistent copies of one store. Coalescing is
//! therefore exact (one execution, one store) while cross-worker
//! convergence is out of scope here (see ROADMAP sharding).

pub mod dispatch;
pub mod queue;

pub use dispatch::{Fleet, FleetConfig, FleetStats, Pacing, Reply, UnlearnService, WorkerSpec};
pub use queue::{LatencyHistogram, QueueStats, Timing};

use std::time::Instant;

use anyhow::Result;

use crate::data::Dataset;
use crate::fisher::{FimdEngine, Importance};
use crate::hwsim::{BaselineProcessor, FicabuProcessor};
use crate::metrics;
use crate::model::macs::ssd_ledger;
use crate::model::{Model, ParamStore};
use crate::runtime::Runtime;
use crate::unlearn::{run_unlearning, DampEngine, UnlearnConfig, UnlearnReport};
use crate::util::prng::Pcg32;

/// Outcome summary of one served unlearning event.
#[derive(Debug, Clone)]
pub struct Summary {
    pub class: usize,
    pub forget_acc: f64,
    pub retain_acc: f64,
    pub stop_depth: Option<usize>,
    pub macs_vs_ssd_pct: f64,
    pub sim_energy_mj: f64,
    pub sim_energy_vs_ssd_pct: f64,
    /// Latency of this event on the simulated FiCABU processor
    /// (50 MHz prototype), from the hwsim pipeline model.
    pub sim_ms: f64,
    /// Filled in by the dispatcher: measured queue + service latency.
    pub timing: Timing,
}

/// Per-worker serving core: one trained model + stored global importance
/// + engine pair + hwsim processors. One `EdgeServer` serves requests
/// sequentially; concurrency lives in [`Fleet`].
pub struct EdgeServer {
    pub model: Model,
    pub params: ParamStore,
    pub global: Importance,
    pub fimd: FimdEngine,
    pub damp: DampEngine,
    pub train: Dataset,
    pub cfg: UnlearnConfig,
    pub ficabu_hw: FicabuProcessor,
    pub baseline_hw: BaselineProcessor,
    pub rng: Pcg32,
}

impl EdgeServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        model: Model,
        params: ParamStore,
        global: Importance,
        fimd: FimdEngine,
        damp: DampEngine,
        train: Dataset,
        cfg: UnlearnConfig,
        ficabu_hw: FicabuProcessor,
        baseline_hw: BaselineProcessor,
    ) -> EdgeServer {
        EdgeServer {
            model,
            params,
            global,
            fimd,
            damp,
            train,
            cfg,
            ficabu_hw,
            baseline_hw,
            rng: Pcg32::seeded(0xedbe),
        }
    }

    /// Reseed the forget-batch sampler (used to decorrelate replicas).
    pub fn with_seed(mut self, seed: u64) -> EdgeServer {
        self.rng = Pcg32::seeded(seed);
        self
    }

    /// Build a replica from a `Send` spec — called inside the worker
    /// thread, because the compiled modules it creates are not `Send`.
    /// Replicas are re-entrant by construction: every engine buffer and
    /// counter is owned per instance, nothing is shared across workers.
    pub fn from_spec(spec: &WorkerSpec, worker_id: usize) -> Result<EdgeServer> {
        let rt = Runtime::from_env()?;
        let model = Model::load(&rt, spec.meta.clone())?;
        let fimd = FimdEngine::new(&rt, &spec.shared)?;
        let damp = DampEngine::new(&rt, &spec.shared)?;
        let tile = spec.meta.tile;
        Ok(EdgeServer::new(
            model,
            spec.params.clone(),
            spec.global.clone(),
            fimd,
            damp,
            spec.train.clone(),
            spec.cfg.clone(),
            FicabuProcessor::new(tile, spec.precision),
            BaselineProcessor::new(tile, spec.precision),
        )
        .with_seed(0xedbe ^ ((worker_id as u64) << 17)))
    }

    /// Execute one unlearning event against this replica's live
    /// parameter store and report quality + simulated hardware cost.
    /// `Summary::timing` is zeroed here; the dispatcher fills it.
    pub fn unlearn(&mut self, class: usize) -> Result<Summary> {
        let meta = &self.model.meta;
        if class >= meta.num_classes {
            anyhow::bail!("class {class} out of range ({} classes)", meta.num_classes);
        }
        let (x, labels) = self.train.forget_batch(class, meta.batch, &mut self.rng);
        let report: UnlearnReport = run_unlearning(
            &self.model,
            &mut self.params,
            &x,
            &labels,
            &self.global,
            &self.fimd,
            &self.damp,
            &self.cfg,
        )?;

        // post-edit quality readout on a subsample (edge-budget sized)
        let forget_idx = self.train.class_indices(class);
        let retain_idx: Vec<usize> = self
            .train
            .without_class(class)
            .into_iter()
            .step_by(4)
            .collect();
        let forget_acc =
            metrics::eval_accuracy(&self.model, &self.params, &self.train, &forget_idx)?;
        let retain_acc =
            metrics::eval_accuracy(&self.model, &self.params, &self.train, &retain_idx)?;

        // hardware cost: this run on FiCABU vs the SSD ledger on baseline
        // (same executed precision, so the f32-gradient lane penalty and
        // byte widths apply to both sides of the comparison)
        let fic = self.ficabu_hw.cost(&report);
        let ssd_ref_report = UnlearnReport {
            ledger: ssd_ledger(meta, meta.batch),
            fimd_elems: meta.total_params() as u64 * (meta.batch / meta.microbatch) as u64,
            damp_elems: meta.total_params() as u64,
            act_cache_bytes: report.act_cache_bytes,
            precision: report.precision,
            ..Default::default()
        };
        let ssd = self.baseline_hw.cost(&ssd_ref_report);

        Ok(Summary {
            class,
            forget_acc,
            retain_acc,
            stop_depth: report.stop_depth,
            macs_vs_ssd_pct: 100.0 * report.ledger.editing_total() as f64
                / ssd_ref_report.ledger.editing_total() as f64,
            sim_energy_mj: fic.energy_mj,
            sim_energy_vs_ssd_pct: 100.0 * fic.energy_mj / ssd.energy_mj,
            sim_ms: fic.seconds * 1e3,
            timing: Timing::default(),
        })
    }

    /// Serve requests from an iterator, sequentially, on the caller's
    /// thread — the single-device deployment of Fig. 1, kept for direct
    /// embedding. Returns one timed summary per request.
    pub fn serve_sequential(
        &mut self,
        classes: impl IntoIterator<Item = usize>,
    ) -> Vec<Result<Summary, String>> {
        classes
            .into_iter()
            .map(|class| {
                let t0 = Instant::now();
                self.unlearn(class)
                    .map(|mut s| {
                        s.timing =
                            Timing { queue_ms: 0.0, service_ms: t0.elapsed().as_secs_f64() * 1e3 };
                        s
                    })
                    .map_err(|e| format!("{e:#}"))
            })
            .collect()
    }
}

impl UnlearnService for EdgeServer {
    fn unlearn(&mut self, class: usize) -> Result<Summary> {
        EdgeServer::unlearn(self, class)
    }
}

#[cfg(test)]
mod tests {
    // Queue statistics are unit-tested in queue.rs; the dispatcher
    // (coalescing, shedding, drain, stats rollup) in tests/dispatch.rs
    // against a mock service; the full fleet end-to-end in
    // examples/edge_serving.rs and benches/bench_serve.rs.
}
