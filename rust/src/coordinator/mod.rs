//! Edge serving layer: typed forget requests over a multi-worker
//! unlearning fleet.
//!
//! The paper's Fig. 1 (right) deploys one Unlearning Engine on the edge
//! device. This module grows that shape into a serving fleet for heavy
//! forget-request traffic, speaking [`ForgetSpec`] end to end:
//!
//! ```text
//!  clients ──► Fleet::submit(spec) ──► admission control ──► bounded FIFO
//!                 │   (coalesce on canonical SpecKey,          │
//!                 │    shed on full queue)                     ▼
//!                 │                        workers 0..N (one thread each)
//!                 ▼                         ├─ UnlearnSession replica 0
//!          Reply receiver ◄── fan-out ──────┤   (own ParamStore + engines
//!          (Done | Failed |                 ├─ UnlearnSession replica 1
//!           Backpressure | Expired)         └─ ...          + Strategy)
//! ```
//!
//! * [`UnlearnSession`] (alias [`EdgeServer`]) is the per-worker core:
//!   one model, one parameter replica, one FIMD/Dampening engine pair,
//!   one hwsim processor pair, one pluggable
//!   [`Strategy`](crate::unlearn::Strategy). Compiled modules hold `Rc`
//!   handles (not `Send`), so replicas are built *inside* their worker
//!   thread from a `Send` [`WorkerSpec`].
//! * [`Fleet`] (see [`dispatch`]) owns the shared queue: requests whose
//!   canonical [`SpecKey`](crate::unlearn::SpecKey) matches a queued
//!   entry coalesce into a single execution with fan-out replies
//!   (`classes:4,1` and `classes:1,4` are one event), workers claim
//!   batched passes, a bounded queue sheds excess load with
//!   [`Reply::Backpressure`], and stale entries are shed against their
//!   deadline.
//! * [`QueueStats`] aggregates per-worker latency (mean/max plus
//!   p50/p95/p99 histograms for queue and service time) and merges into
//!   the fleet-wide rollup surfaced by [`Fleet::stats`] and the `serve`
//!   CLI.
//!
//! Replica semantics: each worker's parameter store drifts independently
//! as it applies edits — the fleet models N devices serving a shared
//! request stream, not N consistent copies of one store. Coalescing is
//! therefore exact (one execution, one store) while cross-worker
//! convergence is out of scope here (see ROADMAP sharding).

pub mod dispatch;
pub mod queue;
pub mod session;

pub use dispatch::{Fleet, FleetConfig, FleetStats, Pacing, Reply, UnlearnService, WorkerSpec};
pub use queue::{LatencyHistogram, QueueStats, Timing};
pub use session::{EdgeServer, UnlearnSession, UnlearnSessionBuilder};

use anyhow::Result;

use crate::unlearn::ForgetSpec;

/// Outcome summary of one served unlearning event.
#[derive(Debug, Clone)]
pub struct Summary {
    /// The canonical request this event executed.
    pub spec: ForgetSpec,
    pub forget_acc: f64,
    pub retain_acc: f64,
    pub stop_depth: Option<usize>,
    pub macs_vs_ssd_pct: f64,
    pub sim_energy_mj: f64,
    pub sim_energy_vs_ssd_pct: f64,
    /// Latency of this event on the simulated FiCABU processor
    /// (50 MHz prototype), from the hwsim pipeline model.
    pub sim_ms: f64,
    /// Filled in by the dispatcher: measured queue + service latency.
    pub timing: Timing,
}

impl UnlearnService for UnlearnSession {
    fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary> {
        self.forget(spec)
    }
}

#[cfg(test)]
mod tests {
    // Queue statistics are unit-tested in queue.rs; the dispatcher
    // (spec-key coalescing, shedding, drain, stats rollup) in
    // tests/dispatch.rs against a mock service; session + fleet
    // end-to-end over class / multi-class / sample specs in
    // tests/spec_e2e.rs, examples/edge_serving.rs and
    // benches/bench_serve.rs.
}
