//! Edge serving loop: the deployment shape of Fig. 1 (right).
//!
//! An edge device receives unlearning requests ("forget identity c") from
//! local producers (sensors/apps) and executes them on-device. PJRT client
//! handles are not `Send`, so the engine owns one OS thread — exactly one
//! Unlearning Engine, like the processor — and requests arrive over an
//! mpsc channel; each carries its own reply channel.

pub mod queue;

pub use queue::{QueueStats, Timing};

use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::fisher::{FimdEngine, Importance};
use crate::hwsim::{BaselineProcessor, FicabuProcessor};
use crate::metrics;
use crate::model::macs::ssd_ledger;
use crate::model::{Model, ParamStore};
use crate::unlearn::{run_unlearning, DampEngine, UnlearnConfig, UnlearnReport};
use crate::data::Dataset;
use crate::util::prng::Pcg32;

/// A request to the edge unlearning service.
pub enum Request {
    /// Forget one class/identity; reply with the outcome summary.
    Unlearn { class: usize, reply: Sender<Result<Summary, String>> },
    /// Read service statistics.
    Stats { reply: Sender<QueueStats> },
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct Summary {
    pub class: usize,
    pub forget_acc: f64,
    pub retain_acc: f64,
    pub stop_depth: Option<usize>,
    pub macs_vs_ssd_pct: f64,
    pub sim_energy_mj: f64,
    pub sim_energy_vs_ssd_pct: f64,
    pub timing: Timing,
}

/// Server state: one trained model + stored global importance + engines.
pub struct EdgeServer {
    pub model: Model,
    pub params: ParamStore,
    pub global: Importance,
    pub fimd: FimdEngine,
    pub damp: DampEngine,
    pub train: Dataset,
    pub cfg: UnlearnConfig,
    pub ficabu_hw: FicabuProcessor,
    pub baseline_hw: BaselineProcessor,
    pub rng: Pcg32,
    stats: QueueStats,
}

impl EdgeServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        model: Model,
        params: ParamStore,
        global: Importance,
        fimd: FimdEngine,
        damp: DampEngine,
        train: Dataset,
        cfg: UnlearnConfig,
        ficabu_hw: FicabuProcessor,
        baseline_hw: BaselineProcessor,
    ) -> EdgeServer {
        EdgeServer {
            model,
            params,
            global,
            fimd,
            damp,
            train,
            cfg,
            ficabu_hw,
            baseline_hw,
            rng: Pcg32::seeded(0xedbe),
            stats: QueueStats::default(),
        }
    }

    /// Serve until `Shutdown`. Each unlearning request mutates the live
    /// parameter store (the device's deployed model).
    pub fn serve(&mut self, rx: Receiver<(Instant, Request)>) -> Result<()> {
        while let Ok((enqueued_at, req)) = rx.recv() {
            match req {
                Request::Shutdown => break,
                Request::Stats { reply } => {
                    let _ = reply.send(self.stats.clone());
                }
                Request::Unlearn { class, reply } => {
                    let queue_ms = enqueued_at.elapsed().as_secs_f64() * 1e3;
                    let t0 = Instant::now();
                    let out = self.handle_unlearn(class, queue_ms, t0);
                    match &out {
                        Ok(s) => self.stats.record(&s.timing),
                        Err(_) => self.stats.failures += 1,
                    }
                    let _ = reply.send(out.map_err(|e| format!("{e:#}")));
                }
            }
        }
        Ok(())
    }

    fn handle_unlearn(&mut self, class: usize, queue_ms: f64, t0: Instant) -> Result<Summary> {
        let meta = &self.model.meta;
        if class >= meta.num_classes {
            anyhow::bail!("class {class} out of range ({} classes)", meta.num_classes);
        }
        let (x, labels) = self.train.forget_batch(class, meta.batch, &mut self.rng);
        let report: UnlearnReport = run_unlearning(
            &self.model,
            &mut self.params,
            &x,
            &labels,
            &self.global,
            &self.fimd,
            &self.damp,
            &self.cfg,
        )?;

        // post-edit quality readout on a subsample (edge-budget sized)
        let forget_idx = self.train.class_indices(class);
        let retain_idx: Vec<usize> = self
            .train
            .without_class(class)
            .into_iter()
            .step_by(4)
            .collect();
        let forget_acc =
            metrics::eval_accuracy(&self.model, &self.params, &self.train, &forget_idx)?;
        let retain_acc =
            metrics::eval_accuracy(&self.model, &self.params, &self.train, &retain_idx)?;

        // hardware cost: this run on FiCABU vs the SSD ledger on baseline
        // (same executed precision, so the f32-gradient lane penalty and
        // byte widths apply to both sides of the comparison)
        let fic = self.ficabu_hw.cost(&report);
        let ssd_ref_report = UnlearnReport {
            ledger: ssd_ledger(meta, meta.batch),
            fimd_elems: meta.total_params() as u64 * (meta.batch / meta.microbatch) as u64,
            damp_elems: meta.total_params() as u64,
            act_cache_bytes: report.act_cache_bytes,
            precision: report.precision,
            ..Default::default()
        };
        let ssd = self.baseline_hw.cost(&ssd_ref_report);
        let service_ms = t0.elapsed().as_secs_f64() * 1e3;

        Ok(Summary {
            class,
            forget_acc,
            retain_acc,
            stop_depth: report.stop_depth,
            macs_vs_ssd_pct: 100.0 * report.ledger.editing_total() as f64
                / ssd_ref_report.ledger.editing_total() as f64,
            sim_energy_mj: fic.energy_mj,
            sim_energy_vs_ssd_pct: 100.0 * fic.energy_mj / ssd.energy_mj,
            timing: Timing { queue_ms, service_ms },
        })
    }
}

#[cfg(test)]
mod tests {
    // The full server loop is exercised end-to-end by
    // `examples/edge_serving.rs` and the integration tests; unit tests here
    // cover the queue statistics (see queue.rs).
}
