//! Multi-worker dispatcher: admission control, coalescing, batching.
//!
//! The [`Fleet`] owns N worker threads behind one shared FIFO. Because
//! the compiled modules hold `Rc` handles (not `Send`), a worker's
//! engine stack is *built inside its thread* from a [`WorkerSpec`] —
//! plain `Send` data (meta, parameter replica, importance, dataset,
//! config). Each worker therefore owns a private [`EdgeServer`] replica
//! whose parameter store drifts independently as it serves edits.
//!
//! Request lifecycle:
//!
//! 1. **Admission** ([`Fleet::submit`]): a request whose canonical
//!    [`SpecKey`] matches an already-queued entry *coalesces* onto that
//!    entry (one execution, fan-out replies) — `classes:4,1,1`,
//!    `classes:1,4`, and a duplicate of either are one queue slot.
//!    Otherwise, a full queue sheds the request immediately with
//!    [`Reply::Backpressure`]; an open slot enqueues it.
//! 2. **Claim**: an idle worker claims up to `batch_max` entries in one
//!    lock acquisition (a *pass*), capped to its fair share of the
//!    backlog (`ceil(queue_len / workers)`) so a burst spreads across
//!    the fleet instead of riding one early waker. All queued requests
//!    share one [`UnlearnConfig`], so every pass is compatible by
//!    construction.
//! 3. **Deadline shed**: a claimed entry whose deadline has already
//!    passed is answered with [`Reply::Expired`] without touching the
//!    engine.
//! 4. **Service**: the worker runs the unlearning event, optionally
//!    paces the reply to the simulated device latency ([`Pacing`]), and
//!    fans the summary out to every coalesced requester.
//!
//! [`Fleet::shutdown`] stops admission, then lets the workers drain the
//! queue deterministically: every admitted request is answered before
//! the threads exit.

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{ModelMeta, SharedMeta};
use crate::coordinator::queue::{QueueStats, Timing};
use crate::coordinator::{EdgeServer, Summary};
use crate::data::Dataset;
use crate::fisher::Importance;
use crate::model::ParamStore;
use crate::runtime::Precision;
use crate::unlearn::{ForgetSpec, SpecKey, UnlearnConfig};
use crate::util::json::Json;

/// Outcome of one submitted request.
#[derive(Debug, Clone)]
pub enum Reply {
    /// The unlearning event ran; the summary is shared by every request
    /// coalesced into the execution.
    Done(Summary),
    /// The event ran and failed (the error is formatted).
    Failed(String),
    /// Shed at admission: the bounded queue was full. Retry later.
    Backpressure { queue_len: usize, queue_cap: usize },
    /// Shed at claim time: the deadline had already passed.
    Expired { missed_by_ms: f64 },
}

impl Reply {
    /// Stable machine-readable discriminant — the one contract shared by
    /// HTTP response bodies, CLI output, and the serving benches.
    pub fn code(&self) -> &'static str {
        match self {
            Reply::Done(_) => "done",
            Reply::Failed(_) => "failed",
            Reply::Backpressure { .. } => "backpressure",
            Reply::Expired { .. } => "expired",
        }
    }

    /// Wire body of this reply: `code` plus the variant's payload
    /// (`summary` for `done`, `error` for `failed`, queue occupancy for
    /// `backpressure`, `missed_by_ms` for `expired`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("code", Json::from(self.code()))];
        match self {
            Reply::Done(s) => fields.push(("summary", s.to_json())),
            Reply::Failed(e) => fields.push(("error", Json::string(e.clone()))),
            Reply::Backpressure { queue_len, queue_cap } => {
                fields.push(("queue_len", Json::from(*queue_len)));
                fields.push(("queue_cap", Json::from(*queue_cap)));
            }
            Reply::Expired { missed_by_ms } => {
                fields.push(("missed_by_ms", Json::from(*missed_by_ms)));
            }
        }
        Json::obj(fields)
    }
}

impl fmt::Display for Reply {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Reply::Done(s) => write!(f, "done ({})", s.spec),
            Reply::Failed(e) => write!(f, "failed: {e}"),
            Reply::Backpressure { queue_len, queue_cap } => {
                write!(f, "backpressure: queue {queue_len}/{queue_cap} — retry later")
            }
            Reply::Expired { missed_by_ms } => {
                write!(f, "expired: deadline missed by {missed_by_ms:.0} ms")
            }
        }
    }
}

/// Every non-`Done` reply is a serving error a caller may want to
/// propagate with `?` — `Error` makes `Err(reply.into())` and
/// `anyhow::Error::from(reply)` work without a bespoke error type.
impl std::error::Error for Reply {}

/// Worker pacing policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pacing {
    /// Reply as fast as the host computes (default).
    Host,
    /// Hold each worker to `max(simulated device latency, floor_ms)`:
    /// every worker stands in for one 50 MHz FiCABU device, so fleet
    /// throughput measures serving-layer scaling, not host GEMM speed.
    SimDevice { floor_ms: f64 },
}

/// Dispatcher tuning. `Default` = single worker, 32-deep queue, no
/// deadline, passes of up to 4, host pacing.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Worker (= replica) count.
    pub workers: usize,
    /// Bounded-queue capacity; admission beyond it sheds with
    /// [`Reply::Backpressure`].
    pub queue_cap: usize,
    /// Default deadline applied at admission (`None` = no deadline).
    pub deadline: Option<Duration>,
    /// Max entries one worker claims per pass.
    pub batch_max: usize,
    pub pacing: Pacing,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            workers: 1,
            queue_cap: 32,
            deadline: None,
            batch_max: 4,
            pacing: Pacing::Host,
        }
    }
}

/// Everything a worker thread needs to rebuild its `EdgeServer` replica
/// in-thread. All fields are plain (`Send`) data; the non-`Send`
/// compiled modules are constructed by the worker itself.
#[derive(Clone)]
pub struct WorkerSpec {
    pub meta: ModelMeta,
    pub shared: SharedMeta,
    pub params: ParamStore,
    pub global: Importance,
    pub train: Dataset,
    pub cfg: UnlearnConfig,
    pub precision: Precision,
}

/// The unlearning work a worker performs per request — implemented by
/// [`EdgeServer`] (= `UnlearnSession`) for production and by test
/// doubles for dispatcher tests. The spec a worker receives is already
/// canonical (it is the entry's coalescing key).
pub trait UnlearnService {
    fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary>;
}

/// Snapshot of fleet-wide serving statistics.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub workers: usize,
    /// Requests admitted as new queue entries.
    pub admitted: u64,
    /// Requests coalesced onto an already-queued entry.
    pub coalesced: u64,
    /// Requests shed at admission (queue full).
    pub shed_backpressure: u64,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    pub per_worker: Vec<QueueStats>,
}

impl FleetStats {
    /// Fleet-wide rollup of the per-worker stats.
    pub fn merged(&self) -> QueueStats {
        let mut total = QueueStats::default();
        for w in &self.per_worker {
            total.merge(w);
        }
        total
    }

    /// Wire form served by `GET /stats`: admission counters, the merged
    /// rollup, and the per-worker breakdown — the same field names
    /// `bench_serve` records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::from(self.workers)),
            ("admitted", Json::from(self.admitted as usize)),
            ("coalesced", Json::from(self.coalesced as usize)),
            ("shed_backpressure", Json::from(self.shed_backpressure as usize)),
            ("queue_depth", Json::from(self.queue_depth)),
            ("rollup", self.merged().to_json()),
            ("per_worker", Json::Arr(self.per_worker.iter().map(QueueStats::to_json).collect())),
        ])
    }
}

struct Entry {
    /// Canonical coalescing/routing key; `key.spec()` is what executes.
    key: SpecKey,
    replies: Vec<std::sync::mpsc::Sender<Reply>>,
    enqueued_at: Instant,
    deadline: Option<Instant>,
}

struct DispatchState {
    queue: VecDeque<Entry>,
    shutdown: bool,
    admitted: u64,
    coalesced: u64,
    shed_backpressure: u64,
    per_worker: Vec<QueueStats>,
}

struct Shared {
    cfg: FleetConfig,
    m: Mutex<DispatchState>,
    cv: Condvar,
}

/// N `EdgeServer` replicas behind one dispatcher. See the module docs
/// for the request lifecycle.
pub struct Fleet {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Fleet {
    /// Start a production fleet: each worker builds its own
    /// `EdgeServer` replica from `spec` inside its thread.
    pub fn start(spec: WorkerSpec, cfg: FleetConfig) -> Result<Fleet> {
        Self::start_with(cfg, move |wid| EdgeServer::from_spec(&spec, wid))
    }

    /// Start a fleet over any [`UnlearnService`] factory. The factory
    /// runs once per worker, *inside* the worker thread (the service
    /// itself need not be `Send`).
    pub fn start_with<S, F>(cfg: FleetConfig, factory: F) -> Result<Fleet>
    where
        S: UnlearnService + 'static,
        F: Fn(usize) -> Result<S> + Send + Sync + 'static,
    {
        if cfg.workers == 0 || cfg.queue_cap == 0 || cfg.batch_max == 0 {
            bail!(
                "fleet config: workers ({}), queue_cap ({}) and batch_max ({}) must all be >= 1",
                cfg.workers,
                cfg.queue_cap,
                cfg.batch_max
            );
        }
        let shared = Arc::new(Shared {
            m: Mutex::new(DispatchState {
                queue: VecDeque::new(),
                shutdown: false,
                admitted: 0,
                coalesced: 0,
                shed_backpressure: 0,
                per_worker: vec![QueueStats::default(); cfg.workers],
            }),
            cv: Condvar::new(),
            cfg,
        });
        let factory = Arc::new(factory);
        let (ack_tx, ack_rx) = channel::<Result<(), String>>();
        let mut handles = Vec::with_capacity(shared.cfg.workers);
        for wid in 0..shared.cfg.workers {
            let sh = Arc::clone(&shared);
            let f = Arc::clone(&factory);
            let ack = ack_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("ficabu-worker-{wid}"))
                .spawn(move || {
                    // Build the replica in-thread: compiled modules are
                    // not Send, only the spec travels. (`*f`: Arc has no
                    // Fn impl, the closure is called through the deref.)
                    let svc = match (*f)(wid) {
                        Ok(s) => {
                            let _ = ack.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = ack.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    // The factory (owning the WorkerSpec's parameter
                    // store, dataset, importance) is startup-only state:
                    // release it before serving so the last worker to
                    // finish startup frees the spec for the fleet's
                    // lifetime.
                    drop(f);
                    worker_loop(wid, &sh, svc);
                })?;
            handles.push(h);
        }
        drop(ack_tx);
        // Fail fast if any replica could not be built.
        let mut startup_err: Option<String> = None;
        for _ in 0..shared.cfg.workers {
            match ack_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => startup_err = Some("worker thread died during startup".to_string()),
            }
        }
        if let Some(e) = startup_err {
            {
                let mut st = shared.m.lock().unwrap();
                st.shutdown = true;
            }
            shared.cv.notify_all();
            for h in handles {
                let _ = h.join();
            }
            bail!("fleet startup failed: {e}");
        }
        Ok(Fleet { shared, handles })
    }

    /// Submit a forget request under the fleet's default deadline.
    /// Returns immediately; the reply arrives on the receiver.
    pub fn submit(&self, spec: ForgetSpec) -> Receiver<Reply> {
        self.submit_with_deadline(spec, self.shared.cfg.deadline)
    }

    /// Submit with an explicit deadline (`None` = never sheds).
    ///
    /// Admission control runs synchronously on the caller's thread: a
    /// request whose canonical [`SpecKey`] matches a *queued* entry
    /// coalesces (requests already being executed are not joined — the
    /// execution started before this request arrived); a full queue
    /// replies `Backpressure` without enqueueing.
    pub fn submit_with_deadline(
        &self,
        spec: ForgetSpec,
        deadline: Option<Duration>,
    ) -> Receiver<Reply> {
        let key = spec.key();
        let (tx, rx) = channel();
        let now = Instant::now();
        let abs_deadline = deadline.map(|d| now + d);
        let mut st = self.shared.m.lock().unwrap();
        if st.shutdown {
            let _ = tx.send(Reply::Failed("fleet is shutting down".to_string()));
            return rx;
        }
        if let Some(e) = st.queue.iter_mut().find(|e| e.key == key) {
            // Coalesce: one execution will fan out to every requester.
            // The entry keeps the laxest deadline so a late joiner
            // cannot get an earlier waiter shed.
            e.deadline = match (e.deadline, abs_deadline) {
                (Some(a), Some(b)) => Some(a.max(b)),
                _ => None,
            };
            e.replies.push(tx);
            st.coalesced += 1;
            return rx;
        }
        if st.queue.len() >= self.shared.cfg.queue_cap {
            st.shed_backpressure += 1;
            let _ = tx.send(Reply::Backpressure {
                queue_len: st.queue.len(),
                queue_cap: self.shared.cfg.queue_cap,
            });
            return rx;
        }
        st.queue.push_back(Entry {
            key,
            replies: vec![tx],
            enqueued_at: now,
            deadline: abs_deadline,
        });
        st.admitted += 1;
        drop(st);
        self.shared.cv.notify_one();
        rx
    }

    /// Point-in-time statistics snapshot.
    pub fn stats(&self) -> FleetStats {
        snapshot(&self.shared)
    }

    /// Stop admission, drain the queue (every admitted request is
    /// answered), join the workers, and return the final statistics.
    pub fn shutdown(mut self) -> Result<FleetStats> {
        self.stop_and_join()?;
        Ok(snapshot(&self.shared))
    }

    /// Signal shutdown and join every worker (all of them, even if some
    /// panicked, so the drain guarantee holds for the survivors); report
    /// a panic only after the whole fleet has stopped.
    fn stop_and_join(&mut self) -> Result<()> {
        {
            let mut st = self.shared.m.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let mut panicked = 0usize;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            bail!("{panicked} fleet worker(s) panicked");
        }
        Ok(())
    }
}

/// Dropping a live fleet must not park the worker threads forever in
/// `cv.wait` (and leak every replica): drain and join, swallowing any
/// worker panic — explicit [`Fleet::shutdown`] is the error-reporting
/// path.
impl Drop for Fleet {
    fn drop(&mut self) {
        let _ = self.stop_and_join();
    }
}

fn snapshot(sh: &Shared) -> FleetStats {
    let st = sh.m.lock().unwrap();
    FleetStats {
        workers: st.per_worker.len(),
        admitted: st.admitted,
        coalesced: st.coalesced,
        shed_backpressure: st.shed_backpressure,
        queue_depth: st.queue.len(),
        per_worker: st.per_worker.clone(),
    }
}

fn worker_loop<S: UnlearnService>(wid: usize, sh: &Shared, mut svc: S) {
    loop {
        let mut batch: Vec<Entry> = Vec::new();
        {
            let mut st = sh.m.lock().unwrap();
            loop {
                if !st.queue.is_empty() {
                    // Fair-share claim: never take more than this
                    // worker's share of the backlog, so one early waker
                    // cannot drain a burst while its peers sit idle —
                    // batching only amortizes lock traffic once every
                    // worker is saturated.
                    let share = st.queue.len().div_ceil(st.per_worker.len());
                    let n = sh.cfg.batch_max.min(share);
                    batch.extend(st.queue.drain(..n));
                    st.per_worker[wid].record_batch(batch.len());
                    break;
                }
                if st.shutdown {
                    return;
                }
                st = sh.cv.wait(st).unwrap();
            }
        }
        for entry in batch {
            serve_entry(wid, sh, &mut svc, entry);
        }
    }
}

fn serve_entry<S: UnlearnService>(wid: usize, sh: &Shared, svc: &mut S, e: Entry) {
    let queue_ms = e.enqueued_at.elapsed().as_secs_f64() * 1e3;
    if let Some(dl) = e.deadline {
        let now = Instant::now();
        if now > dl {
            let missed_by_ms = now.duration_since(dl).as_secs_f64() * 1e3;
            sh.m.lock().unwrap().per_worker[wid].record_shed();
            for tx in e.replies {
                let _ = tx.send(Reply::Expired { missed_by_ms });
            }
            return;
        }
    }
    let t0 = Instant::now();
    let out = svc.unlearn(e.key.spec());
    let mut service_ms = t0.elapsed().as_secs_f64() * 1e3;
    if let Pacing::SimDevice { floor_ms } = sh.cfg.pacing {
        if let Ok(s) = &out {
            let target_ms = s.sim_ms.max(floor_ms);
            if target_ms > service_ms {
                std::thread::sleep(Duration::from_secs_f64((target_ms - service_ms) / 1e3));
            }
            service_ms = t0.elapsed().as_secs_f64() * 1e3;
        }
    }
    let timing = Timing { queue_ms, service_ms };
    sh.m.lock().unwrap().per_worker[wid].record(&timing, out.is_ok());
    match out {
        Ok(mut s) => {
            s.timing = timing;
            for tx in e.replies {
                let _ = tx.send(Reply::Done(s.clone()));
            }
        }
        Err(err) => {
            let msg = format!("{err:#}");
            for tx in e.replies {
                let _ = tx.send(Reply::Failed(msg.clone()));
            }
        }
    }
}
