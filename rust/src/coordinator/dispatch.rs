//! Multi-worker dispatcher: admission control, coalescing, batching,
//! model-addressed routing.
//!
//! The [`Fleet`] owns N worker threads behind one shared FIFO. Two
//! serving shapes exist:
//!
//! - **Legacy replica fleets** ([`Fleet::start`]): each worker builds a
//!   private [`EdgeServer`] replica from a [`WorkerSpec`] — its
//!   parameter store drifts independently as it serves edits. Compiled
//!   modules are immutable `Send + Sync` programs, so the per-worker
//!   build cost is mostly cloning the parameter bag (module loads hit
//!   the shared runtime cache).
//! - **Registry fleets** ([`Fleet::start_registry`]): workers are
//!   O(1)-startup [`RegistryWorker`]s borrowing `Arc`-shared compiled
//!   models from a [`ModelRegistry`] — one fleet hosts many models,
//!   graphs compile once per process (never per worker), and every
//!   request edits a private copy-on-write overlay of the addressed
//!   model's frozen master.
//!
//! Request lifecycle:
//!
//! 1. **Admission** ([`Fleet::submit_to`]; [`Fleet::submit`] resolves
//!    the fleet's sole model first): a request whose [`BatchKey`] —
//!    `(model, config fingerprint, canonical SpecKey)` — matches an
//!    already-queued entry *coalesces* onto that entry (one execution,
//!    fan-out replies) — `classes:4,1,1`, `classes:1,4`, and a
//!    duplicate of either are one queue slot, but the same spec for two
//!    tenants stays two entries. Otherwise, a full queue sheds the
//!    request immediately with [`Reply::Backpressure`]; an open slot
//!    enqueues it.
//! 2. **Claim**: an idle worker claims up to `batch_max` entries in one
//!    lock acquisition (a *pass*), capped to its fair share of the
//!    backlog (`ceil(queue_len / workers)`) so a burst spreads across
//!    the fleet instead of riding one early waker. A pass may freely
//!    mix models and configs: each entry carries its whole routing key,
//!    so there is no fleet-wide config-compatibility contract (the old
//!    `UnlearnConfig: PartialEq` batch gate is retired).
//! 3. **Deadline shed**: a claimed entry whose deadline has already
//!    passed is answered with [`Reply::Expired`] without touching the
//!    engine.
//! 4. **Service**: the worker runs the unlearning event, optionally
//!    paces the reply to the simulated device latency ([`Pacing`]), and
//!    fans the summary out to every coalesced requester.
//!
//! [`Fleet::shutdown`] stops admission, then lets the workers drain the
//! queue deterministically: every admitted request is answered before
//! the threads exit.
//!
//! **Supervision.** A panic inside the unlearning engine is not fatal:
//! `serve_entry` catches it, answers every fanned-out requester with
//! [`Reply::Failed`] (panic payload in the message), pushes the rest of
//! the claimed batch back to the queue front, and the worker thread —
//! which doubles as its own supervisor — discards the (possibly
//! corrupted) replica and rebuilds a fresh one from the retained
//! factory under capped exponential backoff (10 ms · 2^n, capped at
//! 1 s). After [`FleetConfig::respawn_giveup`] consecutive build
//! failures the worker is declared dead; when every worker is dead the
//! queue is drained with `Failed` replies and later submissions fail at
//! admission. [`FleetStats::alive`] plus per-worker `panics`/`respawns`
//! counters expose the supervision state to `/stats` and `/healthz`.
//!
//! **Durability.** A fleet started via [`Fleet::start_durable`] writes a
//! crash-safe audit trail (see [`wal`](crate::coordinator::wal)):
//! admission appends an fsync'd `Accepted` ledger record *before* the
//! caller gets a queue slot (a ledger error fails the request — no slot
//! without a record; the append itself runs with the dispatch lock
//! released, held to a reservation, so disk latency never stalls the
//! workers' claim path), workers append `Completed` records and
//! checkpoint the post-unlearn [`ParamStore`] every `checkpoint_every`
//! successful completions *before* replying, and startup replays every
//! entry whose completion (or covering checkpoint scope) did not make
//! it to disk. A replica is *tainted* — barred from checkpointing —
//! when its store and the ledger can disagree: after a respawn (the
//! fresh replica lost the edits its predecessor served) and after a
//! `Done` completion append fails (the store holds an edit the ledger
//! will replay); recovery replays the affected entries onto the last
//! good checkpoint instead. The exact contract (recovered store bitwise
//! equal to an uninterrupted run) holds for single-worker fleets, the
//! paper's one-device deployment. Multi-worker durable fleets never
//! checkpoint at all — replicas drift independently, so no single store
//! covers the ledger — and recovery therefore replays the full ledger
//! (every accepted entry without a `failed`/`expired` completion) onto
//! factory parameters; the ledger remains an exact record of
//! accepted/completed work. Registry fleets
//! ([`Fleet::start_registry_durable`]) never checkpoint either — their
//! masters are frozen and per-request deltas are discarded, so
//! durability is ledger-replay only: every `Accepted` record carries
//! its model id, recovery routes replays through the registry, and a
//! ledger referencing an unregistered model fails startup loudly.

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{ModelMeta, SharedMeta};
use crate::coordinator::queue::{QueueStats, Timing};
use crate::coordinator::registry::{ModelId, ModelInfo, ModelRegistry, RegistryWorker};
use crate::coordinator::wal::{
    config_fingerprint, Disposition, Durability, DurabilityConfig, DurabilityStats,
};
use crate::coordinator::{EdgeServer, Summary};
use crate::data::Dataset;
use crate::fisher::Importance;
use crate::model::ParamStore;
use crate::runtime::{meta_fingerprint, Precision};
use crate::unlearn::{ForgetSpec, SpecKey, UnlearnConfig};
use crate::util::json::Json;

/// Outcome of one submitted request.
#[derive(Debug, Clone)]
pub enum Reply {
    /// The unlearning event ran; the summary is shared by every request
    /// coalesced into the execution.
    Done(Summary),
    /// The event ran and failed (the error is formatted).
    Failed(String),
    /// Shed at admission: the bounded queue was full. Retry later.
    Backpressure { queue_len: usize, queue_cap: usize },
    /// Shed at claim time: the deadline had already passed.
    Expired { missed_by_ms: f64 },
}

impl Reply {
    /// Stable machine-readable discriminant — the one contract shared by
    /// HTTP response bodies, CLI output, and the serving benches.
    pub fn code(&self) -> &'static str {
        match self {
            Reply::Done(_) => "done",
            Reply::Failed(_) => "failed",
            Reply::Backpressure { .. } => "backpressure",
            Reply::Expired { .. } => "expired",
        }
    }

    /// Wire body of this reply: `code` plus the variant's payload
    /// (`summary` for `done`, `error` for `failed`, queue occupancy for
    /// `backpressure`, `missed_by_ms` for `expired`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("code", Json::from(self.code()))];
        match self {
            Reply::Done(s) => fields.push(("summary", s.to_json())),
            Reply::Failed(e) => fields.push(("error", Json::string(e.clone()))),
            Reply::Backpressure { queue_len, queue_cap } => {
                fields.push(("queue_len", Json::from(*queue_len)));
                fields.push(("queue_cap", Json::from(*queue_cap)));
            }
            Reply::Expired { missed_by_ms } => {
                fields.push(("missed_by_ms", Json::from(*missed_by_ms)));
            }
        }
        Json::obj(fields)
    }
}

impl fmt::Display for Reply {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Reply::Done(s) => write!(f, "done ({})", s.spec),
            Reply::Failed(e) => write!(f, "failed: {e}"),
            Reply::Backpressure { queue_len, queue_cap } => {
                write!(f, "backpressure: queue {queue_len}/{queue_cap} — retry later")
            }
            Reply::Expired { missed_by_ms } => {
                write!(f, "expired: deadline missed by {missed_by_ms:.0} ms")
            }
        }
    }
}

/// Every non-`Done` reply is a serving error a caller may want to
/// propagate with `?` — `Error` makes `Err(reply.into())` and
/// `anyhow::Error::from(reply)` work without a bespoke error type.
impl std::error::Error for Reply {}

/// Worker pacing policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pacing {
    /// Reply as fast as the host computes (default).
    Host,
    /// Hold each worker to `max(simulated device latency, floor_ms)`:
    /// every worker stands in for one 50 MHz FiCABU device, so fleet
    /// throughput measures serving-layer scaling, not host GEMM speed.
    SimDevice { floor_ms: f64 },
}

/// Dispatcher tuning. `Default` = single worker, 32-deep queue, no
/// deadline, passes of up to 4, host pacing.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Worker (= replica) count.
    pub workers: usize,
    /// Bounded-queue capacity; admission beyond it sheds with
    /// [`Reply::Backpressure`].
    pub queue_cap: usize,
    /// Default deadline applied at admission (`None` = no deadline).
    pub deadline: Option<Duration>,
    /// Max entries one worker claims per pass.
    pub batch_max: usize,
    pub pacing: Pacing,
    /// Consecutive replica-build failures after which a panicked
    /// worker's supervisor stops respawning and declares it dead.
    pub respawn_giveup: usize,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            workers: 1,
            queue_cap: 32,
            deadline: None,
            batch_max: 4,
            pacing: Pacing::Host,
            respawn_giveup: 5,
        }
    }
}

/// Supervision backoff: base · 2^attempt, capped.
const RESPAWN_BACKOFF_BASE_MS: u64 = 10;
const RESPAWN_BACKOFF_CAP_MS: u64 = 1000;

/// Lifecycle of one worker replica as seen by the supervisor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WorkerStatus {
    Alive,
    /// Panicked; its supervisor is rebuilding the replica.
    Respawning,
    /// Respawn gave up (or the thread exited); never serves again.
    Dead,
}

/// Everything a worker thread needs to rebuild its `EdgeServer` replica
/// in-thread. All fields are plain (`Send`) data; the non-`Send`
/// compiled modules are constructed by the worker itself.
#[derive(Clone)]
pub struct WorkerSpec {
    pub meta: ModelMeta,
    pub shared: SharedMeta,
    pub params: ParamStore,
    pub global: Importance,
    pub train: Dataset,
    pub cfg: UnlearnConfig,
    pub precision: Precision,
}

/// Coalescing/batch key of one queue entry: which model, under which
/// operating point, forgetting what. Two requests share an execution
/// iff all three halves match — the same spec for two tenants, or the
/// same tenant across a config change, stays two entries. This key is
/// the whole batch-compatibility story: a claimed pass mixes keys
/// freely because each entry routes itself.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub model: ModelId,
    /// [`config_fingerprint`] of the model's `UnlearnConfig`.
    pub config_hash: u64,
    /// Canonical spec; `spec.spec()` is what executes.
    pub spec: SpecKey,
}

/// The unlearning work a worker performs per request — implemented by
/// [`EdgeServer`] (= `UnlearnSession`) and [`RegistryWorker`] for
/// production and by test doubles for dispatcher tests. The spec a
/// worker receives is already canonical (it is the entry's coalescing
/// key).
pub trait UnlearnService {
    fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary>;

    /// Model-addressed entry point — what the dispatcher calls. The
    /// default ignores the model id and serves the service's only
    /// model, so single-model services and test doubles implement just
    /// [`UnlearnService::unlearn`]; [`RegistryWorker`] overrides this
    /// to route through its registry.
    fn unlearn_model(&mut self, _model: &ModelId, spec: &ForgetSpec) -> Result<Summary> {
        self.unlearn(spec)
    }

    /// The replica's live parameter store, when it has one — what a
    /// durable fleet checkpoints after a completed pass. Test doubles
    /// without real parameters keep the default `None` (their
    /// completions are still ledgered; only checkpoints are skipped).
    /// Registry workers also keep the default: their masters are frozen
    /// and per-request deltas die with the summary, so there is nothing
    /// a checkpoint could cover.
    fn params(&self) -> Option<&ParamStore> {
        None
    }
}

/// Snapshot of fleet-wide serving statistics.
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub workers: usize,
    /// Workers currently alive (not panicked-and-respawning, not dead).
    /// `alive < workers` is the degraded state `/healthz` reports as 503.
    pub alive: usize,
    /// Requests admitted as new queue entries.
    pub admitted: u64,
    /// Requests coalesced onto an already-queued entry.
    pub coalesced: u64,
    /// Requests shed at admission (queue full).
    pub shed_backpressure: u64,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    pub per_worker: Vec<QueueStats>,
    /// Per-model serving rollup, keyed by model id, in first-served
    /// order. One entry per model that has had a request claimed.
    pub per_model: Vec<(ModelId, QueueStats)>,
    /// Ledger/checkpoint counters (`None` on a non-durable fleet).
    pub durability: Option<DurabilityStats>,
}

impl FleetStats {
    /// Fleet-wide rollup of the per-worker stats.
    pub fn merged(&self) -> QueueStats {
        let mut total = QueueStats::default();
        for w in &self.per_worker {
            total.merge(w);
        }
        total
    }

    /// Wire form served by `GET /stats`: admission counters, the merged
    /// rollup, and the per-worker breakdown — the same field names
    /// `bench_serve` records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::from(self.workers)),
            ("alive", Json::from(self.alive)),
            ("admitted", Json::from(self.admitted as usize)),
            ("coalesced", Json::from(self.coalesced as usize)),
            ("shed_backpressure", Json::from(self.shed_backpressure as usize)),
            ("queue_depth", Json::from(self.queue_depth)),
            ("rollup", self.merged().to_json()),
            ("per_worker", Json::Arr(self.per_worker.iter().map(QueueStats::to_json).collect())),
            (
                "per_model",
                Json::Obj(
                    self.per_model
                        .iter()
                        .map(|(id, q)| (id.to_string(), q.to_json()))
                        .collect(),
                ),
            ),
            ("durability", self.durability.as_ref().map_or(Json::Null, DurabilityStats::to_json)),
        ])
    }
}

struct Entry {
    /// Coalescing/routing key; `key.spec.spec()` is what executes, on
    /// the model `key.model` addresses.
    key: BatchKey,
    replies: Vec<std::sync::mpsc::Sender<Reply>>,
    enqueued_at: Instant,
    deadline: Option<Instant>,
    /// Ledger seqs of every durable submission coalesced into this
    /// entry (empty on a non-durable fleet). Each gets its own
    /// `Completed` record when the entry is answered.
    wal_seqs: Vec<u64>,
}

struct DispatchState {
    queue: VecDeque<Entry>,
    shutdown: bool,
    admitted: u64,
    coalesced: u64,
    shed_backpressure: u64,
    /// Queue slots held by admissions whose ledger append is in flight
    /// (the dispatch lock is released around the fsync). Counted
    /// against `queue_cap` so concurrent submitters cannot oversubscribe
    /// the queue while a slow disk stalls phase 2.
    reserved: usize,
    per_worker: Vec<QueueStats>,
    /// Per-model serving stats, first-served order (find-or-insert).
    per_model: Vec<(ModelId, QueueStats)>,
    status: Vec<WorkerStatus>,
}

struct Shared {
    cfg: FleetConfig,
    m: Mutex<DispatchState>,
    cv: Condvar,
    /// Durable ledger + checkpoints (`None` = in-memory-only fleet).
    dur: Option<Arc<Durability>>,
    /// Fingerprint of the fleet's single `UnlearnConfig` on a
    /// registry-less fleet (0 for service factories without one);
    /// registry fleets resolve the hash per model at admission.
    config_hash: u64,
    /// Model registry (`None` = single-model fleet addressed as
    /// [`ModelId::default`]).
    registry: Option<Arc<ModelRegistry>>,
    /// `GET /models` row for a registry-less production fleet,
    /// synthesized from its [`WorkerSpec`] (`None` for service-factory
    /// fleets, whose listing is empty).
    static_info: Option<ModelInfo>,
}

/// Per-replica durability state, owned by the worker thread.
#[derive(Default)]
struct ReplicaDur {
    /// The replica must never checkpoint again: its store and the
    /// ledger can disagree. Set after a respawn (the fresh replica lost
    /// its predecessor's served edits, so a checkpoint would claim
    /// completions it does not contain) and after a `Done` completion
    /// append fails (the store holds an edit the ledger will replay, so
    /// a checkpoint would get it applied twice). Recovery replays the
    /// affected entries instead.
    tainted: bool,
    /// Whether this replica completed at least one pass successfully
    /// (gates the final checkpoint at shutdown).
    done_any: bool,
}

/// `GET /models` row for a registry-less production fleet: the sole
/// model is addressed as [`ModelId::default`], its spec key is the
/// fingerprint of the worker spec's graph metadata, and it is always
/// warm (every replica holds it compiled).
fn static_model_info(spec: &WorkerSpec, config_hash: u64) -> ModelInfo {
    ModelInfo {
        id: ModelId::default(),
        spec_key: format!("{:016x}", meta_fingerprint(&spec.meta)),
        config_hash,
        precision: spec.precision,
        warm: true,
    }
}

/// N `EdgeServer` replicas behind one dispatcher. See the module docs
/// for the request lifecycle.
pub struct Fleet {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Fleet {
    /// Start a production fleet: each worker builds its own
    /// `EdgeServer` replica from `spec` inside its thread. The fleet
    /// hosts the single model [`ModelId::default`].
    pub fn start(spec: WorkerSpec, cfg: FleetConfig) -> Result<Fleet> {
        let config_hash = config_fingerprint(&spec.cfg);
        let info = static_model_info(&spec, config_hash);
        Self::start_inner(
            cfg,
            move |wid| EdgeServer::from_spec(&spec, wid),
            None,
            config_hash,
            Vec::new(),
            None,
            Some(info),
        )
    }

    /// Start a registry fleet: one [`RegistryWorker`] per worker thread,
    /// all borrowing `Arc`-shared compiled models from `registry`.
    /// Worker construction is O(1) — graphs compile once per process on
    /// first use ([`ModelRegistry::builds`] pins this). Address requests
    /// with [`Fleet::submit_to`]; [`Fleet::submit`] works while the
    /// registry holds exactly one model.
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: FleetConfig) -> Result<Fleet> {
        let reg = Arc::clone(&registry);
        Self::start_inner(
            cfg,
            move |wid| Ok(RegistryWorker::new(Arc::clone(&reg), wid)),
            None,
            0,
            Vec::new(),
            Some(registry),
            None,
        )
    }

    /// Durable registry fleet: ledger-replay-only durability (registry
    /// masters are frozen and per-request deltas are discarded, so
    /// there is no drifting store to checkpoint — any checkpoint found
    /// in `dcfg.dir` is ignored). Every replayed entry is routed
    /// through `registry`; a ledger referencing an unregistered model
    /// fails startup loudly.
    pub fn start_registry_durable(
        registry: Arc<ModelRegistry>,
        cfg: FleetConfig,
        dcfg: DurabilityConfig,
    ) -> Result<Fleet> {
        let rec = Durability::open_or_recover(&dcfg)?;
        let reg = Arc::clone(&registry);
        Self::start_inner(
            cfg,
            move |wid| Ok(RegistryWorker::new(Arc::clone(&reg), wid)),
            Some(Arc::new(rec.durability)),
            0,
            rec.replay,
            Some(registry),
            None,
        )
    }

    /// Start a durable production fleet: open-or-recover the write-ahead
    /// ledger in `dcfg.dir`, seed every replica from the newest valid
    /// parameter checkpoint (when one exists), and re-enqueue the
    /// recovered replay set through normal admission. With
    /// `cfg.workers > 1` the fleet never writes checkpoints and
    /// recovery replays the full ledger. See the module docs
    /// ("Durability") for the contract.
    pub fn start_durable(spec: WorkerSpec, cfg: FleetConfig, dcfg: DurabilityConfig) -> Result<Fleet> {
        let config_hash = config_fingerprint(&spec.cfg);
        let rec = Durability::open_or_recover(&dcfg)?;
        let mut spec = spec;
        if let Some(params) = rec.params {
            params.validate(&spec.meta)?;
            spec.params = params;
        }
        let info = static_model_info(&spec, config_hash);
        Self::start_inner(
            cfg,
            move |wid| EdgeServer::from_spec(&spec, wid),
            Some(Arc::new(rec.durability)),
            config_hash,
            rec.replay,
            None,
            Some(info),
        )
    }

    /// Durable fleet over an arbitrary service factory (dispatcher tests
    /// and benches). Checkpoint recovery still runs, but the recovered
    /// parameters are discarded — the factory owns replica construction
    /// — and `Accepted` records carry a zero config fingerprint.
    pub fn start_with_durable<S, F>(cfg: FleetConfig, factory: F, dcfg: DurabilityConfig) -> Result<Fleet>
    where
        S: UnlearnService + 'static,
        F: Fn(usize) -> Result<S> + Send + Sync + 'static,
    {
        let rec = Durability::open_or_recover(&dcfg)?;
        Self::start_inner(cfg, factory, Some(Arc::new(rec.durability)), 0, rec.replay, None, None)
    }

    /// Start a fleet over any [`UnlearnService`] factory. The factory
    /// runs once per worker, *inside* the worker thread (the service
    /// itself need not be `Send`).
    pub fn start_with<S, F>(cfg: FleetConfig, factory: F) -> Result<Fleet>
    where
        S: UnlearnService + 'static,
        F: Fn(usize) -> Result<S> + Send + Sync + 'static,
    {
        Self::start_inner(cfg, factory, None, 0, Vec::new(), None, None)
    }

    fn start_inner<S, F>(
        cfg: FleetConfig,
        factory: F,
        dur: Option<Arc<Durability>>,
        config_hash: u64,
        replay: Vec<(u64, ModelId, ForgetSpec)>,
        registry: Option<Arc<ModelRegistry>>,
        static_info: Option<ModelInfo>,
    ) -> Result<Fleet>
    where
        S: UnlearnService + 'static,
        F: Fn(usize) -> Result<S> + Send + Sync + 'static,
    {
        if cfg.workers == 0 || cfg.queue_cap == 0 || cfg.batch_max == 0 || cfg.respawn_giveup == 0
        {
            bail!(
                "fleet config: workers ({}), queue_cap ({}), batch_max ({}) and \
                 respawn_giveup ({}) must all be >= 1",
                cfg.workers,
                cfg.queue_cap,
                cfg.batch_max,
                cfg.respawn_giveup
            );
        }
        // Recovered entries enter the queue before any worker spawns —
        // replay rides the normal claim/serve path, just with no reply
        // receivers. They count as admitted: they were, in a prior life.
        // Every replayed model id is validated first: an unroutable
        // ledger must fail startup loudly, not drop admitted requests.
        let now = Instant::now();
        let mut queue = VecDeque::new();
        for (seq, model, spec) in replay {
            let entry_hash = match &registry {
                Some(reg) => {
                    if !reg.contains(&model) {
                        bail!(
                            "recovery: ledger entry (seq {seq}) addresses model {model}, \
                             which is not registered; register it or move the ledger aside"
                        );
                    }
                    reg.config_hash(&model).unwrap_or(0)
                }
                None => {
                    if model != ModelId::default() {
                        bail!(
                            "recovery: ledger entry (seq {seq}) addresses model {model}, \
                             but this fleet hosts only the default model; start a registry \
                             fleet or move the ledger aside"
                        );
                    }
                    config_hash
                }
            };
            queue.push_back(Entry {
                key: BatchKey { model, config_hash: entry_hash, spec: spec.key() },
                replies: Vec::new(),
                enqueued_at: now,
                deadline: None,
                wal_seqs: vec![seq],
            });
        }
        let admitted = queue.len() as u64;
        let shared = Arc::new(Shared {
            m: Mutex::new(DispatchState {
                queue,
                shutdown: false,
                admitted,
                coalesced: 0,
                shed_backpressure: 0,
                reserved: 0,
                per_worker: vec![QueueStats::default(); cfg.workers],
                per_model: Vec::new(),
                status: vec![WorkerStatus::Alive; cfg.workers],
            }),
            cv: Condvar::new(),
            cfg,
            dur,
            config_hash,
            registry,
            static_info,
        });
        let factory = Arc::new(factory);
        let (ack_tx, ack_rx) = channel::<Result<(), String>>();
        let mut handles = Vec::with_capacity(shared.cfg.workers);
        for wid in 0..shared.cfg.workers {
            let sh = Arc::clone(&shared);
            let f = Arc::clone(&factory);
            let ack = ack_tx.clone();
            let h = std::thread::Builder::new()
                .name(format!("ficabu-worker-{wid}"))
                .spawn(move || {
                    // Build the service in-thread. (`*f`: Arc has no
                    // Fn impl, the closure is called through the deref.)
                    // The factory is retained for the fleet's lifetime:
                    // it is the respawn source after a panic.
                    let mut svc = match (*f)(wid) {
                        Ok(s) => {
                            let _ = ack.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = ack.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                    // The worker thread is its own supervisor: serve
                    // until shutdown, and on an engine panic discard the
                    // replica and rebuild under backoff.
                    let mut rdur = ReplicaDur::default();
                    loop {
                        match worker_loop(wid, &sh, &mut svc, &mut rdur) {
                            WorkerExit::Shutdown => {
                                final_checkpoint(&sh, &svc, &rdur);
                                return;
                            }
                            WorkerExit::Panicked => {
                                set_status(&sh, wid, WorkerStatus::Respawning);
                                match respawn(wid, &sh, &*f) {
                                    Some(fresh) => {
                                        svc = fresh;
                                        // the fresh replica starts from
                                        // factory params: edits served by
                                        // its predecessor are gone
                                        rdur.tainted = true;
                                        let mut st = sh.m.lock().unwrap();
                                        st.status[wid] = WorkerStatus::Alive;
                                        st.per_worker[wid].respawns += 1;
                                    }
                                    None => {
                                        declare_dead(&sh, wid);
                                        return;
                                    }
                                }
                            }
                        }
                    }
                })?;
            handles.push(h);
        }
        drop(ack_tx);
        // Fail fast if any replica could not be built.
        let mut startup_err: Option<String> = None;
        for _ in 0..shared.cfg.workers {
            match ack_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => startup_err = Some(e),
                Err(_) => startup_err = Some("worker thread died during startup".to_string()),
            }
        }
        if let Some(e) = startup_err {
            {
                let mut st = shared.m.lock().unwrap();
                st.shutdown = true;
            }
            shared.cv.notify_all();
            for h in handles {
                let _ = h.join();
            }
            bail!("fleet startup failed: {e}");
        }
        Ok(Fleet { shared, handles })
    }

    /// Whether `id` is servable by this fleet: registered in the
    /// registry, or the default id on a single-model fleet.
    pub fn has_model(&self, id: &ModelId) -> bool {
        match &self.shared.registry {
            Some(reg) => reg.contains(id),
            None => *id == ModelId::default(),
        }
    }

    /// The model a model-less submission resolves to: the registry's
    /// sole entry, or the default id on a registry-less fleet. `None`
    /// when the registry hosts zero or several models — the caller must
    /// address one explicitly.
    pub fn sole_model(&self) -> Option<ModelId> {
        match &self.shared.registry {
            Some(reg) => reg.sole(),
            None => Some(ModelId::default()),
        }
    }

    /// `GET /models` rows: the registry listing, or the synthesized row
    /// of a registry-less production fleet (empty for service-factory
    /// fleets, which have no model metadata to list).
    pub fn models_info(&self) -> Vec<ModelInfo> {
        match &self.shared.registry {
            Some(reg) => reg.list(),
            None => self.shared.static_info.iter().cloned().collect(),
        }
    }

    /// The batch key's config half for `id` (registry lookup, or the
    /// fleet-wide fingerprint on a registry-less fleet).
    fn config_hash_for(&self, id: &ModelId) -> u64 {
        match &self.shared.registry {
            Some(reg) => reg.config_hash(id).unwrap_or(0),
            None => self.shared.config_hash,
        }
    }

    /// The admission deadline applied when a submission does not carry
    /// one ([`FleetConfig::deadline`]); `None` = no deadline.
    pub fn default_deadline(&self) -> Option<Duration> {
        self.shared.cfg.deadline
    }

    /// Submit a forget request under the fleet's default deadline.
    /// Returns immediately; the reply arrives on the receiver.
    pub fn submit(&self, spec: ForgetSpec) -> Receiver<Reply> {
        self.submit_with_deadline(spec, self.shared.cfg.deadline)
    }

    /// Model-less submission: resolves [`Fleet::sole_model`] and fails
    /// immediately (`Reply::Failed`) when the fleet hosts several
    /// models — ambiguity is the caller's to resolve, via
    /// [`Fleet::submit_to`].
    pub fn submit_with_deadline(
        &self,
        spec: ForgetSpec,
        deadline: Option<Duration>,
    ) -> Receiver<Reply> {
        match self.sole_model() {
            Some(model) => self.submit_to(model, spec, deadline),
            None => {
                let (tx, rx) = channel();
                let _ = tx.send(Reply::Failed(
                    "fleet hosts multiple models; address one explicitly".to_string(),
                ));
                rx
            }
        }
    }

    /// Submit a forget request against a specific model, with an
    /// explicit deadline (`None` = never sheds).
    ///
    /// Admission control runs synchronously on the caller's thread: a
    /// request whose [`BatchKey`] — (model, config fingerprint,
    /// canonical [`SpecKey`]) — matches a *queued* entry coalesces
    /// (requests already being executed are not joined — the execution
    /// started before this request arrived); a full queue replies
    /// `Backpressure` without enqueueing; an unknown model fails
    /// immediately (the HTTP layer turns this case into a 404 before
    /// submitting).
    ///
    /// On a durable fleet the `Accepted` record — carrying the model id
    /// — is fsync'd *before* the caller gets its slot; if the ledger
    /// cannot be written the request fails closed (accepting it would
    /// make the crash-replay guarantee a lie). Refused requests —
    /// shutdown, dead fleet, backpressure, unknown model — never reach
    /// the ledger. The append itself runs with the dispatch lock
    /// *released* (the slot is held by a reservation meanwhile), so
    /// fsync latency stalls at most other admissions, never the workers'
    /// claim path or stats snapshots.
    pub fn submit_to(
        &self,
        model: ModelId,
        spec: ForgetSpec,
        deadline: Option<Duration>,
    ) -> Receiver<Reply> {
        let (tx, rx) = channel();
        if !self.has_model(&model) {
            let _ = tx.send(Reply::Failed(format!("unknown model {model}")));
            return rx;
        }
        let key =
            BatchKey { config_hash: self.config_hash_for(&model), model, spec: spec.key() };
        let now = Instant::now();
        let abs_deadline = deadline.map(|d| now + d);
        // Phase 1: admission decision under the dispatch lock — refuse
        // (nothing ledgered) or reserve a slot. No disk I/O here.
        {
            let mut st = self.shared.m.lock().unwrap();
            if let Some(reply) = admission_refusal(&st, &self.shared.cfg, &key) {
                if matches!(reply, Reply::Backpressure { .. }) {
                    st.shed_backpressure += 1;
                }
                let _ = tx.send(reply);
                return rx;
            }
            st.reserved += 1;
        }
        // Phase 2: durable admission, dispatch lock released. The
        // ledger serializes appends under its own lock.
        let wal_seq = match self.log_accepted(&key, deadline) {
            Ok(seq) => seq,
            Err(reply) => {
                self.shared.m.lock().unwrap().reserved -= 1;
                let _ = tx.send(reply);
                return rx;
            }
        };
        // Phase 3: take the slot. The queue may have changed during the
        // append: a coalesce target may have appeared (join it) or been
        // claimed (enqueue a fresh entry — the queue can transiently
        // exceed `queue_cap` by the coalescing admissions in flight,
        // since a ledgered request must not be refused).
        let mut st = self.shared.m.lock().unwrap();
        st.reserved -= 1;
        if st.shutdown || st.status.iter().all(|s| *s == WorkerStatus::Dead) {
            // The fleet stopped while the record was being fsync'd. The
            // `Accepted` entry is durable with no completion, so the
            // next durable start replays it; tell the caller it was not
            // served now.
            let _ = tx.send(Reply::Failed(if st.shutdown {
                "fleet is shutting down".to_string()
            } else {
                "no live fleet workers (every replica died and respawn gave up)".to_string()
            }));
            return rx;
        }
        if let Some(e) = st.queue.iter_mut().find(|e| e.key == key) {
            // Coalesce: one execution will fan out to every requester.
            // The entry keeps the laxest deadline so a late joiner
            // cannot get an earlier waiter shed. On a durable fleet the
            // joiner still gets its own ledger record — the ledger is a
            // per-request audit trail, not a per-execution one.
            e.deadline = match (e.deadline, abs_deadline) {
                (Some(a), Some(b)) => Some(a.max(b)),
                _ => None,
            };
            e.replies.push(tx);
            e.wal_seqs.extend(wal_seq);
            st.coalesced += 1;
            return rx;
        }
        st.queue.push_back(Entry {
            key,
            replies: vec![tx],
            enqueued_at: now,
            deadline: abs_deadline,
            wal_seqs: wal_seq.into_iter().collect(),
        });
        st.admitted += 1;
        drop(st);
        self.shared.cv.notify_one();
        rx
    }

    /// Durable-admission helper: append an `Accepted` record — model
    /// id, spec, and the model's config fingerprint — when the fleet
    /// has a ledger. `Ok(None)` on a non-durable fleet; `Err` carries
    /// the fail-closed reply for a ledger write failure.
    fn log_accepted(
        &self,
        key: &BatchKey,
        deadline: Option<Duration>,
    ) -> std::result::Result<Option<u64>, Reply> {
        let Some(dur) = &self.shared.dur else { return Ok(None) };
        match dur.log_accepted(&key.model, key.spec.spec(), key.config_hash, deadline) {
            Ok(seq) => Ok(Some(seq)),
            Err(e) => Err(Reply::Failed(format!("{e:#}"))),
        }
    }

    /// Point-in-time statistics snapshot.
    pub fn stats(&self) -> FleetStats {
        snapshot(&self.shared)
    }

    /// The live audit chain of `model`, oldest link first (tainted
    /// links included) — what `GET /models/{id}/audit` serves. Empty on
    /// a non-durable fleet (no chain is kept) and for models with no
    /// completed forgets.
    pub fn audit_chain(&self, model: &ModelId) -> Vec<crate::audit::AuditRecord> {
        self.shared.dur.as_ref().map(|d| d.audit_chain(model)).unwrap_or_default()
    }

    /// Stop admission, drain the queue (every admitted request is
    /// answered), join the workers, and return the final statistics.
    pub fn shutdown(mut self) -> Result<FleetStats> {
        self.stop_and_join()?;
        Ok(snapshot(&self.shared))
    }

    /// Signal shutdown and join every worker (all of them, even if some
    /// panicked, so the drain guarantee holds for the survivors); report
    /// a panic only after the whole fleet has stopped.
    fn stop_and_join(&mut self) -> Result<()> {
        {
            let mut st = self.shared.m.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let mut panicked = 0usize;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        // Engine panics are caught in-thread, so the only way entries
        // survive the workers' drain is every worker having died (or a
        // dispatcher bug); answer them rather than dropping the senders.
        let leftovers: Vec<Entry> = {
            let mut st = self.shared.m.lock().unwrap();
            st.queue.drain(..).collect()
        };
        for e in leftovers {
            for tx in e.replies {
                let _ = tx.send(Reply::Failed(
                    "fleet stopped before this request was served".to_string(),
                ));
            }
        }
        if panicked > 0 {
            bail!("{panicked} fleet worker(s) panicked");
        }
        Ok(())
    }
}

/// Dropping a live fleet must not park the worker threads forever in
/// `cv.wait` (and leak every replica): drain and join, swallowing any
/// worker panic — explicit [`Fleet::shutdown`] is the error-reporting
/// path.
impl Drop for Fleet {
    fn drop(&mut self) {
        let _ = self.stop_and_join();
    }
}

/// Phase-1 admission check, under the dispatch lock: the refusal reply
/// when this request cannot be admitted right now, `None` when it may
/// proceed (coalesce or reserve). A request with a queued coalesce
/// target is never backpressure-shed — joining needs no slot.
fn admission_refusal(st: &DispatchState, cfg: &FleetConfig, key: &BatchKey) -> Option<Reply> {
    if st.shutdown {
        return Some(Reply::Failed("fleet is shutting down".to_string()));
    }
    if st.status.iter().all(|s| *s == WorkerStatus::Dead) {
        return Some(Reply::Failed(
            "no live fleet workers (every replica died and respawn gave up)".to_string(),
        ));
    }
    let coalesces = st.queue.iter().any(|e| e.key == *key);
    if !coalesces && st.queue.len() + st.reserved >= cfg.queue_cap {
        return Some(Reply::Backpressure { queue_len: st.queue.len(), queue_cap: cfg.queue_cap });
    }
    None
}

fn snapshot(sh: &Shared) -> FleetStats {
    let st = sh.m.lock().unwrap();
    FleetStats {
        workers: st.per_worker.len(),
        alive: st.status.iter().filter(|s| **s == WorkerStatus::Alive).count(),
        admitted: st.admitted,
        coalesced: st.coalesced,
        shed_backpressure: st.shed_backpressure,
        queue_depth: st.queue.len(),
        per_worker: st.per_worker.clone(),
        per_model: st.per_model.clone(),
        durability: sh.dur.as_ref().map(|d| d.stats()),
    }
}

/// Find-or-insert the per-model stats row for `id`.
fn model_stats<'a>(
    per_model: &'a mut Vec<(ModelId, QueueStats)>,
    id: &ModelId,
) -> &'a mut QueueStats {
    if let Some(i) = per_model.iter().position(|(m, _)| m == id) {
        return &mut per_model[i].1;
    }
    per_model.push((id.clone(), QueueStats::default()));
    &mut per_model.last_mut().unwrap().1
}

/// Why a worker's serve loop returned to its supervisor.
enum WorkerExit {
    Shutdown,
    /// The service panicked mid-request; the replica must be rebuilt.
    Panicked,
}

/// What happened to one served entry.
enum ServeOutcome {
    Answered,
    Panicked,
}

fn set_status(sh: &Shared, wid: usize, status: WorkerStatus) {
    sh.m.lock().unwrap().status[wid] = status;
}

/// Mark `wid` dead; if it was the last non-dead worker, drain the queue
/// with `Failed` replies — nothing will ever claim those entries again.
fn declare_dead(sh: &Shared, wid: usize) {
    let leftovers: Vec<Entry> = {
        let mut st = sh.m.lock().unwrap();
        st.status[wid] = WorkerStatus::Dead;
        if st.status.iter().all(|s| *s == WorkerStatus::Dead) {
            st.queue.drain(..).collect()
        } else {
            Vec::new()
        }
    };
    for e in leftovers {
        for tx in e.replies {
            let _ = tx.send(Reply::Failed(
                "no live fleet workers (every replica died and respawn gave up)".to_string(),
            ));
        }
    }
}

/// Rebuild a replica after a panic: sleep the capped exponential
/// backoff, then try the factory — a factory error *or panic* counts as
/// one consecutive failure. Returns `None` after
/// [`FleetConfig::respawn_giveup`] failures or on fleet shutdown.
fn respawn<S, F>(wid: usize, sh: &Shared, f: &F) -> Option<S>
where
    F: Fn(usize) -> Result<S>,
{
    for attempt in 0..sh.cfg.respawn_giveup {
        let ms = RESPAWN_BACKOFF_BASE_MS
            .saturating_mul(1u64 << attempt.min(20) as u32)
            .min(RESPAWN_BACKOFF_CAP_MS);
        std::thread::sleep(Duration::from_millis(ms));
        if sh.m.lock().unwrap().shutdown {
            return None;
        }
        // `respawn` fault seam: lets chaos tests and CI force build
        // failures without a failing factory.
        let built = catch_unwind(AssertUnwindSafe(|| {
            crate::testkit::faults::hit("respawn").and_then(|()| f(wid))
        }));
        if let Ok(Ok(svc)) = built {
            return Some(svc);
        }
    }
    None
}

/// Flush a final checkpoint at clean shutdown so a restart needs no
/// replay. Skipped for multi-worker fleets (replicas drift; no single
/// store covers the ledger), tainted replicas (see
/// [`ReplicaDur::tainted`]), replicas that completed nothing, services
/// without parameters, and when the cadence already checkpointed the
/// current ledger scope.
fn final_checkpoint<S: UnlearnService>(sh: &Shared, svc: &S, rd: &ReplicaDur) {
    let Some(dur) = &sh.dur else { return };
    if sh.cfg.workers > 1 || rd.tainted || !rd.done_any || dur.checkpoint_current() {
        return;
    }
    let Some(store) = svc.params() else { return };
    if let Err(e) = dur.write_checkpoint(store) {
        eprintln!("ficabu: final checkpoint failed: {e:#}");
    }
}

fn worker_loop<S: UnlearnService>(
    wid: usize,
    sh: &Shared,
    svc: &mut S,
    rd: &mut ReplicaDur,
) -> WorkerExit {
    loop {
        let mut batch: Vec<Entry> = Vec::new();
        {
            let mut st = sh.m.lock().unwrap();
            loop {
                if !st.queue.is_empty() {
                    // Fair-share claim: never take more than this
                    // worker's share of the backlog, so one early waker
                    // cannot drain a burst while its peers sit idle —
                    // batching only amortizes lock traffic once every
                    // worker is saturated.
                    let share = st.queue.len().div_ceil(st.per_worker.len());
                    let n = sh.cfg.batch_max.min(share);
                    batch.extend(st.queue.drain(..n));
                    st.per_worker[wid].record_batch(batch.len());
                    break;
                }
                if st.shutdown {
                    return WorkerExit::Shutdown;
                }
                st = sh.cv.wait(st).unwrap();
            }
        }
        let mut it = batch.into_iter();
        while let Some(entry) = it.next() {
            if let ServeOutcome::Panicked = serve_entry(wid, sh, svc, rd, entry) {
                // the replica may be corrupted: hand the rest of the
                // claimed batch back (in order, at the front) for the
                // respawned replica or a peer to serve
                let rest: Vec<Entry> = it.collect();
                if !rest.is_empty() {
                    let mut st = sh.m.lock().unwrap();
                    for e in rest.into_iter().rev() {
                        st.queue.push_front(e);
                    }
                    drop(st);
                    sh.cv.notify_all();
                }
                return WorkerExit::Panicked;
            }
        }
    }
}

/// Best-effort text of a panic payload for the `Failed` reply.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Ledger a non-`Done` completion (durable fleets only). Failed and
/// expired entries changed no parameters — the engine is transactional
/// — so they are completions that recovery must *not* replay.
fn log_completion_unchanged(sh: &Shared, seqs: &[u64], disposition: Disposition, rolled_back: bool) {
    if let Some(dur) = &sh.dur {
        if !seqs.is_empty() {
            dur.log_completed(seqs, disposition, rolled_back, -1.0, -1.0);
        }
    }
}

fn serve_entry<S: UnlearnService>(
    wid: usize,
    sh: &Shared,
    svc: &mut S,
    rd: &mut ReplicaDur,
    e: Entry,
) -> ServeOutcome {
    let queue_ms = e.enqueued_at.elapsed().as_secs_f64() * 1e3;
    if let Some(dl) = e.deadline {
        let now = Instant::now();
        if now > dl {
            let missed_by_ms = now.duration_since(dl).as_secs_f64() * 1e3;
            {
                let mut st = sh.m.lock().unwrap();
                st.per_worker[wid].record_shed();
                model_stats(&mut st.per_model, &e.key.model).record_shed();
            }
            log_completion_unchanged(sh, &e.wal_seqs, Disposition::Expired, false);
            for tx in e.replies {
                let _ = tx.send(Reply::Expired { missed_by_ms });
            }
            return ServeOutcome::Answered;
        }
    }
    let t0 = Instant::now();
    // Panic isolation: a panicking engine answers its requesters and
    // costs one replica, never the reply channels or the whole fleet.
    let call = catch_unwind(AssertUnwindSafe(|| svc.unlearn_model(&e.key.model, e.key.spec.spec())));
    let out = match call {
        Ok(result) => result,
        Err(payload) => {
            let service_ms = t0.elapsed().as_secs_f64() * 1e3;
            let timing = Timing { queue_ms, service_ms };
            {
                // the in-flight request counts as a failure: it held the
                // engine for its full service time and got an error reply
                let mut st = sh.m.lock().unwrap();
                st.per_worker[wid].record(&timing, false);
                st.per_worker[wid].panics += 1;
                let ms = model_stats(&mut st.per_model, &e.key.model);
                ms.record(&timing, false);
                ms.panics += 1;
            }
            // the engine's journal restored the segment pre-images
            // before the panic propagated: rolled_back is truthful
            log_completion_unchanged(sh, &e.wal_seqs, Disposition::Failed, true);
            let msg =
                format!("worker {wid} panicked mid-request: {}", panic_message(&*payload));
            for tx in e.replies {
                let _ = tx.send(Reply::Failed(msg.clone()));
            }
            return ServeOutcome::Panicked;
        }
    };
    let mut service_ms = t0.elapsed().as_secs_f64() * 1e3;
    if let Pacing::SimDevice { floor_ms } = sh.cfg.pacing {
        if let Ok(s) = &out {
            let target_ms = s.sim_ms.max(floor_ms);
            if target_ms > service_ms {
                std::thread::sleep(Duration::from_secs_f64((target_ms - service_ms) / 1e3));
            }
            service_ms = t0.elapsed().as_secs_f64() * 1e3;
        }
    }
    let timing = Timing { queue_ms, service_ms };
    {
        let mut st = sh.m.lock().unwrap();
        st.per_worker[wid].record(&timing, out.is_ok());
        model_stats(&mut st.per_model, &e.key.model).record(&timing, out.is_ok());
    }
    match out {
        Ok(mut s) => {
            // the batch key is authoritative for the reply's tenancy
            // fields, whatever the service stamped
            s.model = e.key.model.clone();
            s.config_hash = e.key.config_hash;
            s.timing = timing;
            s.wal_seq = e.wal_seqs.iter().copied().min();
            // Durable ordering: the audit chain link, then `Completed`
            // records, then (when due) the covering checkpoint, then
            // the replies. Completion-before-checkpoint means a crash
            // between the two replays onto the *previous* checkpoint
            // (exactly-once parameter state); checkpoint-before-reply
            // means an answered `done` is never silently lost. A crash
            // before the reply re-runs the entry — at-least-once toward
            // the caller, exactly-once on disk.
            if let Some(dur) = &sh.dur {
                if !e.wal_seqs.is_empty() {
                    let (logged, _link) = dur.log_completed_audited(&s, &e.wal_seqs);
                    rd.done_any = true;
                    if !logged.logged {
                        // The store now holds an edit the ledger will
                        // replay; any future checkpoint from this
                        // replica would get the pass applied twice (see
                        // ReplicaDur::tainted). Recovery replays the
                        // entry onto the last good checkpoint instead.
                        rd.tainted = true;
                        eprintln!(
                            "ficabu: worker {wid} replica tainted (completion not ledgered); \
                             checkpointing disabled until restart"
                        );
                    }
                    // Checkpoints are single-worker only: with several
                    // replicas drifting independently no one store
                    // covers the ledger, so a multi-worker durable
                    // fleet relies on full replay instead.
                    if logged.checkpoint_due && !rd.tainted && sh.cfg.workers == 1 {
                        if let Some(store) = svc.params() {
                            if let Err(err) = dur.write_checkpoint(store) {
                                eprintln!("ficabu: checkpoint failed (serving continues): {err:#}");
                            }
                        }
                    }
                }
            }
            for tx in e.replies {
                let _ = tx.send(Reply::Done(s.clone()));
            }
        }
        Err(err) => {
            log_completion_unchanged(sh, &e.wal_seqs, Disposition::Failed, true);
            let msg = format!("{err:#}");
            for tx in e.replies {
                let _ = tx.send(Reply::Failed(msg.clone()));
            }
        }
    }
    ServeOutcome::Answered
}
