//! Durable write-ahead ledger for the serving fleet.
//!
//! The ledger turns the fleet from a cache into a system of record: a
//! forget request is *accepted* only after an [`Record::Accepted`] entry
//! is on disk (length-prefixed, CRC32-checksummed, `fsync`'d), and is
//! *completed* only once the matching [`Record::Completed`] entry is —
//! so a power loss or `kill -9` can lose in-memory state, never the
//! fact that a request was admitted. [`Durability::open_or_recover`]
//! reloads the newest valid parameter checkpoint
//! ([`checkpoint`](crate::coordinator::checkpoint)) and re-enqueues
//! every accepted-but-not-completed request through the normal fleet
//! admission path.
//!
//! # On-disk layout
//!
//! One file per ledger (`wal.log` inside the `--durable` directory):
//!
//! ```text
//! header:  "FICABUW2" | generation u64 LE | crc32(generation bytes) u32 LE
//! record:  len u32 LE | crc32(payload) u32 LE | payload (len bytes)
//!
//! payload (Accepted):  0x01 | seq u64 | config_hash u64 |
//!                      deadline_ms f64 (NaN = none) |
//!                      model_len u32 | model id bytes |
//!                      spec_len u32 | canonical spec string bytes
//! payload (Completed): 0x02 | seq u64 | disposition u8 | rolled_back u8 |
//!                      forget_acc f64 | retain_acc f64
//! ```
//!
//! `FICABUW2` added the model id to `Accepted` records (multi-tenant
//! registry serving). A `FICABUW1` ledger predates model-addressed
//! records, so its entries cannot be routed: [`read_ledger`] refuses it
//! loudly instead of silently treating it as lost.
//!
//! All integers are little-endian. Every append is one
//! `write_all` + `fsync` (`File::sync_data`), in admission order.
//!
//! # Torn-write semantics
//!
//! A crash can leave at most one partial frame at the *tail* of the
//! file (appends are sequential and synced). On open, records are
//! scanned front to back and the scan stops at the first frame that is
//! short (fewer than 8 header bytes or fewer than `len` payload bytes),
//! has an implausible length (0 or > 16 MiB), fails its CRC32, or does
//! not decode to a known record type. Everything before that point is
//! the durable prefix; everything at and after it is discarded —
//! [`Wal::open_append`] physically truncates the file there, and
//! recovery rewrites the ledger wholesale. A torn or corrupt record can
//! therefore only ever drop the *suffix* it begins, never a record
//! before it, and a partially-written `Accepted` entry is equivalent to
//! the request never having been admitted (its caller never got a queue
//! slot: the slot is granted only after the `fsync` returns).
//!
//! # Recovery contract
//!
//! A checkpoint embeds its exact *scope*: the covering sequence number
//! `C` (the highest seq assigned when the snapshot was taken) plus the
//! `pending` list — every seq that was accepted but had no completion
//! on disk at that instant. The scope is snapshotted atomically under
//! the ledger's append lock, so a completion that races the checkpoint
//! is either inside the scope or listed as pending — never silently
//! claimed. This matters because completions are not ordered by seq:
//! a request coalesced onto an earlier queue entry completes (with a
//! high seq) while an entry admitted between them (lower seq) is still
//! queued, so "everything `<= C`" alone would claim edits the
//! checkpoint does not contain. Against the newest valid checkpoint of
//! the *same ledger generation* (`C = 0`, empty pending, when there is
//! no checkpoint or it is from an older generation), an accepted entry
//! is re-enqueued when it has no completion record, or when it
//! completed successfully with `seq > C` or `seq` in the pending list
//! (its edits are not in the checkpoint and were lost with the
//! process). Entries that completed as `failed` or `expired` changed
//! no parameters (the engine is transactional) and were answered, so
//! they are not replayed. Replay is idempotent per (model id, canonical
//! [`SpecKey`](crate::unlearn::SpecKey)): duplicates collapse to one
//! entry — two tenants forgetting the same class stay distinct — and
//! the forget batch of a request is a pure function of (worker seed,
//! spec), so replaying an event reproduces the same edit. Recovery then *rewrites* the ledger atomically (tempfile +
//! fsync + rename) with a bumped generation containing one fresh
//! `Accepted` record per replayed entry — so a second crash before the
//! replays complete recovers them again.
//!
//! # Audit chain
//!
//! A durable fleet also keeps the hash-chained audit log
//! (`audit.log`, see [`crate::audit`]) beside the ledger. A successful
//! completion goes through [`Durability::log_completed_audited`]: the
//! audit link is appended *first*, then the WAL `Completed` records,
//! both under one lock — so a crash leaves at most one trailing audit
//! link whose completion is not durable. Recovery drops exactly those
//! stale trailing links (their executions replay and re-derive them;
//! the per-record `wal_gen` ties a link to the generation being
//! recovered, so links from older generations — whose seqs the fresh
//! ledger reuses — are never touched), which is why a `kill -9` cannot
//! fork the chain: the replayed execution re-appends a link with the
//! same hashed core.
//!
//! Fault seams for chaos tests: `wal_append` (every ledger append),
//! `checkpoint` (every checkpoint write), `replay` (every re-enqueued
//! entry during recovery), `audit_append` (every audit chain append) —
//! see [`testkit::faults`](crate::testkit::faults).

use std::collections::{BTreeSet, HashMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::audit::{self, AuditRecord, ChainHead};
use crate::coordinator::checkpoint;
use crate::coordinator::registry::ModelId;
use crate::coordinator::Summary;
use crate::model::ParamStore;
use crate::testkit::faults;
use crate::unlearn::{ForgetSpec, UnlearnConfig};
use crate::util::json::Json;

/// Ledger file name inside the durable directory.
pub const LEDGER_FILE: &str = "wal.log";

const LEDGER_MAGIC: &[u8; 8] = b"FICABUW2";
/// Pre-registry magic: `Accepted` records carried no model id. Refused
/// loudly — see the module docs.
const LEDGER_MAGIC_V1: &[u8; 8] = b"FICABUW1";
const HEADER_LEN: u64 = 8 + 8 + 4;
/// Upper bound on one record payload — anything larger is treated as
/// corruption (the largest legitimate payload is a sample-level spec).
const MAX_RECORD: u32 = 16 << 20;

// --- CRC32 (IEEE 802.3, reflected) -------------------------------------

fn crc32_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        t
    })
}

/// CRC32 checksum (IEEE 802.3 polynomial) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ u32::from(b)) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// --- records ------------------------------------------------------------

/// How a completed entry left the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// The unlearning pass committed its edits.
    Done,
    /// The pass errored or panicked; the replica rolled back.
    Failed,
    /// Shed at claim time (deadline passed); the engine never ran.
    Expired,
}

impl Disposition {
    fn code(self) -> u8 {
        match self {
            Disposition::Done => 0,
            Disposition::Failed => 1,
            Disposition::Expired => 2,
        }
    }

    fn from_code(c: u8) -> Result<Disposition> {
        Ok(match c {
            0 => Disposition::Done,
            1 => Disposition::Failed,
            2 => Disposition::Expired,
            _ => bail!("unknown disposition code {c}"),
        })
    }
}

/// One ledger entry. `Accepted` precedes the caller's queue slot;
/// `Completed` follows the pass outcome (and precedes the reply).
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    Accepted {
        seq: u64,
        /// Which registered model the request addresses (the default id
        /// for a registry-less fleet). Recovery routes the replay
        /// through the registry, so a ledger referencing an
        /// unregistered model fails startup loudly.
        model: ModelId,
        /// Canonical request (the coalescing key's spec).
        spec: ForgetSpec,
        /// Fingerprint of the addressed model's [`UnlearnConfig`] at
        /// admission — an audit field; recovery does not interpret it.
        config_hash: u64,
        /// Admission deadline in ms (`None` = no deadline). Replayed
        /// entries are re-admitted without one: the original deadline
        /// predates the crash and the regulator wants completion.
        deadline_ms: Option<f64>,
    },
    Completed {
        seq: u64,
        disposition: Disposition,
        rolled_back: bool,
        /// Post-edit accuracy readouts (`-1.0` when the pass did not
        /// produce them, i.e. any non-`Done` disposition).
        forget_acc: f64,
        retain_acc: f64,
    },
}

impl Record {
    pub fn seq(&self) -> u64 {
        match self {
            Record::Accepted { seq, .. } | Record::Completed { seq, .. } => *seq,
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Record::Accepted { seq, model, spec, config_hash, deadline_ms } => {
                b.push(1u8);
                b.extend_from_slice(&seq.to_le_bytes());
                b.extend_from_slice(&config_hash.to_le_bytes());
                b.extend_from_slice(&deadline_ms.unwrap_or(f64::NAN).to_le_bytes());
                let m = model.as_str();
                b.extend_from_slice(&(m.len() as u32).to_le_bytes());
                b.extend_from_slice(m.as_bytes());
                let s = spec.to_string();
                b.extend_from_slice(&(s.len() as u32).to_le_bytes());
                b.extend_from_slice(s.as_bytes());
            }
            Record::Completed { seq, disposition, rolled_back, forget_acc, retain_acc } => {
                b.push(2u8);
                b.extend_from_slice(&seq.to_le_bytes());
                b.push(disposition.code());
                b.push(u8::from(*rolled_back));
                b.extend_from_slice(&forget_acc.to_le_bytes());
                b.extend_from_slice(&retain_acc.to_le_bytes());
            }
        }
        b
    }

    fn decode(payload: &[u8]) -> Result<Record> {
        let mut pos = 0usize;
        let tag = *take(payload, &mut pos, 1)?.first().unwrap();
        Ok(match tag {
            1 => {
                let seq = read_u64(payload, &mut pos)?;
                let config_hash = read_u64(payload, &mut pos)?;
                let ms = read_f64(payload, &mut pos)?;
                let m = read_u32(payload, &mut pos)? as usize;
                let raw = take(payload, &mut pos, m)?;
                let model = std::str::from_utf8(raw).context("model id is not utf-8")?;
                let n = read_u32(payload, &mut pos)? as usize;
                let raw = take(payload, &mut pos, n)?;
                let text = std::str::from_utf8(raw).context("spec is not utf-8")?;
                Record::Accepted {
                    seq,
                    model: ModelId::new(model)?,
                    spec: ForgetSpec::parse(text)?,
                    config_hash,
                    deadline_ms: if ms.is_nan() { None } else { Some(ms) },
                }
            }
            2 => {
                let seq = read_u64(payload, &mut pos)?;
                let disposition = Disposition::from_code(*take(payload, &mut pos, 1)?.first().unwrap())?;
                let rolled_back = *take(payload, &mut pos, 1)?.first().unwrap() != 0;
                let forget_acc = read_f64(payload, &mut pos)?;
                let retain_acc = read_f64(payload, &mut pos)?;
                Record::Completed { seq, disposition, rolled_back, forget_acc, retain_acc }
            }
            t => bail!("unknown record type {t}"),
        })
    }
}

fn take<'a>(b: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > b.len() {
        bail!("record truncated at byte {pos}");
    }
    let s = &b[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

fn read_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let r = take(b, pos, 4)?;
    Ok(u32::from_le_bytes([r[0], r[1], r[2], r[3]]))
}

fn read_u64(b: &[u8], pos: &mut usize) -> Result<u64> {
    let r = take(b, pos, 8)?;
    Ok(u64::from_le_bytes(r.try_into().unwrap()))
}

fn read_f64(b: &[u8], pos: &mut usize) -> Result<f64> {
    Ok(f64::from_bits(read_u64(b, pos)?))
}

// --- ledger scan --------------------------------------------------------

/// Result of scanning a ledger file under the torn-write rules (see the
/// module docs): the valid record prefix plus where it ends.
#[derive(Debug)]
pub struct LedgerScan {
    pub generation: u64,
    pub records: Vec<Record>,
    /// Byte length of the valid prefix (header included).
    pub valid_len: u64,
    /// Whether bytes past `valid_len` were found (torn tail/corruption).
    pub truncated: bool,
}

/// Scan `path`, stopping at the first torn or corrupt frame. An
/// unreadable *header* yields an empty generation-0 scan (the whole
/// file is treated as lost; recovery rewrites it with a bumped
/// generation).
pub fn read_ledger(path: &Path) -> Result<LedgerScan> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading ledger {}", path.display()))?;
    if bytes.len() >= 8 && &bytes[..8] == LEDGER_MAGIC_V1 {
        bail!(
            "ledger {} is FICABUW1 (pre-registry): its records carry no model id and \
             cannot be routed; migrate or remove it before serving",
            path.display()
        );
    }
    let header_ok = bytes.len() >= HEADER_LEN as usize
        && &bytes[..8] == LEDGER_MAGIC
        && crc32(&bytes[8..16]) == u32::from_le_bytes(bytes[16..20].try_into().unwrap());
    if !header_ok {
        return Ok(LedgerScan { generation: 0, records: Vec::new(), valid_len: 0, truncated: true });
    }
    let generation = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let mut records = Vec::new();
    let mut pos = HEADER_LEN as usize;
    loop {
        if pos + 8 > bytes.len() {
            break; // clean end (pos == len) or short frame header
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if len == 0 || len > MAX_RECORD {
            break;
        }
        let end = pos + 8 + len as usize;
        if end > bytes.len() {
            break; // torn payload
        }
        let payload = &bytes[pos + 8..end];
        if crc32(payload) != crc {
            break;
        }
        let Ok(rec) = Record::decode(payload) else {
            break; // checksummed but unknown shape: stop, same as torn
        };
        records.push(rec);
        pos = end;
    }
    Ok(LedgerScan {
        generation,
        records,
        valid_len: pos as u64,
        truncated: pos < bytes.len(),
    })
}

/// Atomically replace the ledger at `path` with a fresh one holding
/// `records` under `generation` (tempfile + fsync + rename + dir fsync).
pub fn write_replacing(path: &Path, generation: u64, records: &[Record]) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(LEDGER_MAGIC);
    buf.extend_from_slice(&generation.to_le_bytes());
    buf.extend_from_slice(&crc32(&generation.to_le_bytes()).to_le_bytes());
    for rec in records {
        frame_into(&mut buf, rec);
    }
    let tmp = path.with_extension("log.tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    sync_dir(path.parent().unwrap_or(Path::new(".")));
    Ok(())
}

fn frame_into(buf: &mut Vec<u8>, rec: &Record) {
    let payload = rec.encode();
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
}

/// Best-effort directory fsync so a rename survives power loss. Shared
/// with the audit log and atomic parameter saves.
pub(crate) fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

// --- the ledger handle --------------------------------------------------

struct WalInner {
    file: File,
    next_seq: u64,
    /// Accepted seqs with no completion record on disk — the `pending`
    /// half of a checkpoint's scope. Kept under the same lock as the
    /// appends so scope snapshots are consistent with the file.
    outstanding: BTreeSet<u64>,
}

/// Append handle over one ledger file. Appends are serialized through
/// an internal lock and each is `fsync`'d before returning, so sequence
/// numbers on disk are in admission order.
pub struct Wal {
    path: PathBuf,
    generation: u64,
    inner: Mutex<WalInner>,
}

impl Wal {
    /// Open an existing ledger for appending: scan it, physically
    /// truncate any torn tail, and continue the sequence numbering
    /// after the highest valid record. Fails on an unreadable header —
    /// that state is recovered by [`Durability::open_or_recover`].
    pub fn open_append(path: impl AsRef<Path>) -> Result<(Wal, Vec<Record>)> {
        let path = path.as_ref().to_path_buf();
        let scan = read_ledger(&path)?;
        if scan.valid_len < HEADER_LEN {
            bail!("ledger {} has a corrupt header; run recovery", path.display());
        }
        let file = OpenOptions::new().append(true).open(&path)?;
        if scan.truncated {
            file.set_len(scan.valid_len)?;
            file.sync_all()?;
        }
        let next_seq = scan.records.iter().map(Record::seq).max().unwrap_or(0) + 1;
        let mut outstanding = BTreeSet::new();
        for rec in &scan.records {
            match rec {
                Record::Accepted { seq, .. } => {
                    outstanding.insert(*seq);
                }
                Record::Completed { seq, .. } => {
                    outstanding.remove(seq);
                }
            }
        }
        Ok((
            Wal {
                path,
                generation: scan.generation,
                inner: Mutex::new(WalInner { file, next_seq, outstanding }),
            },
            scan.records,
        ))
    }

    fn lock(&self) -> MutexGuard<'_, WalInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Highest sequence number assigned so far (0 = none).
    pub fn last_seq(&self) -> u64 {
        self.lock().next_seq - 1
    }

    fn append_locked(inner: &mut WalInner, rec: &Record) -> Result<()> {
        faults::hit("wal_append")?;
        let mut frame = Vec::new();
        frame_into(&mut frame, rec);
        inner.file.write_all(&frame)?;
        inner.file.sync_data()?;
        Ok(())
    }

    /// Append an `Accepted` record and return its sequence number. The
    /// record is on disk (fsync'd) when this returns.
    pub fn append_accepted(
        &self,
        model: &ModelId,
        spec: &ForgetSpec,
        config_hash: u64,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        let mut inner = self.lock();
        let seq = inner.next_seq;
        let rec = Record::Accepted {
            seq,
            model: model.clone(),
            spec: spec.canonical(),
            config_hash,
            deadline_ms: deadline.map(|d| d.as_secs_f64() * 1e3),
        };
        Self::append_locked(&mut inner, &rec)?;
        inner.next_seq = seq + 1;
        inner.outstanding.insert(seq);
        Ok(seq)
    }

    /// Append a `Completed` record for `seq`. On failure `seq` stays
    /// outstanding: it will appear in the pending list of any later
    /// checkpoint scope and replay after a crash.
    pub fn append_completed(
        &self,
        seq: u64,
        disposition: Disposition,
        rolled_back: bool,
        forget_acc: f64,
        retain_acc: f64,
    ) -> Result<()> {
        let mut inner = self.lock();
        let rec = Record::Completed { seq, disposition, rolled_back, forget_acc, retain_acc };
        Self::append_locked(&mut inner, &rec)?;
        inner.outstanding.remove(&seq);
        Ok(())
    }

    /// Consistent checkpoint scope, snapshotted under the append lock:
    /// `(covering, pending)` where `covering` is the highest seq
    /// assigned so far and `pending` lists every accepted seq with no
    /// completion on disk. A checkpoint stamped with this scope claims
    /// exactly the `Done` completions with `seq <= covering` that are
    /// not pending.
    pub fn checkpoint_scope(&self) -> (u64, Vec<u64>) {
        let inner = self.lock();
        (inner.next_seq - 1, inner.outstanding.iter().copied().collect())
    }
}

// --- durability orchestration -------------------------------------------

/// Where and how often the fleet persists.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Directory holding the ledger and parameter checkpoints.
    pub dir: PathBuf,
    /// Checkpoint the serving store every N successful completions
    /// (>= 1). A final checkpoint is also flushed at clean shutdown.
    pub checkpoint_every: u64,
}

/// Counters surfaced by `GET /stats` and the `serve` CLI summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DurabilityStats {
    pub generation: u64,
    /// Highest ledger sequence number assigned (0 = none yet).
    pub wal_seq: u64,
    /// Entries re-enqueued by recovery at startup.
    pub replayed: u64,
    /// Parameter checkpoints written this process.
    pub checkpoints: u64,
}

impl DurabilityStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("generation", Json::from(self.generation as usize)),
            ("wal_seq", Json::from(self.wal_seq as usize)),
            ("replayed", Json::from(self.replayed as usize)),
            ("checkpoints", Json::from(self.checkpoints as usize)),
        ])
    }
}

/// Outcome of [`Durability::open_or_recover`].
pub struct Recovered {
    pub durability: Durability,
    /// Parameter store of the newest valid checkpoint, when one exists
    /// — the fleet's replicas must start from it.
    pub params: Option<ParamStore>,
    /// Entries to re-enqueue, in ledger order: (fresh ledger seq,
    /// model id, canonical spec). Their `Accepted` records are already
    /// durable. The dispatcher validates every model id against its
    /// registry before seeding the queue — an unknown id fails startup.
    pub replay: Vec<(u64, ModelId, ForgetSpec)>,
}

/// Outcome of [`Durability::log_completed`].
pub struct CompletionLog {
    /// A parameter checkpoint is due under the configured cadence.
    pub checkpoint_due: bool,
    /// Every completion record reached disk. When false the affected
    /// seqs stay outstanding (they replay after a crash), so a replica
    /// whose *successful* pass went unrecorded must stop checkpointing:
    /// its store contains the edit while the scope would list the seq
    /// as pending, and recovery would apply the pass a second time.
    pub logged: bool,
}

/// The fleet's durable state: one write-ahead ledger plus the parameter
/// checkpoint cadence. Shared across admission (caller threads) and
/// completion (worker threads).
pub struct Durability {
    wal: Wal,
    /// The per-model audit chains. The lock also pairs each audit
    /// append with its WAL `Completed` appends
    /// ([`log_completed_audited`](Durability::log_completed_audited)),
    /// so a crash leaves at most one trailing unpaired link.
    audit: Mutex<audit::AuditLog>,
    dir: PathBuf,
    checkpoint_every: u64,
    replayed: u64,
    /// Successful completions since start (checkpoint cadence).
    done_entries: AtomicU64,
    checkpoints: AtomicU64,
    /// Scope of the last checkpoint written this process (`None` =
    /// none yet), so shutdown skips a redundant final flush. Doubles as
    /// the lock serializing checkpoint writes.
    ckpt_scope: Mutex<Option<(u64, Vec<u64>)>>,
}

impl Durability {
    /// Open the durable directory, recovering if a previous process
    /// died: load the newest valid checkpoint, scan the ledger under
    /// the torn-write rules, compute the replay set, and atomically
    /// rewrite the ledger (bumped generation) with one fresh `Accepted`
    /// record per replayed entry.
    pub fn open_or_recover(cfg: &DurabilityConfig) -> Result<Recovered> {
        ensure!(cfg.checkpoint_every >= 1, "checkpoint_every must be >= 1");
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating durable dir {}", cfg.dir.display()))?;
        let ckpt = checkpoint::load_latest(&cfg.dir)?;
        let path = cfg.dir.join(LEDGER_FILE);
        let scan = if path.exists() {
            read_ledger(&path)?
        } else {
            LedgerScan { generation: 0, records: Vec::new(), valid_len: 0, truncated: false }
        };

        // A checkpoint's scope is only meaningful against the same
        // ledger generation; an older-generation checkpoint covers none
        // of the current ledger's completions (conservative: replay
        // them all).
        let ckpt_gen = ckpt.as_ref().map(|c| c.generation).unwrap_or(0);
        let (covering, ckpt_pending): (u64, HashSet<u64>) = match &ckpt {
            Some(c) if c.generation == scan.generation => {
                (c.covering_seq, c.pending.iter().copied().collect())
            }
            _ => (0, HashSet::new()),
        };

        let mut completed: HashMap<u64, Disposition> = HashMap::new();
        for rec in &scan.records {
            if let Record::Completed { seq, disposition, .. } = rec {
                completed.insert(*seq, *disposition);
            }
        }
        let mut seen_keys: HashSet<(ModelId, u64)> = HashSet::new();
        let mut replayed_old: HashSet<u64> = HashSet::new();
        let mut fresh: Vec<Record> = Vec::new();
        let mut replay: Vec<(u64, ModelId, ForgetSpec)> = Vec::new();
        for rec in &scan.records {
            let Record::Accepted { seq, model, spec, config_hash, .. } = rec else { continue };
            let replayable = match completed.get(seq) {
                None => true,
                // A `Done` seq is in the checkpoint iff it is inside
                // the scope: at or below the covering seq and not
                // pending when the snapshot was taken.
                Some(Disposition::Done) => *seq > covering || ckpt_pending.contains(seq),
                Some(_) => false, // failed/expired: answered, no edits
            };
            if !replayable {
                continue;
            }
            replayed_old.insert(*seq);
            faults::hit("replay")?;
            // idempotent per (model, canonical SpecKey): two tenants
            // forgetting the same class are distinct replays
            if !seen_keys.insert((model.clone(), spec.key().hash64())) {
                continue;
            }
            let new_seq = fresh.len() as u64 + 1;
            fresh.push(Record::Accepted {
                seq: new_seq,
                model: model.clone(),
                spec: spec.clone(),
                config_hash: *config_hash,
                deadline_ms: None,
            });
            replay.push((new_seq, model.clone(), spec.canonical()));
        }

        // Re-enter the audit chain (see the module docs): the pair lock
        // appends the audit link before its WAL `Completed` records, so
        // the tail of `audit.log` may hold links of this generation
        // whose executions are about to replay — either their
        // completion never landed, or it landed outside the checkpoint
        // scope and its edits were lost with the process. Drop exactly
        // those trailing links (the replayed executions re-derive
        // them); `wal_gen` keeps links of older generations safe even
        // though the fresh ledger reuses their seq numbers. The audit
        // rewrite lands *before* the ledger rewrite: if we crash
        // between the two, the next recovery recomputes the same drop
        // set from the old ledger (idempotent), whereas the reverse
        // order would judge old links against a fresh completion-less
        // ledger and truncate valid history.
        let audit_path = cfg.dir.join(audit::AUDIT_FILE);
        if audit_path.exists() {
            let mut links = audit::log::read_log(&audit_path)?.records;
            let before = links.len();
            while let Some(last) = links.last() {
                let stale = last.wal_gen == scan.generation
                    && match last.wal_seq {
                        Some(s) => {
                            replayed_old.contains(&s)
                                || !matches!(completed.get(&s), Some(Disposition::Done))
                        }
                        None => false,
                    };
                if !stale {
                    break;
                }
                links.pop();
            }
            if links.len() < before {
                audit::log::write_replacing(&audit_path, &links)?;
            }
        }

        let generation = scan.generation.max(ckpt_gen) + 1;
        write_replacing(&path, generation, &fresh)?;
        let (wal, _) = Wal::open_append(&path)?;
        let audit_log = audit::AuditLog::open_append(&audit_path)?;
        Ok(Recovered {
            durability: Durability {
                wal,
                audit: Mutex::new(audit_log),
                dir: cfg.dir.clone(),
                checkpoint_every: cfg.checkpoint_every,
                replayed: replay.len() as u64,
                done_entries: AtomicU64::new(0),
                checkpoints: AtomicU64::new(0),
                ckpt_scope: Mutex::new(None),
            },
            params: ckpt.map(|c| c.params),
            replay,
        })
    }

    /// Durable admission: append `Accepted` (fsync'd) and return its
    /// seq. An error here must fail the request — no slot without a
    /// ledger record.
    pub fn log_accepted(
        &self,
        model: &ModelId,
        spec: &ForgetSpec,
        config_hash: u64,
        deadline: Option<Duration>,
    ) -> Result<u64> {
        self.wal.append_accepted(model, spec, config_hash, deadline).context("durable admission")
    }

    /// Record completion of one queue entry (every coalesced seq gets
    /// its own `Completed` record). Append errors are reported, not
    /// propagated — a missing completion means the entry is replayed
    /// after a crash (at-least-once, idempotent) — but
    /// [`CompletionLog::logged`] tells the completing replica whether
    /// all records landed, because a lost *successful* completion must
    /// also stop that replica's checkpoints (see the field docs).
    pub fn log_completed(
        &self,
        seqs: &[u64],
        disposition: Disposition,
        rolled_back: bool,
        forget_acc: f64,
        retain_acc: f64,
    ) -> CompletionLog {
        let mut logged = true;
        for &seq in seqs {
            if let Err(e) =
                self.wal.append_completed(seq, disposition, rolled_back, forget_acc, retain_acc)
            {
                logged = false;
                eprintln!("ficabu: ledger completion append failed for seq {seq}: {e:#}");
            }
        }
        if disposition != Disposition::Done {
            return CompletionLog { checkpoint_due: false, logged };
        }
        let done = self.done_entries.fetch_add(1, Ordering::SeqCst) + 1;
        CompletionLog { checkpoint_due: done % self.checkpoint_every == 0, logged }
    }

    /// Record a *successful* completion together with its audit link.
    /// The [`AuditRecord`] is appended to the model's hash chain first,
    /// then every coalesced seq gets its WAL `Completed` record — one
    /// lock spans both, so concurrent completions cannot interleave an
    /// audit link with another entry's completion and a crash leaves at
    /// most one trailing link without its completion (recovery drops it
    /// and the replayed execution re-derives it). A failed audit append
    /// taints the link ([`crate::audit::log`] — flagged in memory and
    /// hashed over by later links, never dropped) and does not block
    /// the reply. Returns the stamped link alongside the completion
    /// outcome.
    pub fn log_completed_audited(
        &self,
        summary: &Summary,
        seqs: &[u64],
    ) -> (CompletionLog, AuditRecord) {
        let mut audit = self.audit.lock().unwrap_or_else(PoisonError::into_inner);
        let link = audit.append(AuditRecord {
            model: summary.model.clone(),
            chain_seq: 0, // stamped by the chain
            prev_hash: 0, // stamped by the chain
            spec: summary.spec.canonical(),
            config_hash: summary.config_hash,
            git_rev: audit::git_rev().to_string(),
            rolled_back: summary.rolled_back,
            wal_seq: seqs.iter().copied().min(),
            wal_gen: self.wal.generation(),
            tainted: false,
            forget_acc: summary.forget_acc,
            retain_acc: summary.retain_acc,
            attest: summary.attest.clone(),
        });
        let log = self.log_completed(
            seqs,
            Disposition::Done,
            summary.rolled_back,
            summary.forget_acc,
            summary.retain_acc,
        );
        (log, link)
    }

    /// The live audit chain of `model`, oldest link first (tainted
    /// links included) — what `GET /models/{id}/audit` serves.
    pub fn audit_chain(&self, model: &ModelId) -> Vec<AuditRecord> {
        self.audit.lock().unwrap_or_else(PoisonError::into_inner).chain(model)
    }

    /// Per-model heads over durably persisted links — what checkpoints
    /// embed.
    pub fn audit_heads(&self) -> Vec<ChainHead> {
        self.audit.lock().unwrap_or_else(PoisonError::into_inner).heads()
    }

    /// Atomically checkpoint `store` under the ledger's current scope
    /// (covering seq + pending list, snapshotted under the append
    /// lock). The caller asserts that `store` contains the edit of
    /// every `Done` completion on disk — true for the single replica of
    /// an untainted one-worker fleet.
    pub fn write_checkpoint(&self, store: &ParamStore) -> Result<()> {
        let mut last = self.ckpt_scope.lock().unwrap_or_else(PoisonError::into_inner);
        // Heads before scope: a completion racing this snapshot may add
        // a link the checkpoint then doesn't anchor (harmless — the
        // anchor check is containment), while the reverse could anchor
        // a link whose seq falls outside the scope and is dropped by
        // recovery.
        let heads = self.audit_heads();
        let (covering, pending) = self.wal.checkpoint_scope();
        checkpoint::write(&self.dir, store, self.wal.generation(), covering, &pending, &heads)?;
        self.checkpoints.fetch_add(1, Ordering::SeqCst);
        *last = Some((covering, pending));
        Ok(())
    }

    /// Whether the last checkpoint written this process already
    /// captures the current ledger scope (nothing accepted or completed
    /// since) — lets clean shutdown skip a redundant final flush.
    pub fn checkpoint_current(&self) -> bool {
        let last = self.ckpt_scope.lock().unwrap_or_else(PoisonError::into_inner);
        last.as_ref() == Some(&self.wal.checkpoint_scope())
    }

    pub fn stats(&self) -> DurabilityStats {
        DurabilityStats {
            generation: self.wal.generation(),
            wal_seq: self.wal.last_seq(),
            replayed: self.replayed,
            checkpoints: self.checkpoints.load(Ordering::SeqCst),
        }
    }
}

/// Stable fingerprint of an [`UnlearnConfig`] recorded in `Accepted`
/// entries — an audit field tying a ledger line to the operating point
/// that served it (FNV-1a over the config's debug rendering).
pub fn config_fingerprint(cfg: &UnlearnConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in format!("{cfg:?}").bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficabu_wal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE 802.3 reference values
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn record_roundtrip() {
        let recs = [
            Record::Accepted {
                seq: 7,
                model: ModelId::default(),
                spec: ForgetSpec::Classes(vec![1, 4]),
                config_hash: 0xdead_beef,
                deadline_ms: Some(250.0),
            },
            Record::Accepted {
                seq: 8,
                model: ModelId::new("tenant-b.v2").unwrap(),
                spec: ForgetSpec::Samples(vec![0, 9, 44]),
                config_hash: 1,
                deadline_ms: None,
            },
            Record::Completed {
                seq: 7,
                disposition: Disposition::Done,
                rolled_back: false,
                forget_acc: 0.05,
                retain_acc: 0.91,
            },
            Record::Completed {
                seq: 8,
                disposition: Disposition::Expired,
                rolled_back: false,
                forget_acc: -1.0,
                retain_acc: -1.0,
            },
        ];
        for r in &recs {
            assert_eq!(&Record::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn append_scan_roundtrip_and_seq_continuity() {
        let dir = tmpdir("roundtrip");
        let path = dir.join(LEDGER_FILE);
        write_replacing(&path, 3, &[]).unwrap();
        let (wal, recs) = Wal::open_append(&path).unwrap();
        assert!(recs.is_empty());
        assert_eq!(wal.generation(), 3);
        let m = ModelId::default();
        let s1 = wal.append_accepted(&m, &ForgetSpec::Class(2), 11, None).unwrap();
        let s2 = wal
            .append_accepted(
                &m,
                &ForgetSpec::Classes(vec![4, 1]),
                11,
                Some(Duration::from_millis(9)),
            )
            .unwrap();
        wal.append_completed(s1, Disposition::Done, false, 0.04, 0.9).unwrap();
        assert_eq!((s1, s2), (1, 2));
        assert_eq!(wal.last_seq(), 2);
        drop(wal);

        let (wal, recs) = Wal::open_append(&path).unwrap();
        assert_eq!(recs.len(), 3);
        // canonicalized on write: classes:4,1 -> classes:1,4
        assert!(matches!(
            &recs[1],
            Record::Accepted { seq: 2, spec: ForgetSpec::Classes(v), .. } if v == &[1, 4]
        ));
        // numbering continues after the highest valid record
        assert_eq!(wal.append_accepted(&m, &ForgetSpec::Class(0), 0, None).unwrap(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_and_prefix_survives() {
        let dir = tmpdir("torn");
        let path = dir.join(LEDGER_FILE);
        write_replacing(&path, 1, &[]).unwrap();
        let (wal, _) = Wal::open_append(&path).unwrap();
        let m = ModelId::default();
        wal.append_accepted(&m, &ForgetSpec::Class(1), 0, None).unwrap();
        wal.append_accepted(&m, &ForgetSpec::Class(2), 0, None).unwrap();
        drop(wal);
        let whole = std::fs::read(&path).unwrap();

        // (a) torn mid-payload: claim 64 bytes, provide 5
        let mut torn = whole.clone();
        torn.extend_from_slice(&64u32.to_le_bytes());
        torn.extend_from_slice(&0u32.to_le_bytes());
        torn.extend_from_slice(b"abcde");
        std::fs::write(&path, &torn).unwrap();
        let scan = read_ledger(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert!(scan.truncated);
        let (wal, recs) = Wal::open_append(&path).unwrap();
        assert_eq!(recs.len(), 2);
        drop(wal);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), whole.len() as u64, "tail cut");

        // (b) bit flip inside the *second* record's payload: the first
        // record survives, the flipped one and everything after it drop
        let mut flipped = whole.clone();
        let n = flipped.len();
        flipped[n - 3] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let scan = read_ledger(&path).unwrap();
        assert_eq!(scan.records.len(), 1);
        assert!(scan.truncated);

        // (c) corrupt header: the whole file is treated as lost
        let mut bad = whole;
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let scan = read_ledger(&path).unwrap();
        assert_eq!(scan.generation, 0);
        assert!(scan.records.is_empty());
        assert!(Wal::open_append(&path).is_err(), "append refuses a corrupt header");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_replays_only_unfinished_and_post_checkpoint_entries() {
        let dir = tmpdir("recover");
        let cfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 };
        // Ledger: seq1 done, seq2 failed, seq3 done, seq4 accepted-only,
        // seq5 accepted-only duplicate of seq4's canonical key.
        let m = ModelId::default();
        let recs = vec![
            Record::Accepted { seq: 1, model: m.clone(), spec: ForgetSpec::Class(1), config_hash: 9, deadline_ms: None },
            Record::Completed { seq: 1, disposition: Disposition::Done, rolled_back: false, forget_acc: 0.1, retain_acc: 0.9 },
            Record::Accepted { seq: 2, model: m.clone(), spec: ForgetSpec::Class(2), config_hash: 9, deadline_ms: Some(5.0) },
            Record::Completed { seq: 2, disposition: Disposition::Failed, rolled_back: true, forget_acc: -1.0, retain_acc: -1.0 },
            Record::Accepted { seq: 3, model: m.clone(), spec: ForgetSpec::Class(3), config_hash: 9, deadline_ms: None },
            Record::Completed { seq: 3, disposition: Disposition::Done, rolled_back: false, forget_acc: 0.1, retain_acc: 0.9 },
            Record::Accepted { seq: 4, model: m.clone(), spec: ForgetSpec::Classes(vec![5, 6]), config_hash: 9, deadline_ms: None },
            Record::Accepted { seq: 5, model: m.clone(), spec: ForgetSpec::Classes(vec![6, 5, 5]), config_hash: 9, deadline_ms: None },
        ];
        write_replacing(&dir.join(LEDGER_FILE), 4, &recs).unwrap();
        // Checkpoint of generation 4 covering seq 1: seq 3's edits are
        // lost with the process, so it must be replayed; seq 1 must not.
        let meta = crate::config::ModelMeta::builtin("rn18slim").unwrap();
        let store = ParamStore::init(&meta, 3);
        checkpoint::write(&dir, &store, 4, 1, &[], &[]).unwrap();

        let rec = Durability::open_or_recover(&cfg).unwrap();
        let specs: Vec<&ForgetSpec> = rec.replay.iter().map(|(_, _, s)| s).collect();
        assert_eq!(
            specs,
            [&ForgetSpec::Class(3), &ForgetSpec::Classes(vec![5, 6])],
            "replay = post-checkpoint done + accepted-without-completed, deduped by key"
        );
        assert!(rec.replay.iter().all(|(_, id, _)| *id == m), "model ids survive replay");
        assert_eq!(rec.replay[0].0, 1, "fresh ledger renumbers from 1");
        assert!(rec.params.is_some());
        let st = rec.durability.stats();
        assert_eq!(st.generation, 5, "generation bumps past ledger and checkpoint");
        assert_eq!(st.replayed, 2);
        assert_eq!(st.wal_seq, 2, "fresh ledger holds exactly the replay records");
        // A second recovery before the replays complete finds them again.
        drop(rec);
        let rec2 = Durability::open_or_recover(&cfg).unwrap();
        assert_eq!(rec2.durability.stats().replayed, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_scope_tracks_outstanding_accepted_seqs() {
        let dir = tmpdir("scope");
        let path = dir.join(LEDGER_FILE);
        let m = ModelId::default();
        let recs = vec![
            Record::Accepted { seq: 1, model: m.clone(), spec: ForgetSpec::Class(1), config_hash: 0, deadline_ms: None },
            Record::Accepted { seq: 2, model: m.clone(), spec: ForgetSpec::Class(2), config_hash: 0, deadline_ms: None },
            Record::Completed { seq: 1, disposition: Disposition::Done, rolled_back: false, forget_acc: 0.1, retain_acc: 0.9 },
        ];
        write_replacing(&path, 1, &recs).unwrap();
        // open_append seeds the outstanding set from the scanned records
        let (wal, _) = Wal::open_append(&path).unwrap();
        assert_eq!(wal.checkpoint_scope(), (2, vec![2]));
        let s3 = wal.append_accepted(&m, &ForgetSpec::Class(3), 0, None).unwrap();
        assert_eq!(wal.checkpoint_scope(), (3, vec![2, 3]));
        wal.append_completed(s3, Disposition::Done, false, 0.1, 0.9).unwrap();
        assert_eq!(wal.checkpoint_scope(), (3, vec![2]));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The coalesce race: a joiner's high seq completes with an earlier
    /// entry while a lower seq is still queued. The checkpoint must not
    /// claim the queued seq — and once it completes *after* the
    /// checkpoint, recovery must replay it even though its seq is below
    /// the covering seq.
    #[test]
    fn pending_seqs_below_covering_are_replayed() {
        let dir = tmpdir("pending");
        let cfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 };
        let meta = crate::config::ModelMeta::builtin("rn18slim").unwrap();
        let store = ParamStore::init(&meta, 3);
        {
            let d = Durability::open_or_recover(&cfg).unwrap().durability;
            // A (seq 1) and B (seq 2) admitted; a duplicate of A
            // coalesces onto A's queue entry (seq 3). The worker serves
            // A first: seqs 1 and 3 complete in one pass and the
            // checkpoint lands while B is still queued.
            let m = ModelId::default();
            let a = d.log_accepted(&m, &ForgetSpec::Class(1), 0, None).unwrap();
            let b = d.log_accepted(&m, &ForgetSpec::Class(2), 0, None).unwrap();
            let j = d.log_accepted(&m, &ForgetSpec::Class(1), 0, None).unwrap();
            assert_eq!((a, b, j), (1, 2, 3));
            d.log_completed(&[a, j], Disposition::Done, false, 0.1, 0.9);
            d.write_checkpoint(&store).unwrap();
            // B completes after the checkpoint; the process dies before
            // the next one.
            d.log_completed(&[b], Disposition::Done, false, 0.1, 0.9);
        }
        let ck = checkpoint::load_latest(&dir).unwrap().unwrap();
        assert_eq!((ck.covering_seq, ck.pending.as_slice()), (3, &[2u64][..]));
        // B's edits are absent from the checkpoint even though its seq
        // is below the covering seq: recovery replays it, and only it.
        let rec = Durability::open_or_recover(&cfg).unwrap();
        let specs: Vec<&ForgetSpec> = rec.replay.iter().map(|(_, _, s)| s).collect();
        assert_eq!(specs, [&ForgetSpec::Class(2)]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Two tenants forgetting the same class must stay distinct under
    /// replay dedup — the key is (model, spec key), not spec key alone.
    #[test]
    fn replay_dedup_is_per_model() {
        let dir = tmpdir("tenants");
        let cfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 };
        let ma = ModelId::new("tenant-a").unwrap();
        let mb = ModelId::new("tenant-b").unwrap();
        let recs = vec![
            Record::Accepted { seq: 1, model: ma.clone(), spec: ForgetSpec::Class(7), config_hash: 1, deadline_ms: None },
            Record::Accepted { seq: 2, model: mb.clone(), spec: ForgetSpec::Class(7), config_hash: 2, deadline_ms: None },
            Record::Accepted { seq: 3, model: ma.clone(), spec: ForgetSpec::Class(7), config_hash: 1, deadline_ms: None },
        ];
        write_replacing(&dir.join(LEDGER_FILE), 1, &recs).unwrap();
        let rec = Durability::open_or_recover(&cfg).unwrap();
        let got: Vec<(&ModelId, &ForgetSpec)> =
            rec.replay.iter().map(|(_, id, s)| (id, s)).collect();
        assert_eq!(
            got,
            [(&ma, &ForgetSpec::Class(7)), (&mb, &ForgetSpec::Class(7))],
            "same spec for two models replays twice; same (model, spec) collapses"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A pre-registry (FICABUW1) ledger is refused loudly — its records
    /// carry no model id, so treating it as lost would silently drop
    /// admitted requests.
    #[test]
    fn v1_ledger_is_refused_loudly() {
        let dir = tmpdir("v1");
        let path = dir.join(LEDGER_FILE);
        let mut buf = Vec::new();
        buf.extend_from_slice(LEDGER_MAGIC_V1);
        buf.extend_from_slice(&1u64.to_le_bytes());
        buf.extend_from_slice(&crc32(&1u64.to_le_bytes()).to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        let err = read_ledger(&path).unwrap_err();
        assert!(err.to_string().contains("FICABUW1"), "{err:#}");
        assert!(Wal::open_append(&path).is_err());
        assert!(
            Durability::open_or_recover(&DurabilityConfig {
                dir: dir.clone(),
                checkpoint_every: 1
            })
            .is_err(),
            "recovery must not silently rewrite a pre-registry ledger"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn audited_completion_appends_a_chained_link() {
        let dir = tmpdir("audited");
        let cfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 8 };
        let d = Durability::open_or_recover(&cfg).unwrap().durability;
        let m = ModelId::default();
        let s1 = d.log_accepted(&m, &ForgetSpec::Class(1), 7, None).unwrap();
        let summary = Summary {
            model: m.clone(),
            config_hash: 7,
            spec: ForgetSpec::Class(1),
            forget_acc: 0.05,
            retain_acc: 0.9,
            stop_depth: Some(2),
            macs_vs_ssd_pct: 50.0,
            sim_energy_mj: 1.0,
            sim_energy_vs_ssd_pct: 40.0,
            sim_ms: 2.0,
            rolled_back: false,
            timing: Default::default(),
            wal_seq: Some(s1),
            attest: None,
        };
        let (log, link) = d.log_completed_audited(&summary, &[s1]);
        assert!(log.logged);
        assert_eq!(link.chain_seq, 1);
        assert_eq!(link.prev_hash, AuditRecord::genesis_hash(&m));
        assert_eq!(link.wal_seq, Some(s1));
        assert_eq!(link.wal_gen, d.stats().generation);
        assert!(!link.tainted);
        assert_eq!(d.audit_chain(&m), vec![link.clone()]);
        let heads = d.audit_heads();
        assert_eq!(heads.len(), 1);
        assert_eq!((heads[0].chain_len, heads[0].head_hash), (1, link.core_hash()));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The chain re-entry rule: a trailing audit link of the current
    /// generation whose execution replays (no durable completion, or a
    /// completion outside the checkpoint scope) is dropped; links of
    /// older generations survive even when the fresh ledger reuses
    /// their seq numbers.
    #[test]
    fn recovery_drops_stale_trailing_audit_links() {
        let dir = tmpdir("auditchain");
        let cfg = DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 };
        let m = ModelId::default();
        // Ledger generation 4: seq 1 done, seq 2 accepted-only (its
        // execution finished in memory — the audit link landed — but
        // the process died before the `Completed` append).
        let recs = vec![
            Record::Accepted { seq: 1, model: m.clone(), spec: ForgetSpec::Class(1), config_hash: 9, deadline_ms: None },
            Record::Completed { seq: 1, disposition: Disposition::Done, rolled_back: false, forget_acc: 0.1, retain_acc: 0.9 },
            Record::Accepted { seq: 2, model: m.clone(), spec: ForgetSpec::Class(2), config_hash: 9, deadline_ms: None },
        ];
        write_replacing(&dir.join(LEDGER_FILE), 4, &recs).unwrap();
        let meta = crate::config::ModelMeta::builtin("rn18slim").unwrap();
        let store = ParamStore::init(&meta, 3);
        checkpoint::write(&dir, &store, 4, 1, &[], &[]).unwrap();
        let mk = |wal_seq: u64, wal_gen: u64| {
            let mut r = crate::audit::test_record("default", wal_seq, 0);
            r.wal_seq = Some(wal_seq);
            r.wal_gen = wal_gen;
            r
        };
        {
            let mut alog = audit::AuditLog::open_append(dir.join(audit::AUDIT_FILE)).unwrap();
            alog.append(mk(5, 3)); // older generation, seq meaningless here
            alog.append(mk(1, 4)); // covered by the checkpoint
            alog.append(mk(2, 4)); // the orphan
        }

        let rec = Durability::open_or_recover(&cfg).unwrap();
        let specs: Vec<&ForgetSpec> = rec.replay.iter().map(|(_, _, s)| s).collect();
        assert_eq!(specs, [&ForgetSpec::Class(2)], "only the orphan's entry replays");
        let chain = rec.durability.audit_chain(&m);
        assert_eq!(chain.len(), 2, "the orphan link is dropped, earlier links survive");
        assert_eq!(chain[1].wal_seq, Some(1));
        let heads = rec.durability.audit_heads();
        assert_eq!((heads[0].chain_len, heads[0].head_hash), (2, chain[1].core_hash()));
        drop(rec);

        // Second crash before the replay completes: the fresh ledger
        // (generation 6 now) reuses seq 1, which is accepted-only — but
        // the surviving tail link carries wal_gen 4, so it is not
        // judged against the new ledger and stays.
        let rec2 = Durability::open_or_recover(&cfg).unwrap();
        let specs: Vec<&ForgetSpec> = rec2.replay.iter().map(|(_, _, s)| s).collect();
        assert_eq!(specs, [&ForgetSpec::Class(2)], "still replays after a second crash");
        assert_eq!(
            rec2.durability.audit_chain(&m).len(),
            2,
            "links of older generations survive seq-number reuse"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_fingerprint_is_stable_and_discriminating() {
        let a = UnlearnConfig::default();
        let mut b = UnlearnConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&a));
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.alpha += 1.0;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
    }
}
