//! Request routing: the JSON contract over the [`Fleet`].
//!
//! | route                      | reply                                             |
//! |----------------------------|---------------------------------------------------|
//! | `POST /forget`             | the [`Reply`] wire body; status from its code     |
//! | `POST /models/{id}/forget` | same, addressed to one registered model           |
//! | `GET /models`              | `{"models":[{id,spec_key,config_hash,precision,warm}]}` |
//! | `GET /models/{id}/audit`   | the model's audit chain: `{model,chain_len,head_hash,records}` |
//! | `GET /stats`               | the fleet's percentile rollup, as JSON            |
//! | `GET /healthz`             | fleet liveness: 200 `{"ok":true,...}`, 503 degraded |
//!
//! Forget bodies are scanned lazily ([`scan::path`]) for the fields the
//! admission path needs — `spec` (the CLI grammar string or the
//! [`ForgetSpec::to_json`] object form), `deadline_ms` (absent = fleet
//! default, `0` = no deadline), and on the legacy `/forget` route an
//! optional `model` string (absent = the fleet's sole model; 400 when
//! several are registered) — every other byte is skipped, not parsed.
//! Malformed bodies answer 400 with the machine-readable shape
//! `{"code","error","offset","context"}` so clients can point at the
//! offending byte; addressing a model the registry does not hold
//! answers 404 `{"code":"unknown-model",...}`.

use std::time::Duration;

use crate::coordinator::dispatch::{Fleet, Reply};
use crate::coordinator::registry::{ModelId, ModelInfo};
use crate::unlearn::ForgetSpec;
use crate::util::json::{scan, Json, JsonError};

use super::proto::{Request, Response};

/// Dataset bounds the HTTP layer validates specs against:
/// `(num_classes, num_samples)`. `None` defers validation to execution.
pub type Bounds = Option<(usize, usize)>;

/// Upper bound on a request's `deadline_ms` (one year). Anything larger
/// is not a deadline a client means seriously, and the cap keeps the
/// value safely inside `Duration::from_secs_f64`'s domain — unbounded
/// input (`1e308`, or `1e999` = infinity after parse) would panic there,
/// and a panic on the accept path kills an accept thread for good.
const MAX_DEADLINE_MS: f64 = 365.0 * 24.0 * 3600.0 * 1e3;

/// Dispatch one parsed request against the fleet.
pub(super) fn handle(req: &Request, fleet: &Fleet, bounds: Bounds) -> Response {
    match (req.method.as_str(), req.path()) {
        ("POST", "/forget") => forget(req, fleet, bounds, None),
        ("GET", "/models") => {
            let rows = fleet.models_info().iter().map(ModelInfo::to_json).collect();
            Response::json(200, &Json::obj(vec![("models", Json::Arr(rows))]))
        }
        ("GET", "/stats") => Response::json(200, &fleet.stats().to_json()),
        ("GET", "/healthz") => {
            // Degraded contract: any dead or respawning worker answers
            // 503 so a load balancer can drain the device; 200 only
            // with the full fleet alive.
            let s = fleet.stats();
            let ok = s.alive == s.workers;
            Response::json(
                if ok { 200 } else { 503 },
                &Json::obj(vec![
                    ("ok", Json::from(ok)),
                    ("alive", Json::from(s.alive)),
                    ("workers", Json::from(s.workers)),
                    ("queue_depth", Json::from(s.queue_depth)),
                ]),
            )
        }
        (_, "/forget") => method_not_allowed(req, "POST"),
        (_, "/stats" | "/healthz" | "/models") => method_not_allowed(req, "GET"),
        (method, path) => {
            // `/models/{id}/forget`: the model-addressed submission route.
            if let Some(id) =
                path.strip_prefix("/models/").and_then(|rest| rest.strip_suffix("/forget"))
            {
                if method != "POST" {
                    return method_not_allowed(req, "POST");
                }
                return match ModelId::new(id) {
                    Ok(model) => forget(req, fleet, bounds, Some(model)),
                    Err(e) => error(400, "invalid_model", format!("{e:#}"), None),
                };
            }
            // `/models/{id}/audit`: the model's verifiable forget history.
            if let Some(id) =
                path.strip_prefix("/models/").and_then(|rest| rest.strip_suffix("/audit"))
            {
                if method != "GET" {
                    return method_not_allowed(req, "GET");
                }
                return match ModelId::new(id) {
                    Ok(model) => audit(fleet, &model),
                    Err(e) => error(400, "invalid_model", format!("{e:#}"), None),
                };
            }
            error(404, "not_found", format!("no route `{path}`"), None)
        }
    }
}

/// `POST /forget` and `POST /models/{id}/forget`: extract `spec` +
/// `deadline_ms`, resolve the target model (`route_model` from the
/// path, or the legacy route's optional `model` body field, or the
/// fleet's sole model), admit, and block on the fleet's reply (the HTTP
/// contract is synchronous: one request, one final outcome).
fn forget(req: &Request, fleet: &Fleet, bounds: Bounds, route_model: Option<ModelId>) -> Response {
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(e) => {
            return error(
                400,
                "bad_request",
                "body is not UTF-8",
                Some((e.valid_up_to(), String::new())),
            )
        }
    };
    let raw = match scan::path(body, &["spec"]) {
        Err(e) => return bad_json(e),
        Ok(None) => return error(400, "invalid_spec", "missing `spec` field", None),
        Ok(Some(raw)) => raw,
    };
    let spec = match raw.parse().map_err(BodyError::Json).and_then(|j| {
        ForgetSpec::from_json(&j).map_err(|e| BodyError::Spec(format!("{e:#}"), raw.offset()))
    }) {
        Ok(s) => s,
        Err(BodyError::Json(e)) => return bad_json(e),
        Err(BodyError::Spec(msg, off)) => {
            return error(400, "invalid_spec", msg, Some((off, String::new())))
        }
    };
    if let Some((num_classes, num_samples)) = bounds {
        if let Err(e) = spec.validate(num_classes, num_samples) {
            let at = Some((raw.offset(), String::new()));
            return error(400, "invalid_spec", format!("{e:#}"), at);
        }
    }
    let model = match route_model {
        Some(m) => m,
        None => match scan::path(body, &["model"]) {
            Err(e) => return bad_json(e),
            Ok(Some(raw)) => {
                let at = Some((raw.offset(), String::new()));
                let j = match raw.parse() {
                    Ok(j) => j,
                    Err(e) => return bad_json(e),
                };
                let Some(s) = j.as_str() else {
                    return error(400, "invalid_model", "`model` must be a string", at);
                };
                match ModelId::new(s) {
                    Ok(m) => m,
                    Err(e) => return error(400, "invalid_model", format!("{e:#}"), at),
                }
            }
            // a model-less legacy submission only works while the fleet
            // hosts exactly one model — ambiguity is a client error
            Ok(None) => match fleet.sole_model() {
                Some(m) => m,
                None => {
                    return error(
                        400,
                        "ambiguous_model",
                        "fleet hosts multiple models; POST /models/{id}/forget \
                         or set the `model` field",
                        None,
                    )
                }
            },
        },
    };
    if !fleet.has_model(&model) {
        return error(
            404,
            "unknown-model",
            format!("model {model} is not registered; GET /models lists what is"),
            None,
        );
    }
    let rx = match scan::path_f64(body, &["deadline_ms"]) {
        Err(e) => return bad_json(e),
        Ok(Some(ms)) if !ms.is_finite() || ms < 0.0 || ms > MAX_DEADLINE_MS => {
            let msg = format!("`deadline_ms` must be in [0, {MAX_DEADLINE_MS:.0}], got {ms}");
            return error(400, "bad_request", msg, None);
        }
        // explicit 0 = no deadline, overriding any fleet default
        Ok(Some(ms)) if ms == 0.0 => fleet.submit_to(model, spec, None),
        Ok(Some(ms)) => {
            fleet.submit_to(model, spec, Some(Duration::from_secs_f64(ms / 1e3)))
        }
        Ok(None) => fleet.submit_to(model, spec, fleet.default_deadline()),
    };
    match rx.recv() {
        Ok(reply) => {
            let status = match &reply {
                Reply::Done(_) => 200,
                Reply::Failed(_) => 500,
                Reply::Backpressure { .. } => 429,
                Reply::Expired { .. } => 504,
            };
            let resp = Response::json(status, &reply.to_json());
            if status == 429 {
                resp.with_header("retry-after", "1")
            } else {
                resp
            }
        }
        // the worker dropped the reply channel without answering —
        // engine panics are caught and answered, so this is a worker
        // thread dying outright (or a dispatcher bug)
        Err(_) => error(
            500,
            "worker-lost",
            "the worker serving this request died before answering",
            None,
        ),
    }
}

/// `GET /models/{id}/audit`: the model's hash-chained forget history.
/// An empty chain (no completed forgets yet, or a fleet running without
/// durability) answers 200 with `chain_len: 0` and the genesis hash, so
/// clients can distinguish "nothing to audit" from "unknown model" (404).
fn audit(fleet: &Fleet, model: &ModelId) -> Response {
    use crate::audit::AuditRecord;
    if !fleet.has_model(model) {
        return error(
            404,
            "unknown-model",
            format!("model {model} is not registered; GET /models lists what is"),
            None,
        );
    }
    let records = fleet.audit_chain(model);
    let head = records
        .last()
        .map(AuditRecord::core_hash)
        .unwrap_or_else(|| AuditRecord::genesis_hash(model));
    Response::json(
        200,
        &Json::obj(vec![
            ("model", Json::from(model.as_str())),
            ("chain_len", Json::from(records.len())),
            ("head_hash", Json::string(format!("{head:016x}"))),
            ("records", Json::Arr(records.iter().map(AuditRecord::to_json).collect())),
        ]),
    )
}

enum BodyError {
    Json(JsonError),
    Spec(String, usize),
}

fn method_not_allowed(req: &Request, allow: &'static str) -> Response {
    let msg = format!("{} {} is not routable; allow: {allow}", req.method, req.path());
    error(405, "method_not_allowed", msg, None).with_header("allow", allow)
}

fn bad_json(e: JsonError) -> Response {
    let ctx = e.context.clone();
    error(400, "bad_request", e.msg, Some((e.pos, ctx)))
}

/// The machine-readable error body shared by every non-reply failure:
/// `code` (stable discriminant), `error` (human text), and — when the
/// failure points at request bytes — `offset` (+ `context` when the
/// scanner captured surrounding input).
pub(super) fn error(
    status: u16,
    code: &str,
    msg: impl Into<String>,
    at: Option<(usize, String)>,
) -> Response {
    let mut fields = vec![("code", Json::from(code)), ("error", Json::string(msg))];
    if let Some((offset, context)) = at {
        fields.push(("offset", Json::from(offset)));
        if !context.is_empty() {
            fields.push(("context", Json::string(context)));
        }
    }
    Response::json(status, &Json::obj(fields))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::Timing;
    use crate::coordinator::{FleetConfig, Summary, UnlearnService};
    use anyhow::Result;

    /// Service double: echoes the canonical spec back in a summary.
    struct Echo;
    impl UnlearnService for Echo {
        fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary> {
            Ok(Summary {
                model: ModelId::default(),
                config_hash: 0,
                spec: spec.clone(),
                forget_acc: 0.02,
                retain_acc: 0.9,
                stop_depth: Some(1),
                macs_vs_ssd_pct: 11.0,
                sim_energy_mj: 1.0,
                sim_energy_vs_ssd_pct: 8.0,
                sim_ms: 0.0,
                rolled_back: false,
                timing: Timing { queue_ms: 0.0, service_ms: 0.0 },
                wal_seq: None,
                attest: None,
            })
        }
    }

    fn fleet() -> Fleet {
        Fleet::start_with(FleetConfig::default(), |_| Ok(Echo)).unwrap()
    }

    fn req(method: &str, target: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            target: target.to_string(),
            http11: true,
            headers: vec![],
            body: body.as_bytes().to_vec(),
        }
    }

    fn body(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(&resp.body).unwrap().trim()).unwrap()
    }

    #[test]
    fn healthz_reports_liveness() {
        let f = fleet();
        let resp = handle(&req("GET", "/healthz", ""), &f, None);
        assert_eq!(resp.status, 200);
        let j = body(&resp);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("alive").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("workers").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn healthz_degrades_to_503_when_a_worker_dies() {
        // single worker whose first request panics, with a factory that
        // only ever builds once — the respawn fails until give-up and
        // the fleet degrades permanently
        struct PanicOnce;
        impl UnlearnService for PanicOnce {
            fn unlearn(&mut self, _spec: &ForgetSpec) -> Result<Summary> {
                panic!("replica poisoned");
            }
        }
        let built = std::sync::atomic::AtomicUsize::new(0);
        let f = Fleet::start_with(
            FleetConfig { respawn_giveup: 1, ..FleetConfig::default() },
            move |_| {
                if built.fetch_add(1, std::sync::atomic::Ordering::SeqCst) == 0 {
                    Ok(PanicOnce)
                } else {
                    anyhow::bail!("no spare replica")
                }
            },
        )
        .unwrap();
        let reply = f.submit(ForgetSpec::Class(1)).recv().unwrap();
        assert!(matches!(&reply, Reply::Failed(e) if e.contains("panicked")), "{reply:?}");
        // wait out the respawn window (one ~10ms backoff attempt)
        let t0 = std::time::Instant::now();
        loop {
            let resp = handle(&req("GET", "/healthz", ""), &f, None);
            if resp.status == 503 {
                let j = body(&resp);
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
                assert_eq!(j.get("alive").unwrap().as_i64(), Some(0));
                assert_eq!(j.get("workers").unwrap().as_i64(), Some(1));
                break;
            }
            assert!(t0.elapsed() < std::time::Duration::from_secs(10), "healthz never degraded");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }

    #[test]
    fn stats_serves_the_fleet_rollup() {
        let f = fleet();
        let resp = handle(&req("GET", "/stats", ""), &f, None);
        assert_eq!(resp.status, 200);
        let j = body(&resp);
        assert!(j.get("rollup").unwrap().get("queue_p99_ms").is_some());
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn stats_exposes_supervision_and_durability_on_the_wire() {
        // supervision counters on a plain fleet; durability is null
        let f = fleet();
        let j = body(&handle(&req("GET", "/stats", ""), &f, None));
        assert_eq!(j.get("alive").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("rollup").unwrap().get("panics").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("rollup").unwrap().get("respawns").unwrap().as_i64(), Some(0));
        assert!(matches!(j.get("durability"), Some(Json::Null)));
        drop(f);

        // a durable fleet serves its ledger counters
        let dir = std::env::temp_dir()
            .join(format!("ficabu_routes_durable_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let f = Fleet::start_with_durable(
            FleetConfig::default(),
            |_| Ok(Echo),
            crate::coordinator::DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 },
        )
        .unwrap();
        let reply = f.submit(ForgetSpec::Class(2)).recv().unwrap();
        assert!(matches!(reply, Reply::Done(_)), "{reply:?}");
        let j = body(&handle(&req("GET", "/stats", ""), &f, None));
        let d = j.get("durability").unwrap();
        assert_eq!(d.get("wal_seq").unwrap().as_i64(), Some(1));
        assert_eq!(d.get("replayed").unwrap().as_i64(), Some(0));
        // Echo has no params: completions are ledgered, checkpoints skipped
        assert_eq!(d.get("checkpoints").unwrap().as_i64(), Some(0));
        assert!(d.get("generation").unwrap().as_i64().unwrap() >= 1);
        drop(f);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn audit_route_serves_the_chain_and_head() {
        // non-durable fleet: registered model, empty chain, genesis head
        let f = fleet();
        let resp = handle(&req("GET", "/models/default/audit", ""), &f, None);
        assert_eq!(resp.status, 200, "{:?}", body(&resp));
        let j = body(&resp);
        assert_eq!(j.get("chain_len").unwrap().as_i64(), Some(0));
        let genesis = crate::audit::AuditRecord::genesis_hash(&ModelId::default());
        assert_eq!(j.get("head_hash").unwrap().as_str(), Some(format!("{genesis:016x}").as_str()));
        // unknown model answers the machine-readable 404; bad method 405
        let resp = handle(&req("GET", "/models/tenant-b/audit", ""), &f, None);
        assert_eq!(resp.status, 404);
        assert_eq!(body(&resp).get("code").unwrap().as_str(), Some("unknown-model"));
        assert_eq!(handle(&req("POST", "/models/default/audit", ""), &f, None).status, 405);
        drop(f);

        // durable fleet: each completed forget appends one chained link
        let dir = std::env::temp_dir()
            .join(format!("ficabu_routes_audit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let f = Fleet::start_with_durable(
            FleetConfig::default(),
            |_| Ok(Echo),
            crate::coordinator::DurabilityConfig { dir: dir.clone(), checkpoint_every: 1 },
        )
        .unwrap();
        for class in [2, 5] {
            let reply = f.submit(ForgetSpec::Class(class)).recv().unwrap();
            assert!(matches!(reply, Reply::Done(_)), "{reply:?}");
        }
        let j = body(&handle(&req("GET", "/models/default/audit", ""), &f, None));
        assert_eq!(j.get("chain_len").unwrap().as_i64(), Some(2));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs[0].get("spec").unwrap().as_str(), Some("class:2"));
        assert_eq!(recs[1].get("spec").unwrap().as_str(), Some("class:5"));
        // the reported head is the last record's core hash: link 2's
        // prev_hash must equal link 1's core hash, and the chain must
        // verify end to end on disk
        let chain = f.audit_chain(&ModelId::default());
        assert_eq!(
            j.get("head_hash").unwrap().as_str(),
            Some(format!("{:016x}", chain[1].core_hash()).as_str())
        );
        assert_eq!(chain[1].prev_hash, chain[0].core_hash());
        drop(f);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forget_string_spec_round_trips() {
        let f = fleet();
        let resp = handle(&req("POST", "/forget", r#"{"spec": "class:3"}"#), &f, None);
        assert_eq!(resp.status, 200, "{:?}", body(&resp));
        let j = body(&resp);
        assert_eq!(j.get("code").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("summary").unwrap().get("spec").unwrap().as_str(), Some("class:3"));
    }

    #[test]
    fn forget_object_spec_is_canonicalized() {
        let f = fleet();
        let resp =
            handle(&req("POST", "/forget", r#"{"spec": {"classes": [4, 1, 1]}}"#), &f, None);
        assert_eq!(resp.status, 200);
        let j = body(&resp);
        assert_eq!(j.get("summary").unwrap().get("spec").unwrap().as_str(), Some("classes:1,4"));
    }

    #[test]
    fn missing_and_invalid_specs_are_400() {
        let f = fleet();
        let resp = handle(&req("POST", "/forget", r#"{"other": 1}"#), &f, None);
        assert_eq!(resp.status, 400);
        assert_eq!(body(&resp).get("code").unwrap().as_str(), Some("invalid_spec"));

        let resp = handle(&req("POST", "/forget", r#"{"spec": "bogus"}"#), &f, None);
        assert_eq!(resp.status, 400);
        let j = body(&resp);
        assert_eq!(j.get("code").unwrap().as_str(), Some("invalid_spec"));
        // the offset points at the spec value in the request body
        assert_eq!(j.get("offset").unwrap().as_i64(), Some(9));
    }

    #[test]
    fn malformed_json_carries_offset_and_context() {
        let f = fleet();
        let resp = handle(&req("POST", "/forget", r#"{"spec": bogus}"#), &f, None);
        assert_eq!(resp.status, 400);
        let j = body(&resp);
        assert_eq!(j.get("code").unwrap().as_str(), Some("bad_request"));
        assert_eq!(j.get("offset").unwrap().as_i64(), Some(9));
        assert!(j.get("context").unwrap().as_str().unwrap().contains("bogus"));
    }

    #[test]
    fn bounds_validation_rejects_out_of_range_specs() {
        let f = fleet();
        let resp = handle(&req("POST", "/forget", r#"{"spec": "class:99"}"#), &f, Some((10, 100)));
        assert_eq!(resp.status, 400);
        let j = body(&resp);
        assert_eq!(j.get("code").unwrap().as_str(), Some("invalid_spec"));
        assert!(j.get("error").unwrap().as_str().unwrap().contains("out of range"));
    }

    #[test]
    fn bad_deadlines_are_400() {
        let f = fleet();
        let r = req("POST", "/forget", r#"{"spec": "class:1", "deadline_ms": "soon"}"#);
        let resp = handle(&r, &f, None);
        assert_eq!(resp.status, 400);
        assert!(body(&resp).get("error").unwrap().as_str().unwrap().contains("deadline_ms"));

        let r = req("POST", "/forget", r#"{"spec": "class:1", "deadline_ms": -5}"#);
        assert_eq!(handle(&r, &f, None).status, 400);

        // out-of-Duration-domain values must 400, not panic the thread:
        // 1e999 saturates to +inf on parse, 1e308 is finite but overflows
        // Duration, NaN is unordered past a `< 0` guard
        for ms in ["1e999", "1e308", "NaN"] {
            let body = format!(r#"{{"spec": "class:1", "deadline_ms": {ms}}}"#);
            let resp = handle(&req("POST", "/forget", &body), &f, None);
            assert_eq!(resp.status, 400, "deadline_ms = {ms}");
        }
    }

    #[test]
    fn unknown_routes_and_methods() {
        let f = fleet();
        assert_eq!(handle(&req("GET", "/nope", ""), &f, None).status, 404);
        let resp = handle(&req("DELETE", "/forget", ""), &f, None);
        assert_eq!(resp.status, 405);
        assert!(resp.headers.iter().any(|(k, v)| *k == "allow" && v == "POST"));
        assert_eq!(handle(&req("POST", "/stats", ""), &f, None).status, 405);
        assert_eq!(handle(&req("POST", "/models", ""), &f, None).status, 405);
        assert_eq!(handle(&req("GET", "/models/x/forget", ""), &f, None).status, 405);
        // /models/{id} without the /forget leaf is not a route
        assert_eq!(handle(&req("POST", "/models/x", ""), &f, None).status, 404);
    }

    #[test]
    fn model_routes_on_a_single_model_fleet() {
        let f = fleet();
        // service-factory fleets have no model metadata to list
        let resp = handle(&req("GET", "/models", ""), &f, None);
        assert_eq!(resp.status, 200);
        assert_eq!(body(&resp).get("models").unwrap().as_arr().unwrap().len(), 0);
        // ...but still serve the default model under its address
        let resp =
            handle(&req("POST", "/models/default/forget", r#"{"spec": "class:2"}"#), &f, None);
        assert_eq!(resp.status, 200, "{:?}", body(&resp));
        let j = body(&resp);
        assert_eq!(j.get("summary").unwrap().get("model").unwrap().as_str(), Some("default"));
    }

    #[test]
    fn unknown_model_is_a_machine_readable_404() {
        let f = fleet();
        for r in [
            req("POST", "/models/tenant-b/forget", r#"{"spec": "class:1"}"#),
            req("POST", "/forget", r#"{"spec": "class:1", "model": "tenant-b"}"#),
        ] {
            let resp = handle(&r, &f, None);
            assert_eq!(resp.status, 404, "{} {}", r.method, r.target);
            let j = body(&resp);
            assert_eq!(j.get("code").unwrap().as_str(), Some("unknown-model"));
            assert!(j.get("error").unwrap().as_str().unwrap().contains("tenant-b"));
        }
    }

    #[test]
    fn invalid_model_ids_are_400() {
        let f = fleet();
        // path id with a character outside [A-Za-z0-9._-]
        let resp = handle(&req("POST", "/models/bad%20id/forget", r#"{"spec":"class:1"}"#), &f, None);
        assert_eq!(resp.status, 400);
        assert_eq!(body(&resp).get("code").unwrap().as_str(), Some("invalid_model"));
        // body model must be a JSON string
        let resp = handle(&req("POST", "/forget", r#"{"spec":"class:1","model":7}"#), &f, None);
        assert_eq!(resp.status, 400);
        assert_eq!(body(&resp).get("code").unwrap().as_str(), Some("invalid_model"));
    }

    #[test]
    fn body_model_field_addresses_the_default_model() {
        let f = fleet();
        let r = req("POST", "/forget", r#"{"spec": "class:4", "model": "default"}"#);
        let resp = handle(&r, &f, None);
        assert_eq!(resp.status, 200, "{:?}", body(&resp));
        assert_eq!(body(&resp).get("code").unwrap().as_str(), Some("done"));
    }
}
