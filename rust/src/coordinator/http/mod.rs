//! Wire-facing serving API: a zero-dependency HTTP/1.1 front-end on the
//! [`Fleet`].
//!
//! The paper's deployment story puts the unlearning engine on an edge
//! device that *other* software talks to; this module is that boundary.
//! No hyper/tokio — the offline vendor tree carries no async stack, and
//! a blocking [`TcpListener`] pool is the right size for a device that
//! serves forget requests, not web traffic:
//!
//! ```text
//!  clients ──► TcpListener ──► accept pool (threads × serve_connection)
//!                                   │  proto::read_request (framed)
//!                                   ▼
//!                              routes::handle ──► Fleet::submit ──► Reply
//!                                   │                 (blocking recv)
//!                                   ▼
//!               proto::Response (status from Reply::code, JSON body)
//! ```
//!
//! Endpoints and status mapping live in `routes`; message framing in
//! `proto`. Each accept thread serves its connection synchronously
//! (keep-alive included), so `threads` is the concurrent-connection cap
//! — admission control stays the fleet's job ([`Reply::Backpressure`] →
//! 429), the HTTP layer never queues.
//!
//! Health is supervision-aware: `GET /healthz` answers 200 only while
//! every fleet worker is alive, and 503 with
//! `{"ok":false,"alive":k,"workers":n}` while any worker is dead or
//! respawning after a panic — the signal a load balancer uses to drain
//! a degraded device.
//!
//! Shutdown is deliberate: [`HttpServer::shutdown`] flips the stop flag,
//! force-closes every registered live connection (unblocking reads
//! mid-keep-alive), wakes the accept threads with dummy connections, and
//! joins — so a fleet owner can always regain sole ownership of its
//! `Arc<Fleet>` afterwards.
//!
//! [`Reply::Backpressure`]: crate::coordinator::Reply::Backpressure

mod proto;
mod routes;

use std::collections::HashMap;
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::coordinator::dispatch::Fleet;

pub use routes::Bounds;

/// HTTP front-end tuning. `Default` = 2 accept threads, 64 KiB bodies,
/// no spec bounds (validation deferred to execution).
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Accept-pool size = concurrent-connection cap.
    pub threads: usize,
    /// Request body cap; larger bodies answer 413.
    pub max_body_bytes: usize,
    /// `(num_classes, num_samples)` to validate specs against at
    /// admission, so out-of-range requests 400 instead of occupying a
    /// queue slot to fail.
    pub bounds: Bounds,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig { threads: 2, max_body_bytes: 64 * 1024, bounds: None }
    }
}

/// Shared server state: what a connection needs to serve and what
/// shutdown needs to interrupt it.
struct ServerState {
    fleet: Arc<Fleet>,
    cfg: HttpConfig,
    stop: AtomicBool,
    /// Live connections by id — `try_clone` handles kept so shutdown can
    /// force-close sockets whose accept thread is blocked in a read.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
}

/// The running HTTP front-end. Bind with [`HttpServer::bind`], stop with
/// [`HttpServer::shutdown`]; dropping without shutdown also stops the
/// pool (so a panicking test does not leak accept threads).
pub struct HttpServer {
    state: Arc<ServerState>,
    addr: SocketAddr,
    handles: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8787`, port `0` for ephemeral) and
    /// start the accept pool over `fleet`.
    pub fn bind(addr: &str, fleet: Arc<Fleet>, cfg: HttpConfig) -> Result<HttpServer> {
        anyhow::ensure!(cfg.threads >= 1, "http config: threads must be >= 1");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http listener on {addr}"))?;
        let local = listener.local_addr()?;
        let state = Arc::new(ServerState {
            fleet,
            cfg,
            stop: AtomicBool::new(false),
            conns: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(state.cfg.threads);
        for tid in 0..state.cfg.threads {
            let st = Arc::clone(&state);
            let l = listener.try_clone()?;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ficabu-http-{tid}"))
                    .spawn(move || accept_loop(&st, &l))?,
            );
        }
        Ok(HttpServer { state, addr: local, handles })
    }

    /// The bound address (resolves port `0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, force-close live connections, join the pool. The
    /// fleet is *not* shut down — it outlives its front-end.
    pub fn shutdown(mut self) {
        self.stop();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    fn stop(&self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock reads first (connections registered after this sweep
        // observe the stop flag before their first read — see
        // serve_connection), then unblock the accepts.
        for (_, conn) in self.state.conns.lock().unwrap().drain() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        for _ in 0..self.handles.len() {
            let _ = TcpStream::connect(self.addr);
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        if !self.handles.is_empty() {
            self.stop();
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

fn accept_loop(st: &ServerState, listener: &TcpListener) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            // Transient (ECONNABORTED etc.): keep accepting — unless the
            // server is stopping, where an error may mean the listener
            // itself is gone.
            Err(_) => {
                if st.stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if st.stop.load(Ordering::SeqCst) {
            return;
        }
        // Socket errors are per-connection: drop it, keep accepting.
        let _ = serve_connection(st, stream);
        if st.stop.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// Serve one connection until close: register it for shutdown, then
/// request/response until the peer closes, errors, opts out of
/// keep-alive, or the server stops.
fn serve_connection(st: &ServerState, stream: TcpStream) -> std::io::Result<()> {
    let id = st.next_conn.fetch_add(1, Ordering::Relaxed);
    st.conns.lock().unwrap().insert(id, stream.try_clone()?);
    let out = serve_requests(st, stream);
    st.conns.lock().unwrap().remove(&id);
    out
}

fn serve_requests(st: &ServerState, mut stream: TcpStream) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    loop {
        // Ordering with `stop()`: the registry sweep happens *after* the
        // flag is set, so either this load sees the stop or the sweep
        // sees the registered socket and unblocks the read below.
        if st.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let req = match proto::read_request(&mut reader, st.cfg.max_body_bytes) {
            Ok(None) => return Ok(()),
            Ok(Some(r)) => r,
            Err(proto::ProtoError::Bad(msg)) => {
                let resp = routes::error(400, "bad_request", msg, None);
                return resp.write_to(&mut stream, false);
            }
            Err(proto::ProtoError::TooLarge { limit }) => {
                let msg = format!("body exceeds {limit} bytes");
                let resp = routes::error(413, "payload_too_large", msg, None);
                return resp.write_to(&mut stream, false);
            }
            Err(proto::ProtoError::Io(e)) => return Err(e),
        };
        let keep_alive = req.keep_alive() && !st.stop.load(Ordering::SeqCst);
        let resp = routes::handle(&req, &st.fleet, st.cfg.bounds);
        resp.write_to(&mut stream, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    // Request/response behavior over a real socket (including shutdown
    // mid-connection and backpressure) lives in tests/http_e2e.rs; here
    // we pin the lifecycle basics that don't need a client.
    use super::*;
    use crate::coordinator::queue::Timing;
    use crate::coordinator::{FleetConfig, Summary, UnlearnService};
    use crate::unlearn::ForgetSpec;

    struct Echo;
    impl UnlearnService for Echo {
        fn unlearn(&mut self, spec: &ForgetSpec) -> Result<Summary> {
            Ok(Summary {
                model: crate::coordinator::ModelId::default(),
                config_hash: 0,
                spec: spec.clone(),
                forget_acc: 0.0,
                retain_acc: 1.0,
                stop_depth: None,
                macs_vs_ssd_pct: 10.0,
                sim_energy_mj: 1.0,
                sim_energy_vs_ssd_pct: 8.0,
                sim_ms: 0.0,
                rolled_back: false,
                timing: Timing { queue_ms: 0.0, service_ms: 0.0 },
                wal_seq: None,
                attest: None,
            })
        }
    }

    #[test]
    fn binds_ephemeral_and_shuts_down() {
        let fleet = Arc::new(Fleet::start_with(FleetConfig::default(), |_| Ok(Echo)).unwrap());
        let srv = HttpServer::bind("127.0.0.1:0", Arc::clone(&fleet), HttpConfig::default())
            .unwrap();
        assert_ne!(srv.local_addr().port(), 0);
        srv.shutdown();
        // the front-end released its fleet handles: we are the sole owner
        let fleet = Arc::try_unwrap(fleet).ok().expect("server retained fleet handles");
        fleet.shutdown().unwrap();
    }

    #[test]
    fn rejects_zero_threads() {
        let fleet = Arc::new(Fleet::start_with(FleetConfig::default(), |_| Ok(Echo)).unwrap());
        let cfg = HttpConfig { threads: 0, ..HttpConfig::default() };
        assert!(HttpServer::bind("127.0.0.1:0", fleet, cfg).is_err());
    }

    #[test]
    fn drop_without_shutdown_stops_the_pool() {
        let fleet = Arc::new(Fleet::start_with(FleetConfig::default(), |_| Ok(Echo)).unwrap());
        {
            let _srv =
                HttpServer::bind("127.0.0.1:0", Arc::clone(&fleet), HttpConfig::default())
                    .unwrap();
        }
        assert!(Arc::try_unwrap(fleet).is_ok());
    }
}
