//! HTTP/1.1 message framing over std I/O — no hyper, no tokio.
//!
//! The subset a forget-request endpoint needs: request line + headers +
//! `Content-Length` bodies in, status line + headers + body out, with
//! keep-alive. Chunked transfer encoding is rejected (411/400), header
//! and body sizes are capped, and all parsing is byte-exact so malformed
//! requests fail with a reason instead of hanging the connection.

use std::io::{BufRead, Read, Write};

/// Cap on the request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// How much of an over-limit body is drained before answering 413.
/// Closing a socket with unread bytes in its receive buffer resets the
/// connection, which can discard the un-flushed response; draining what
/// the client already sent (bounded — an abusive declared length still
/// just closes) lets the 413 reach the peer.
const MAX_DRAIN_BYTES: usize = 256 * 1024;

/// One parsed request. Header names are lowercased on ingest; the
/// target keeps its raw form (`/forget`, `/stats?verbose=1`, ...).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    /// `false` for HTTP/1.0, whose connection semantics differ.
    pub http11: bool,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// The target's path component (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Keep-alive semantics per version: HTTP/1.1 defaults to
    /// persistent unless the client sent `Connection: close`; HTTP/1.0
    /// defaults to close unless it sent `Connection: keep-alive` (a
    /// plain 1.0 client would otherwise hang waiting for EOF).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.http11,
        }
    }
}

/// Framing failure while reading one request.
#[derive(Debug)]
pub enum ProtoError {
    /// Malformed head or body framing — answer 400 and close.
    Bad(String),
    /// Body exceeds the configured cap — answer 413 and close.
    TooLarge { limit: usize },
    /// Socket error or EOF mid-message — just drop the connection.
    Io(std::io::Error),
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> ProtoError {
        ProtoError::Io(e)
    }
}

/// Read one request off the connection. `Ok(None)` on clean EOF before
/// any request bytes (the peer closed an idle keep-alive connection).
pub fn read_request(
    r: &mut impl BufRead,
    max_body_bytes: usize,
) -> Result<Option<Request>, ProtoError> {
    let line = match read_line(r, true)? {
        None => return Ok(None),
        Some(l) => l,
    };
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
        _ => return Err(ProtoError::Bad(format!("malformed request line `{line}`"))),
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ProtoError::Bad(format!("unsupported version `{version}`")));
    }
    let mut headers = Vec::new();
    let mut head_bytes = line.len();
    loop {
        let line = match read_line(r, false)? {
            None => return Err(ProtoError::Io(std::io::ErrorKind::UnexpectedEof.into())),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        if head_bytes > MAX_HEAD_BYTES {
            return Err(ProtoError::Bad(format!("request head exceeds {MAX_HEAD_BYTES} bytes")));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| ProtoError::Bad(format!("malformed header `{line}`")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let find = |name: &str| headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str());
    if find("transfer-encoding").is_some() {
        return Err(ProtoError::Bad("chunked transfer encoding is not supported".to_string()));
    }
    // RFC 9112 §6.3: duplicate Content-Length is a framing ambiguity
    // (request-smuggling vector behind a proxy that honors the other
    // occurrence) — reject outright rather than pick one.
    if headers.iter().filter(|(k, _)| k == "content-length").count() > 1 {
        return Err(ProtoError::Bad("duplicate content-length header".to_string()));
    }
    let body = match find("content-length") {
        None => Vec::new(),
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| ProtoError::Bad(format!("bad content-length `{v}`")))?;
            if n > max_body_bytes {
                let drain = n.min(MAX_DRAIN_BYTES) as u64;
                let _ = std::io::copy(&mut r.by_ref().take(drain), &mut std::io::sink());
                return Err(ProtoError::TooLarge { limit: max_body_bytes });
            }
            let mut buf = vec![0u8; n];
            r.read_exact(&mut buf)?;
            buf
        }
    };
    Ok(Some(Request { method, target, http11: version == "HTTP/1.1", headers, body }))
}

/// Read one CRLF-terminated line (tolerating bare LF). `Ok(None)` on
/// EOF; when `eof_ok_at_start` is false an EOF before any byte is still
/// `None` and the caller decides.
fn read_line(r: &mut impl BufRead, _eof_ok_at_start: bool) -> Result<Option<String>, ProtoError> {
    let mut buf = Vec::with_capacity(64);
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Err(ProtoError::Io(std::io::ErrorKind::UnexpectedEof.into()));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    return Ok(Some(String::from_utf8(buf).map_err(|_| {
                        ProtoError::Bad("non-UTF-8 bytes in request head".to_string())
                    })?));
                }
                if buf.len() > MAX_HEAD_BYTES {
                    return Err(ProtoError::Bad(format!(
                        "header line exceeds {MAX_HEAD_BYTES} bytes"
                    )));
                }
                buf.push(byte[0]);
            }
            Err(e) => return Err(ProtoError::Io(e)),
        }
    }
}

/// One response, written with `Content-Length` framing.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(&'static str, String)>,
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response with `Content-Type: application/json`.
    pub fn json(status: u16, body: &crate::util::json::Json) -> Response {
        let mut text = String::new();
        body.write(&mut text);
        text.push('\n');
        Response {
            status,
            headers: vec![("content-type", "application/json".to_string())],
            body: text.into_bytes(),
        }
    }

    /// Add a header (chainable).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }

    /// Serialize onto the socket. `keep_alive` controls the
    /// `Connection` header; the caller closes the stream accordingly.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status, reason(self.status));
        for (k, v) in &self.headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n", self.body.len()));
        head.push_str(if keep_alive {
            "connection: keep-alive\r\n\r\n"
        } else {
            "connection: close\r\n\r\n"
        });
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for every status this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::io::BufReader;

    fn req(raw: &str) -> Result<Option<Request>, ProtoError> {
        read_request(&mut BufReader::new(raw.as_bytes()), 1024)
    }

    #[test]
    fn parses_a_post_with_body() {
        let r = req(
            "POST /forget HTTP/1.1\r\nHost: x\r\nContent-Length: 18\r\n\r\n{\"spec\":\"class:3\"}",
        );
        let r = r.unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path(), "/forget");
        assert_eq!(r.header("host"), Some("x"));
        assert_eq!(std::str::from_utf8(&r.body).unwrap(), "{\"spec\":\"class:3\"}");
        assert!(r.keep_alive());
    }

    #[test]
    fn exact_content_length_and_query_split() {
        let body = r#"{"spec":"class:3"}"#;
        let raw = format!(
            "POST /forget?src=test HTTP/1.1\r\ncontent-length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
        let r = req(&raw).unwrap().unwrap();
        assert_eq!(r.path(), "/forget");
        assert_eq!(std::str::from_utf8(&r.body).unwrap(), body);
        assert!(!r.keep_alive());
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(req("").unwrap().is_none());
    }

    #[test]
    fn http10_defaults_to_close() {
        let r = req("GET /healthz HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!r.http11);
        assert!(!r.keep_alive(), "a plain 1.0 client expects EOF framing");
        // explicit opt-in persists
        let r = req("GET /healthz HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n").unwrap().unwrap();
        assert!(r.keep_alive());
        let r = req("GET /healthz HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert!(r.http11 && r.keep_alive());
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        let r = req("POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 5\r\n\r\nhello");
        assert!(matches!(r, Err(ProtoError::Bad(_))));
        // even when the values agree: the duplication itself is the
        // smuggling vector
        let r = req("POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi");
        assert!(matches!(r, Err(ProtoError::Bad(_))));
    }

    #[test]
    fn malformed_heads_are_bad_requests() {
        assert!(matches!(req("GET\r\n\r\n"), Err(ProtoError::Bad(_))));
        assert!(matches!(req("GET / HTTP/2\r\n\r\n"), Err(ProtoError::Bad(_))));
        assert!(matches!(
            req("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"),
            Err(ProtoError::Bad(_))
        ));
        assert!(matches!(
            req("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            Err(ProtoError::Bad(_))
        ));
        assert!(matches!(
            req("POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"),
            Err(ProtoError::Bad(_))
        ));
    }

    #[test]
    fn oversized_bodies_are_too_large() {
        let r = req("POST / HTTP/1.1\r\nContent-Length: 99999\r\n\r\n");
        assert!(matches!(r, Err(ProtoError::TooLarge { limit: 1024 })));
        // an over-limit body that already arrived is drained, so the 413
        // can be written before the socket closes without a reset
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: 2000\r\n\r\n{}", "x".repeat(2000));
        let mut rd = BufReader::new(raw.as_bytes());
        assert!(matches!(read_request(&mut rd, 1024), Err(ProtoError::TooLarge { limit: 1024 })));
        let mut rest = Vec::new();
        rd.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "drained {} of 2000 body bytes", 2000 - rest.len());
    }

    #[test]
    fn truncated_body_is_io_error() {
        let r = req("POST / HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort");
        assert!(matches!(r, Err(ProtoError::Io(_))));
    }

    #[test]
    fn bare_lf_lines_are_tolerated() {
        let r = req("GET /healthz HTTP/1.1\nhost: y\n\n").unwrap().unwrap();
        assert_eq!(r.path(), "/healthz");
        assert_eq!(r.header("host"), Some("y"));
    }

    #[test]
    fn response_wire_format() {
        let resp = Response::json(429, &Json::obj(vec![("code", Json::from("backpressure"))]))
            .with_header("retry-after", "1");
        let mut out = Vec::new();
        resp.write_to(&mut out, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("content-type: application/json\r\n"));
        assert!(text.contains("retry-after: 1\r\n"));
        assert!(text.contains("connection: close\r\n\r\n"));
        let body = text.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(Json::parse(body.trim()).unwrap().get("code").unwrap().as_str(),
            Some("backpressure"));
    }
}
