//! Atomic parameter checkpoints for the durable serving fleet.
//!
//! A checkpoint is the full post-unlearn [`ParamStore`] — f32 masters
//! *and* the per-slot int8 weight copies when the store serves int8 —
//! plus the ledger generation and the scope it covers: the covering
//! sequence number and the `pending` seq list (every successful
//! completion with `seq <= covering_seq` of that generation that is
//! *not* listed as pending is baked into the parameters; pending seqs
//! were accepted but had no completion on disk at snapshot time, so
//! their edits — if they complete later — are not contained). Files
//! are named `ckpt-<generation>-<covering_seq>.fcp` with zero-padded
//! fields so lexicographic order is (generation, seq) order.
//!
//! `FICABUC3` added the audit section: the per-model
//! [`ChainHead`](crate::audit::ChainHead)s of the audit chain at
//! snapshot time, so `audit verify` can anchor the standalone
//! `audit.log` against the parameters a recovery would load. A
//! `FICABUC2` file fails the magic check and is skipped like any
//! invalid candidate — recovery degrades to full ledger replay.
//!
//! Writes are atomic: the body is written to a `.tmp` sibling, fsync'd,
//! renamed over the final name, and the directory is fsync'd — a crash
//! mid-write leaves a stale `.tmp` that is never loaded and is swept by
//! the next successful write. [`load_latest`] walks candidates newest
//! first and returns the first whose magic and CRC32 validate, so a
//! torn or bit-flipped checkpoint degrades to the previous one instead
//! of poisoning recovery.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::audit::ChainHead;
use crate::coordinator::registry::ModelId;
use crate::coordinator::wal::crc32;
use crate::model::ParamStore;
use crate::tensor::quant::QTensor;
use crate::tensor::Tensor;
use crate::testkit::faults;

const MAGIC: &[u8; 8] = b"FICABUC3";
const PREFIX: &str = "ckpt-";
const SUFFIX: &str = ".fcp";

/// One decoded checkpoint.
pub struct Checkpoint {
    pub params: ParamStore,
    /// Ledger generation the scope refers to.
    pub generation: u64,
    /// Every `Done` completion with `seq <= covering_seq` (same
    /// generation) that is not in `pending` is contained in `params`.
    pub covering_seq: u64,
    /// Seqs accepted but not completed on disk when the scope was
    /// snapshotted; their edits are *not* in `params` even when their
    /// seq is below the covering seq.
    pub pending: Vec<u64>,
    /// Per-model audit chain heads (durably persisted links only) at
    /// snapshot time — `audit verify` anchors the log against these.
    pub audit: Vec<ChainHead>,
}

fn file_name(generation: u64, covering_seq: u64) -> String {
    format!("{PREFIX}{generation:010}-{covering_seq:010}{SUFFIX}")
}

/// Atomically write a checkpoint into `dir` and prune older ones.
/// Returns the final path. Fault site: `checkpoint`.
pub fn write(
    dir: &Path,
    store: &ParamStore,
    generation: u64,
    covering_seq: u64,
    pending: &[u64],
    audit: &[ChainHead],
) -> Result<PathBuf> {
    faults::hit("checkpoint")?;
    let body = encode(store, generation, covering_seq, pending, audit);
    let name = file_name(generation, covering_seq);
    let path = dir.join(&name);
    let tmp = dir.join(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        use std::io::Write as _;
        f.write_all(&body)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    prune_older(dir, &name);
    Ok(path)
}

/// Load the newest checkpoint in `dir` that validates (magic + CRC32 +
/// decode), skipping corrupt or torn candidates with a note on stderr.
/// `.tmp` leftovers are never considered.
pub fn load_latest(dir: &Path) -> Result<Option<Checkpoint>> {
    let mut names = list_checkpoints(dir)?;
    names.sort();
    for name in names.iter().rev() {
        let path = dir.join(name);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        match decode(&bytes) {
            Ok(c) => return Ok(Some(c)),
            Err(e) => eprintln!("ficabu: skipping invalid checkpoint {}: {e:#}", path.display()),
        }
    }
    Ok(None)
}

fn list_checkpoints(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(names),
        Err(e) => return Err(e).with_context(|| format!("listing {}", dir.display())),
    };
    for entry in rd {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(PREFIX) && name.ends_with(SUFFIX) {
            names.push(name);
        }
    }
    Ok(names)
}

/// Remove every checkpoint older (lexicographically smaller) than
/// `keep`, plus stale `.tmp` files. Best-effort — failures are ignored;
/// a leftover file only wastes disk, never correctness.
fn prune_older(dir: &Path, keep: &str) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        let stale_ckpt = name.starts_with(PREFIX) && name.ends_with(SUFFIX) && name.as_str() < keep;
        let stale_tmp = name.starts_with(PREFIX) && name.ends_with(".tmp") && name != format!("{keep}.tmp");
        if stale_ckpt || stale_tmp {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

// --- codec --------------------------------------------------------------
//
// magic (8) | crc32(body) u32 LE | body
// body: generation u64 | covering_seq u64 |
//       npending u32, pending seqs u64 LE... | nseg u32 |
//       per segment: nparam u32, per param: rank u32, dims u32...,
//                    f32 LE data |
//       quantized u8 | if 1, per segment, per slot:
//           present u8 | if 1: rank u32, dims u32..., nscales u32,
//                        scales f32 LE, data i8 raw |
//       nmodels u32 | per model: id_len u32, id bytes,
//                     chain_len u64, head_hash u64

fn encode(
    store: &ParamStore,
    generation: u64,
    covering_seq: u64,
    pending: &[u64],
    audit: &[ChainHead],
) -> Vec<u8> {
    let mut body = Vec::new();
    body.extend_from_slice(&generation.to_le_bytes());
    body.extend_from_slice(&covering_seq.to_le_bytes());
    body.extend_from_slice(&(pending.len() as u32).to_le_bytes());
    for &seq in pending {
        body.extend_from_slice(&seq.to_le_bytes());
    }
    body.extend_from_slice(&(store.seg.len() as u32).to_le_bytes());
    for s in &store.seg {
        body.extend_from_slice(&(s.len() as u32).to_le_bytes());
        for t in s {
            push_shape(&mut body, &t.shape);
            for v in &t.data {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    let quantized = store.is_quantized();
    body.push(u8::from(quantized));
    if quantized {
        for k in 0..store.seg.len() {
            for slot in store.qseg(k).unwrap() {
                match slot {
                    None => body.push(0u8),
                    Some(q) => {
                        body.push(1u8);
                        push_shape(&mut body, &q.shape);
                        body.extend_from_slice(&(q.scales.len() as u32).to_le_bytes());
                        for v in &q.scales {
                            body.extend_from_slice(&v.to_le_bytes());
                        }
                        // i8 round-trips through u8 bit-exactly
                        body.extend(q.data.iter().map(|&v| v as u8));
                    }
                }
            }
        }
    }
    body.extend_from_slice(&(audit.len() as u32).to_le_bytes());
    for h in audit {
        let id = h.model.as_str();
        body.extend_from_slice(&(id.len() as u32).to_le_bytes());
        body.extend_from_slice(id.as_bytes());
        body.extend_from_slice(&h.chain_len.to_le_bytes());
        body.extend_from_slice(&h.head_hash.to_le_bytes());
    }
    let mut out = Vec::with_capacity(body.len() + 12);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

fn decode(bytes: &[u8]) -> Result<Checkpoint> {
    if bytes.len() < 12 || &bytes[..8] != MAGIC {
        bail!("bad checkpoint magic");
    }
    let crc = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    let body = &bytes[12..];
    if crc32(body) != crc {
        bail!("checkpoint CRC mismatch");
    }
    let mut pos = 0usize;
    let generation = read_u64(body, &mut pos)?;
    let covering_seq = read_u64(body, &mut pos)?;
    let npending = read_u32(body, &mut pos)? as usize;
    if npending > (body.len() - pos) / 8 {
        bail!("implausible pending count {npending}");
    }
    let mut pending = Vec::with_capacity(npending);
    for _ in 0..npending {
        pending.push(read_u64(body, &mut pos)?);
    }
    let nseg = read_u32(body, &mut pos)? as usize;
    let mut seg = Vec::with_capacity(nseg);
    for _ in 0..nseg {
        let np = read_u32(body, &mut pos)? as usize;
        let mut ps = Vec::with_capacity(np);
        for _ in 0..np {
            let shape = read_shape(body, &mut pos)?;
            let n: usize = shape.iter().product();
            let raw = take(body, &mut pos, n * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ps.push(Tensor::new(shape, data)?);
        }
        seg.push(ps);
    }
    let quantized = *take(body, &mut pos, 1)?.first().unwrap() != 0;
    let quant = if quantized {
        let mut qseg = Vec::with_capacity(seg.len());
        for s in &seg {
            let mut qs = Vec::with_capacity(s.len());
            for _ in 0..s.len() {
                let present = *take(body, &mut pos, 1)?.first().unwrap() != 0;
                if !present {
                    qs.push(None);
                    continue;
                }
                let shape = read_shape(body, &mut pos)?;
                let nscales = read_u32(body, &mut pos)? as usize;
                let raw = take(body, &mut pos, nscales * 4)?;
                let scales = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let n: usize = shape.iter().product();
                let data = take(body, &mut pos, n)?.iter().map(|&v| v as i8).collect();
                qs.push(Some(QTensor { shape, data, scales }));
            }
            qseg.push(qs);
        }
        Some(qseg)
    } else {
        None
    };
    let nmodels = read_u32(body, &mut pos)? as usize;
    if nmodels > (body.len() - pos) / 20 {
        bail!("implausible audit head count {nmodels}");
    }
    let mut audit = Vec::with_capacity(nmodels);
    for _ in 0..nmodels {
        let n = read_u32(body, &mut pos)? as usize;
        let raw = take(body, &mut pos, n)?;
        let id = std::str::from_utf8(raw).context("audit head model id is not utf-8")?;
        let model = ModelId::new(id)?;
        let chain_len = read_u64(body, &mut pos)?;
        let head_hash = read_u64(body, &mut pos)?;
        audit.push(ChainHead { model, chain_len, head_hash });
    }
    if pos != body.len() {
        bail!("checkpoint has {} trailing bytes", body.len() - pos);
    }
    Ok(Checkpoint {
        params: ParamStore::from_parts(seg, quant)?,
        generation,
        covering_seq,
        pending,
        audit,
    })
}

fn push_shape(buf: &mut Vec<u8>, shape: &[usize]) {
    buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for &d in shape {
        buf.extend_from_slice(&(d as u32).to_le_bytes());
    }
}

fn read_shape(b: &[u8], pos: &mut usize) -> Result<Vec<usize>> {
    let rank = read_u32(b, pos)? as usize;
    if rank > 8 {
        bail!("implausible tensor rank {rank}");
    }
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u32(b, pos)? as usize);
    }
    Ok(shape)
}

fn take<'a>(b: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > b.len() {
        bail!("checkpoint truncated at byte {pos}");
    }
    let s = &b[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

fn read_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let r = take(b, pos, 4)?;
    Ok(u32::from_le_bytes([r[0], r[1], r[2], r[3]]))
}

fn read_u64(b: &[u8], pos: &mut usize) -> Result<u64> {
    let r = take(b, pos, 8)?;
    Ok(u64::from_le_bytes(r.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelMeta;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficabu_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn assert_bitwise_eq(a: &ParamStore, b: &ParamStore) {
        let (fa, fb) = (a.flat(), b.flat());
        assert_eq!(fa.len(), fb.len());
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.shape, y.shape);
            assert!(x.data.iter().zip(&y.data).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
        for k in 0..a.seg.len() {
            match (a.qseg(k), b.qseg(k)) {
                (None, None) => {}
                (Some(qa), Some(qb)) => {
                    for (sa, sb) in qa.iter().zip(qb) {
                        match (sa, sb) {
                            (None, None) => {}
                            (Some(x), Some(y)) => {
                                assert_eq!(x.shape, y.shape);
                                assert_eq!(x.data, y.data);
                                assert!(x
                                    .scales
                                    .iter()
                                    .zip(&y.scales)
                                    .all(|(p, q)| p.to_bits() == q.to_bits()));
                            }
                            _ => panic!("int8 slot presence differs"),
                        }
                    }
                }
                _ => panic!("quantization state differs"),
            }
        }
    }

    #[test]
    fn roundtrip_f32_and_int8() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        for int8 in [false, true] {
            let dir = tmpdir(if int8 { "rt8" } else { "rt32" });
            let mut store = ParamStore::init(&meta, 11);
            if int8 {
                store.quantize_int8(&meta);
            }
            let heads = vec![
                ChainHead { model: ModelId::default(), chain_len: 4, head_hash: 0xfeed_beef },
                ChainHead {
                    model: ModelId::new("tenant-b").unwrap(),
                    chain_len: 1,
                    head_hash: 0x1234_5678_9abc_def0,
                },
            ];
            write(&dir, &store, 2, 7, &[3, 6], &heads).unwrap();
            let c = load_latest(&dir).unwrap().expect("checkpoint present");
            assert_eq!((c.generation, c.covering_seq), (2, 7));
            assert_eq!(c.pending, [3, 6]);
            assert_eq!(c.audit, heads, "audit heads roundtrip");
            assert_eq!(c.params.is_quantized(), int8);
            assert_bitwise_eq(&store, &c.params);
            c.params.validate(&meta).unwrap();
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn newest_wins_and_older_are_pruned() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let dir = tmpdir("newest");
        let s1 = ParamStore::init(&meta, 1);
        let s2 = ParamStore::init(&meta, 2);
        write(&dir, &s1, 1, 3, &[], &[]).unwrap();
        write(&dir, &s2, 1, 8, &[], &[]).unwrap();
        let c = load_latest(&dir).unwrap().unwrap();
        assert_eq!(c.covering_seq, 8);
        assert_bitwise_eq(&s2, &c.params);
        assert_eq!(list_checkpoints(&dir).unwrap().len(), 1, "older checkpoint pruned");
        // a later generation with a smaller seq still wins
        write(&dir, &s1, 2, 1, &[], &[]).unwrap();
        let c = load_latest(&dir).unwrap().unwrap();
        assert_eq!((c.generation, c.covering_seq), (2, 1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let dir = tmpdir("corrupt");
        let good = ParamStore::init(&meta, 5);
        write(&dir, &good, 1, 4, &[], &[]).unwrap();
        // a "newer" file that is pure garbage, plus a torn .tmp
        std::fs::write(dir.join(file_name(1, 9)), b"garbage").unwrap();
        std::fs::write(dir.join(format!("{}.tmp", file_name(1, 12))), b"half").unwrap();
        let c = load_latest(&dir).unwrap().unwrap();
        assert_eq!(c.covering_seq, 4, "falls back past the corrupt newest");
        assert_bitwise_eq(&good, &c.params);
        // bit flip inside a valid file: CRC catches it
        let path = dir.join(file_name(1, 4));
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n / 2] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        std::fs::remove_file(dir.join(file_name(1, 9))).unwrap();
        assert!(load_latest(&dir).unwrap().is_none(), "no valid checkpoint left");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let dir = std::env::temp_dir().join(format!("ficabu_ckpt_none_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_latest(&dir).unwrap().is_none());
    }
}
