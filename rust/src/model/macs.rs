//! MAC accounting — the paper's hardware-relevant computation proxy.
//!
//! Table I/IV report MACs relative to SSD, *including* checkpoint
//! evaluation overhead. This module prices every phase of the unlearning
//! procedure from the per-segment analytic counts in meta.json:
//!
//! * forward (cache) pass: sum of segment fwd MACs at batch N
//! * backward (grad) pass per segment: ~2x fwd (grad wrt input + params)
//! * FIMD: one square+accumulate per parameter per microbatch
//! * Dampening: compare + beta-multiply per parameter (2 ops)
//! * checkpoint partial inference: fwd MACs of segments l..1 at batch N

use crate::config::ModelMeta;

/// Ledger of MACs by phase; `total()` is what the tables normalize.
#[derive(Debug, Default, Clone)]
pub struct MacLedger {
    pub forward: u64,
    pub backward: u64,
    pub fisher: u64,
    pub dampen: u64,
    pub checkpoint: u64,
}

impl MacLedger {
    pub fn total(&self) -> u64 {
        self.forward + self.backward + self.fisher + self.dampen + self.checkpoint
    }

    /// MACs of the *unlearning edit itself*: gradient/Fisher backward
    /// stream + dampening + checkpoint partial inference. The Step-0
    /// forward is excluded — its activations come from the inference the
    /// deployed model already ran on the forget samples (the paper's
    /// Table I PinsFace entry, 0.00137% of SSD, is only reachable under
    /// this accounting; with the forward included the floor would be
    /// ~33%). `total()` (with forward) still feeds the energy model.
    pub fn editing_total(&self) -> u64 {
        self.backward + self.fisher + self.dampen + self.checkpoint
    }

    pub fn add(&mut self, other: &MacLedger) {
        self.forward += other.forward;
        self.backward += other.backward;
        self.fisher += other.fisher;
        self.dampen += other.dampen;
        self.checkpoint += other.checkpoint;
    }
}

pub fn fwd_macs(meta: &ModelMeta, k: usize, batch: usize) -> u64 {
    meta.segments[k].macs_fwd_per_sample * batch as u64
}

/// Grad wrt inputs + grad wrt params: standard 2x-forward estimate.
pub fn bwd_macs(meta: &ModelMeta, k: usize, batch: usize) -> u64 {
    2 * fwd_macs(meta, k, batch)
}

/// FIMD square+accumulate over all params of segment k, all microbatches.
pub fn fisher_macs(meta: &ModelMeta, k: usize, num_microbatches: usize) -> u64 {
    meta.segments[k].param_count() as u64 * num_microbatches as u64
}

/// Dampening compare + multiply over all params of segment k.
pub fn dampen_macs(meta: &ModelMeta, k: usize) -> u64 {
    2 * meta.segments[k].param_count() as u64
}

/// Partial inference from segment k to the head, batch N.
pub fn partial_inference_macs(meta: &ModelMeta, from_seg: usize, batch: usize) -> u64 {
    (from_seg..meta.num_segments())
        .map(|k| fwd_macs(meta, k, batch))
        .sum()
}

/// Full forward at batch N.
pub fn full_forward_macs(meta: &ModelMeta, batch: usize) -> u64 {
    partial_inference_macs(meta, 0, batch)
}

/// The SSD baseline ledger: one cached forward, then Fisher + dampening on
/// EVERY segment (full backward chain), no checkpoints.
pub fn ssd_ledger(meta: &ModelMeta, batch: usize) -> MacLedger {
    let num_mb = batch / meta.microbatch;
    let mut ledger = MacLedger {
        forward: full_forward_macs(meta, batch),
        ..Default::default()
    };
    for k in 0..meta.num_segments() {
        ledger.backward += bwd_macs(meta, k, batch);
        ledger.fisher += fisher_macs(meta, k, num_mb);
        ledger.dampen += dampen_macs(meta, k);
    }
    ledger
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelMeta;

    fn meta() -> ModelMeta {
        ModelMeta::builtin("rn18slim").unwrap()
    }

    #[test]
    fn partial_cheaper_than_full() {
        let m = meta();
        let full = full_forward_macs(&m, 64);
        let tail = partial_inference_macs(&m, m.num_segments() - 1, 64);
        assert!(tail < full / 10, "head-only {tail} vs full {full}");
        assert_eq!(partial_inference_macs(&m, 0, 64), full);
    }

    #[test]
    fn ssd_ledger_dominated_by_gemm() {
        let m = meta();
        let l = ssd_ledger(&m, 64);
        assert!(l.forward > 0 && l.backward > 0);
        // fwd+bwd (GEMM work) must dominate the elementwise IP work --
        // that's why the paper hides FIMD/damp latency in the GEMM window
        assert!(l.forward + l.backward > 10 * (l.fisher + l.dampen));
        assert_eq!(l.backward, 2 * l.forward);
        assert_eq!(l.checkpoint, 0);
    }

    #[test]
    fn ledger_add() {
        let m = meta();
        let mut a = ssd_ledger(&m, 64);
        let b = a.clone();
        a.add(&b);
        assert_eq!(a.total(), 2 * b.total());
    }
}
