//! Activation cache — Algorithm 1, Step 0.
//!
//! One forward pass over the forget batch caches the *input* tensor of
//! every segment (``activation[l, n]`` in the paper) plus the final
//! logits. Because Context-Adaptive Unlearning edits strictly back-end
//! first, the cached input of segment l stays exact while segments
//! l..1 are being edited (everything *upstream* of l is untouched), so
//! checkpoint partial inference and the lazy Fisher backprop can both
//! start from the cache without re-running the front-end.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

#[derive(Clone)]
pub struct ActivationCache {
    /// `inputs[k]` = batched input to segment k (forward order).
    pub inputs: Vec<Tensor>,
    /// Logits of the cached forward pass (batch x classes).
    pub logits: Tensor,
}

impl ActivationCache {
    pub fn new(inputs: Vec<Tensor>, logits: Tensor) -> ActivationCache {
        ActivationCache { inputs, logits }
    }

    pub fn num_segments(&self) -> usize {
        self.inputs.len()
    }

    /// Input of segment `k`, sliced to a microbatch for the FIMD stream.
    pub fn microbatch_input(&self, k: usize, mb: usize, mb_size: usize) -> Result<Tensor> {
        if k >= self.inputs.len() {
            bail!("segment {} out of {}", k, self.inputs.len());
        }
        self.inputs[k].slice_batch(mb * mb_size, mb_size)
    }

    /// Logits sliced to a microbatch (starting point of the grad stream).
    pub fn microbatch_logits(&self, mb: usize, mb_size: usize) -> Result<Tensor> {
        self.logits.slice_batch(mb * mb_size, mb_size)
    }

    /// Host memory held by the cache, in bytes (reported by the hwsim DDR
    /// model and the perf pass).
    pub fn bytes(&self) -> usize {
        let n: usize = self.inputs.iter().map(|t| t.len()).sum::<usize>() + self.logits.len();
        n * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> ActivationCache {
        let a = Tensor::new(vec![4, 2], (0..8).map(|v| v as f32).collect()).unwrap();
        let b = Tensor::new(vec![4, 3], (0..12).map(|v| v as f32).collect()).unwrap();
        let logits = Tensor::new(vec![4, 5], vec![0.0; 20]).unwrap();
        ActivationCache::new(vec![a, b], logits)
    }

    #[test]
    fn microbatch_slicing() {
        let c = cache();
        let mb = c.microbatch_input(0, 1, 2).unwrap();
        assert_eq!(mb.shape, vec![2, 2]);
        assert_eq!(mb.data, vec![4.0, 5.0, 6.0, 7.0]);
        assert!(c.microbatch_input(2, 0, 2).is_err());
        assert!(c.microbatch_input(0, 2, 2).is_err());
    }

    #[test]
    fn bytes_accounting() {
        let c = cache();
        assert_eq!(c.bytes(), (8 + 12 + 20) * 4);
    }
}
