//! Model graph driver: per-segment compiled modules + parameter store +
//! activation cache + MAC accounting.

pub mod acts;
pub mod graph;
pub mod macs;
pub mod params;

pub use acts::ActivationCache;
pub use graph::Model;
pub use params::{CowParams, ParamAccess, ParamStore, SegmentSnapshot};
