//! Compiled model graph: drives the per-segment AOT modules.
//!
//! Argument order contract (see `python/compile/aot.py`):
//!   fwd_k:      (params_k..., x)        -> (y,)
//!   bwd_k:      (params_k..., x, gy)    -> (grads_k..., gx)
//!   logits:     (all params..., x)      -> (logits,)
//!   train_step: (all params..., x, onehot, lr) -> (new params..., loss)
//!   loss_grad:  (logits, onehot)        -> (dlogits,)

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelMeta;
use crate::model::params::ParamAccess;
use crate::model::{ActivationCache, ParamStore};
use crate::runtime::{ArgRef, Executable, ModuleSpec, Precision, Runtime};
use crate::tensor::Tensor;

/// A model's compiled modules. Every executable is an immutable
/// `Send + Sync` program behind `Arc`, so a `Model` (and anything built
/// on it, e.g. a registry's `CompiledModel`) can be shared across fleet
/// worker threads without a per-worker rebuild. Read paths take the
/// parameters as `&dyn ParamAccess`, so the same graph serves an owned
/// drifting [`ParamStore`] and a per-request copy-on-write overlay.
pub struct Model {
    pub meta: ModelMeta,
    fwd: Vec<Arc<Executable>>,
    bwd: Vec<Arc<Executable>>,
    logits_exe: Arc<Executable>,
    train_step_exe: Arc<Executable>,
    loss_grad_exe: Arc<Executable>,
}

impl Model {
    /// Compile (or fetch from the runtime cache) every module of a model.
    pub fn load(rt: &Runtime, meta: ModelMeta) -> Result<Model> {
        let mut fwd = Vec::new();
        let mut bwd = Vec::new();
        for k in 0..meta.num_segments() {
            fwd.push(rt.load(&ModuleSpec::SegmentFwd { meta: meta.clone(), seg: k })?);
            bwd.push(rt.load(&ModuleSpec::SegmentBwd { meta: meta.clone(), seg: k })?);
        }
        let logits_exe = rt.load(&ModuleSpec::Logits { meta: meta.clone() })?;
        let train_step_exe = rt.load(&ModuleSpec::TrainStep { meta: meta.clone() })?;
        let loss_grad_exe = rt.load(&ModuleSpec::LossGrad { meta: meta.clone() })?;
        Ok(Model { meta, fwd, bwd, logits_exe, train_step_exe, loss_grad_exe })
    }

    pub fn num_segments(&self) -> usize {
        self.meta.num_segments()
    }

    /// Serving precision implied by the store: quantized -> int8.
    pub fn store_precision(params: &dyn ParamAccess) -> Precision {
        if params.is_quantized() {
            Precision::Int8
        } else {
            Precision::F32
        }
    }

    /// Parameter arguments of segment `k` at the requested precision:
    /// int8 weight slots where the store has them, f32 otherwise.
    fn seg_args<'a>(params: &'a dyn ParamAccess, k: usize, prec: Precision) -> Vec<ArgRef<'a>> {
        match (prec, params.qseg(k)) {
            (Precision::Int8, Some(qs)) => params
                .seg(k)
                .iter()
                .zip(qs)
                .map(|(t, q)| match q {
                    Some(qt) => ArgRef::Quant(qt),
                    None => ArgRef::F32(t),
                })
                .collect(),
            _ => params.seg(k).iter().map(ArgRef::F32).collect(),
        }
    }

    fn check_precision(params: &dyn ParamAccess, prec: Precision) -> Result<()> {
        if prec == Precision::Int8 && !params.is_quantized() {
            bail!("int8 forward requested on an unquantized store (ParamStore::quantize_int8)");
        }
        Ok(())
    }

    /// Whole-model forward through the fused `logits` module (batch =
    /// meta.batch), at the store's native precision.
    pub fn logits(&self, params: &dyn ParamAccess, x: &Tensor) -> Result<Tensor> {
        self.logits_prec(params, x, Self::store_precision(params))
    }

    /// [`Model::logits`] at an explicit precision.
    pub fn logits_prec(
        &self,
        params: &dyn ParamAccess,
        x: &Tensor,
        prec: Precision,
    ) -> Result<Tensor> {
        Self::check_precision(params, prec)?;
        let mut args: Vec<ArgRef> = Vec::new();
        for k in 0..self.num_segments() {
            args.extend(Self::seg_args(params, k, prec));
        }
        args.push(ArgRef::F32(x));
        let mut out = self.logits_exe.run_mixed(&args)?;
        Ok(out.pop().context("logits output")?)
    }

    /// Segment-by-segment forward that caches each segment's input —
    /// Algorithm 1 Step 0 — at the store's native precision.
    pub fn forward_cached(&self, params: &dyn ParamAccess, x: &Tensor) -> Result<ActivationCache> {
        self.forward_cached_prec(params, x, Self::store_precision(params))
    }

    /// [`Model::forward_cached`] at an explicit precision.
    pub fn forward_cached_prec(
        &self,
        params: &dyn ParamAccess,
        x: &Tensor,
        prec: Precision,
    ) -> Result<ActivationCache> {
        Self::check_precision(params, prec)?;
        let mut inputs = Vec::with_capacity(self.num_segments());
        let mut h = x.clone();
        for (k, exe) in self.fwd.iter().enumerate() {
            inputs.push(h.clone());
            let mut args = Self::seg_args(params, k, prec);
            args.push(ArgRef::F32(&h));
            let mut out = exe.run_mixed(&args)?;
            h = out.pop().with_context(|| format!("fwd[{k}] output"))?;
        }
        Ok(ActivationCache::new(inputs, h))
    }

    /// Partial inference (Algorithm 1): resume from the cached input of
    /// segment `from_seg` and run through the back-end to logits, using the
    /// *current* (possibly dampened) parameters.
    pub fn partial_forward(
        &self,
        params: &dyn ParamAccess,
        from_seg: usize,
        act: &Tensor,
    ) -> Result<Tensor> {
        self.partial_forward_prec(params, from_seg, act, Self::store_precision(params))
    }

    /// [`Model::partial_forward`] at an explicit precision.
    pub fn partial_forward_prec(
        &self,
        params: &dyn ParamAccess,
        from_seg: usize,
        act: &Tensor,
        prec: Precision,
    ) -> Result<Tensor> {
        Self::check_precision(params, prec)?;
        if from_seg >= self.num_segments() {
            bail!("partial_forward: segment {} out of range", from_seg);
        }
        let mut h = act.clone();
        for k in from_seg..self.num_segments() {
            let mut args = Self::seg_args(params, k, prec);
            args.push(ArgRef::F32(&h));
            let mut out = self.fwd[k].run_mixed(&args)?;
            h = out.pop().with_context(|| format!("fwd[{k}] output"))?;
        }
        Ok(h)
    }

    /// Per-segment VJP: returns (param grads in meta order, input grad).
    pub fn segment_bwd(
        &self,
        k: usize,
        params: &dyn ParamAccess,
        x_mb: &Tensor,
        gy: &Tensor,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let mut args: Vec<&Tensor> = params.seg(k).iter().collect();
        args.push(x_mb);
        args.push(gy);
        let mut out = self.bwd[k].run(&args)?;
        let gx = out.pop().with_context(|| format!("bwd[{k}] gx"))?;
        Ok((out, gx))
    }

    /// dlogits of the mean NLL over a microbatch.
    pub fn loss_grad(&self, logits_mb: &Tensor, onehot_mb: &Tensor) -> Result<Tensor> {
        let mut out = self.loss_grad_exe.run(&[logits_mb, onehot_mb])?;
        Ok(out.pop().context("loss_grad output")?)
    }

    /// One SGD step in place; returns the loss.
    pub fn train_step(
        &self,
        params: &mut ParamStore,
        x: &Tensor,
        onehot: &Tensor,
        lr: f32,
    ) -> Result<f32> {
        let lr_t = Tensor::scalar(lr);
        let mut args = params.flat();
        args.push(x);
        args.push(onehot);
        args.push(&lr_t);
        let mut out = self.train_step_exe.run(&args)?;
        let loss = out.pop().context("train_step loss")?;
        params.set_flat(out)?;
        Ok(loss.data[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelMeta;
    use crate::util::prng::Pcg32;

    fn rand_batch(meta: &ModelMeta, batch: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::seeded(seed);
        let n: usize = meta.input_shape.iter().product::<usize>() * batch;
        let mut shape = vec![batch];
        shape.extend_from_slice(&meta.input_shape);
        Tensor::new(shape, rng.normal_vec(n, 1.0)).unwrap()
    }

    #[test]
    fn cached_forward_matches_fused_logits() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let params = ParamStore::init(&meta, 11);
        let x = rand_batch(&meta, meta.batch, 42);
        let cache = model.forward_cached(&params, &x).unwrap();
        let fused = model.logits(&params, &x).unwrap();
        assert_eq!(cache.logits.shape, fused.shape);
        for (a, b) in cache.logits.data.iter().zip(&fused.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        assert_eq!(cache.num_segments(), meta.num_segments());
    }

    #[test]
    fn partial_forward_from_cache_matches_full() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let params = ParamStore::init(&meta, 13);
        let x = rand_batch(&meta, meta.batch, 44);
        let cache = model.forward_cached(&params, &x).unwrap();
        // resume from the middle: same logits as the cached full pass
        let mid = meta.num_segments() / 2;
        let resumed = model.partial_forward(&params, mid, &cache.inputs[mid]).unwrap();
        for (a, b) in resumed.data.iter().zip(&cache.logits.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn int8_forward_tracks_snapped_f32_forward() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let mut params = ParamStore::init(&meta, 19);
        let x = rand_batch(&meta, meta.batch, 48);
        params.quantize_int8(&meta);
        assert_eq!(Model::store_precision(&params), Precision::Int8);
        // f32 forward over the snapped masters = the reference the int8
        // path approximates (weights identical, activations quantized)
        let snapped = model.logits_prec(&params, &x, Precision::F32).unwrap();
        let int8 = model.logits(&params, &x).unwrap();
        assert_eq!(int8.shape, snapped.shape);
        let num: f32 = int8
            .data
            .iter()
            .zip(&snapped.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = snapped.data.iter().map(|v| v * v).sum();
        let rel = (num / den.max(1e-12)).sqrt();
        assert!(rel < 0.35, "int8 logits diverge: rel L2 {rel}");
        // partial/full consistency on the int8 path
        let cache = model.forward_cached(&params, &x).unwrap();
        for (a, b) in cache.logits.data.iter().zip(&int8.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        let mid = meta.num_segments() / 2;
        let resumed = model.partial_forward(&params, mid, &cache.inputs[mid]).unwrap();
        for (a, b) in resumed.data.iter().zip(&cache.logits.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn int8_forward_on_unquantized_store_rejected() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let params = ParamStore::init(&meta, 25);
        let x = rand_batch(&meta, meta.batch, 50);
        assert!(model.logits_prec(&params, &x, Precision::Int8).is_err());
    }

    #[test]
    fn train_step_reduces_loss() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let mut params = ParamStore::init(&meta, 15);
        let x = rand_batch(&meta, meta.batch, 46);
        let mut onehot = Tensor::zeros(vec![meta.batch, meta.num_classes]);
        for i in 0..meta.batch {
            onehot.data[i * meta.num_classes + (i % meta.num_classes)] = 1.0;
        }
        let l0 = model.train_step(&mut params, &x, &onehot, 0.05).unwrap();
        let mut last = l0;
        for _ in 0..4 {
            last = model.train_step(&mut params, &x, &onehot, 0.05).unwrap();
        }
        assert!(last < l0, "loss {l0} -> {last}");
    }

    #[test]
    fn loss_grad_rows_sum_zero() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let mb = meta.microbatch;
        let mut rng = Pcg32::seeded(5);
        let logits = Tensor::new(vec![mb, meta.num_classes],
            rng.normal_vec(mb * meta.num_classes, 1.0)).unwrap();
        let mut onehot = Tensor::zeros(vec![mb, meta.num_classes]);
        for i in 0..mb {
            onehot.data[i * meta.num_classes + (i % meta.num_classes)] = 1.0;
        }
        let g = model.loss_grad(&logits, &onehot).unwrap();
        for i in 0..mb {
            let s: f32 = g.row(i).iter().sum();
            assert!(s.abs() < 1e-5);
        }
    }

    #[test]
    fn segment_bwd_shapes() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let params = ParamStore::init(&meta, 17);
        let k = meta.num_segments() - 1; // head
        let mb = meta.microbatch;
        let mut in_shape = vec![mb];
        in_shape.extend_from_slice(&meta.segments[k].in_shape);
        let mut out_shape = vec![mb];
        out_shape.extend_from_slice(&meta.segments[k].out_shape);
        let x = Tensor::zeros(in_shape.clone());
        let gy = Tensor::new(out_shape.clone(), vec![1.0; out_shape.iter().product()]).unwrap();
        let (grads, gx) = model.segment_bwd(k, &params, &x, &gy).unwrap();
        assert_eq!(grads.len(), meta.segments[k].params.len());
        for (g, pm) in grads.iter().zip(&meta.segments[k].params) {
            assert_eq!(g.shape, pm.shape);
        }
        assert_eq!(gx.shape, in_shape);
    }
}
