//! Parameter store: per-segment named tensors, host-side.
//!
//! The store owns the single authoritative copy of the model parameters.
//! Initialization follows standard He/Glorot-style schemes keyed off the
//! parameter roles recorded in meta.json (the Rust binary initializes and
//! trains — Python never produces parameter values). Checkpoints are a
//! small self-describing binary format so trained models can be reused
//! across CLI invocations (`artifacts/runs/<model>.fcb`).
//!
//! Two views of parameters exist behind the [`ParamAccess`] seam:
//!
//! * [`ParamStore`] — the owned, drifting store a legacy single-model
//!   replica edits in place.
//! * [`CowParams`] — a per-request copy-on-write overlay against a
//!   frozen `Arc<ParamStore>` master: reads fall through to the master,
//!   the first write to a segment materializes a private delta of just
//!   that segment. This is what multi-tenant registry workers serve
//!   with — the master never changes, so every request's result is
//!   independent of interleaving.

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelMeta;
use crate::tensor::quant::{self, QTensor};
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;

const MAGIC: &[u8; 8] = b"FICABU01";
/// Trailing magic of the embedded provenance record
/// ([`ParamStore::save_with_provenance`]).
const PROV_MAGIC: &[u8; 8] = b"FICABUP1";

/// Tmp + fsync + rename write discipline (the one `checkpoint.rs`
/// uses): a crash mid-save can leave a stale `.tmp`, never a torn
/// destination file.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    if let Some(parent) = path.parent() {
        crate::coordinator::wal::sync_dir(parent);
    }
    Ok(())
}

#[derive(Clone)]
pub struct ParamStore {
    /// `seg[i][j]` = j-th parameter tensor of segment i (meta order).
    pub seg: Vec<Vec<Tensor>>,
    /// Per-(segment, param) int8 weight copies for true int8 serving
    /// (`None` per slot for params served in f32: rank < 2 and the
    /// positional embedding). `None` overall = plain f32 store. Kept in
    /// lockstep with `seg`: quantized once at load, re-quantized after
    /// each dampening write-back of the edited segment only.
    quant: Option<Vec<Vec<Option<QTensor>>>>,
}

impl ParamStore {
    /// He/Glorot initialization from the meta inventory.
    pub fn init(meta: &ModelMeta, seed: u64) -> ParamStore {
        let mut rng = Pcg32::seeded(seed);
        let mut seg = Vec::with_capacity(meta.segments.len());
        for s in &meta.segments {
            let mut ps = Vec::with_capacity(s.params.len());
            for p in &s.params {
                ps.push(init_param(&p.name, &p.shape, &mut rng));
            }
            seg.push(ps);
        }
        ParamStore { seg, quant: None }
    }

    /// Flatten in (segment, param) order — the AOT whole-model arg order.
    pub fn flat(&self) -> Vec<&Tensor> {
        self.seg.iter().flat_map(|s| s.iter()).collect()
    }

    /// Replace every tensor (the train_step write-back). Drops any int8
    /// copies — a full f32 parameter replacement returns the store to
    /// f32 serving; re-quantize explicitly after training.
    pub fn set_flat(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        let n: usize = self.seg.iter().map(|s| s.len()).sum();
        if tensors.len() != n {
            bail!("set_flat: {} tensors for {} slots", tensors.len(), n);
        }
        let mut it = tensors.into_iter();
        for s in self.seg.iter_mut() {
            for p in s.iter_mut() {
                *p = it.next().unwrap();
            }
        }
        self.quant = None;
        Ok(())
    }

    pub fn total_len(&self) -> usize {
        self.seg.iter().flat_map(|s| s.iter()).map(|t| t.len()).sum()
    }

    /// Snap every tensor onto its per-tensor INT8 grid (fake
    /// quantization). Legacy deployment-assumption mode and test oracle;
    /// true int8 serving goes through [`ParamStore::quantize_int8`].
    pub fn fake_quant_int8(&mut self) {
        for s in self.seg.iter_mut() {
            for p in s.iter_mut() {
                quant::fake_quant(p);
            }
        }
    }

    // --- true int8 store ---------------------------------------------------

    /// True INT8 deployment (paper §IV-A): every GEMM/conv weight is
    /// quantized per output channel and the f32 master is snapped to the
    /// dequantized grid, so the (f32) gradient chain differentiates
    /// exactly the weights the int8 forward executes. 1-D params
    /// (biases, norm affines) and the positional embedding stay f32,
    /// mirroring the hardware split.
    pub fn quantize_int8(&mut self, meta: &ModelMeta) {
        let mut quant = Vec::with_capacity(self.seg.len());
        for (s, ms) in self.seg.iter_mut().zip(&meta.segments) {
            let mut qs = Vec::with_capacity(s.len());
            for (t, pm) in s.iter_mut().zip(&ms.params) {
                qs.push(quantize_slot(t, &pm.name));
            }
            quant.push(qs);
        }
        self.quant = Some(quant);
    }

    /// Re-quantize one segment's weight slots after a dampening
    /// write-back (the master f32 tensors of segment `k` changed).
    /// No-op on an f32 store.
    pub fn requantize_segment(&mut self, k: usize) {
        if let Some(quant) = &mut self.quant {
            requantize_row(&mut self.seg[k], &mut quant[k]);
        }
    }

    /// Whether the store carries int8 weight copies (serves int8).
    pub fn is_quantized(&self) -> bool {
        self.quant.is_some()
    }

    // --- transactional segment snapshots -----------------------------------

    /// Capture segment `k`'s pre-image: the f32 masters *and* (on an
    /// int8 store) the int8 weight copies. [`ParamStore::restore_segment`]
    /// puts both back bit for bit — stronger than re-deriving the int8
    /// copies via [`ParamStore::requantize_segment`], because restoring
    /// the captured copies cannot depend on quantization round-trips.
    pub fn snapshot_segment(&self, k: usize) -> SegmentSnapshot {
        SegmentSnapshot {
            tensors: self.seg[k].clone(),
            quant: self.quant.as_ref().map(|q| q[k].clone()),
        }
    }

    /// Restore segment `k` from a snapshot taken on this store: masters
    /// and int8 copies are bitwise identical to capture time afterwards.
    pub fn restore_segment(&mut self, k: usize, snap: SegmentSnapshot) {
        debug_assert_eq!(self.seg[k].len(), snap.tensors.len(), "snapshot arity mismatch");
        self.seg[k] = snap.tensors;
        match (self.quant.as_mut(), snap.quant) {
            (Some(q), Some(qs)) => q[k] = qs,
            (None, None) => {}
            // quantization state changed between capture and restore —
            // impossible from the unlearning engine (which never toggles
            // it mid-pass); keep whichever side still exists.
            _ => debug_assert!(false, "snapshot quantization state mismatch"),
        }
    }

    /// Int8 weight slots of segment `k` (`None` on an f32 store).
    pub fn qseg(&self, k: usize) -> Option<&[Option<QTensor>]> {
        self.quant.as_ref().map(|q| q[k].as_slice())
    }

    /// Reassemble a store from raw parts (the durability checkpoint
    /// loader). `quant`, when present, must be in lockstep with `seg` —
    /// same segment count and slot count per segment.
    pub(crate) fn from_parts(
        seg: Vec<Vec<Tensor>>,
        quant: Option<Vec<Vec<Option<QTensor>>>>,
    ) -> Result<ParamStore> {
        if let Some(q) = &quant {
            if q.len() != seg.len() || q.iter().zip(&seg).any(|(qs, s)| qs.len() != s.len()) {
                bail!("from_parts: int8 copies not in lockstep with segments");
            }
        }
        Ok(ParamStore { seg, quant })
    }

    // --- checkpoint io -----------------------------------------------------

    fn encode(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        push_u32(&mut buf, self.seg.len() as u32);
        for s in &self.seg {
            push_u32(&mut buf, s.len() as u32);
            for t in s {
                push_u32(&mut buf, t.shape.len() as u32);
                for &d in &t.shape {
                    push_u32(&mut buf, d as u32);
                }
                for v in &t.data {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        buf
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_atomic(path.as_ref(), &self.encode())
    }

    /// Save with the model's audit-chain head embedded as a trailing
    /// provenance record, so a shipped parameter file carries its own
    /// forgetting provenance (in the spirit of cargo-auditable's
    /// in-binary dependency record). The trailer rides *after* the
    /// payload — [`ParamStore::load`] reads exactly the declared tensor
    /// bytes and ignores the rest, so provenance-bearing files load
    /// everywhere the plain format does. Layout, from the end of file:
    ///
    /// ```text
    /// ... payload ... | record JSON | crc32(json) u32 | len u32 | "FICABUP1"
    /// ```
    pub fn save_with_provenance(
        &self,
        path: impl AsRef<Path>,
        head: &crate::audit::AuditRecord,
    ) -> Result<()> {
        let mut buf = self.encode();
        let json = head.to_json().to_string().into_bytes();
        let crc = crate::coordinator::wal::crc32(&json);
        buf.extend_from_slice(&json);
        buf.extend_from_slice(&crc.to_le_bytes());
        push_u32(&mut buf, json.len() as u32);
        buf.extend_from_slice(PROV_MAGIC);
        write_atomic(path.as_ref(), &buf)
    }

    /// Read back the provenance record embedded by
    /// [`ParamStore::save_with_provenance`]. `Ok(None)` for a plain
    /// parameter file (no trailer magic); an error for a trailer that is
    /// present but torn, CRC-damaged, or schema-invalid — a corrupted
    /// provenance claim must fail loudly, never read as "no provenance".
    pub fn load_provenance(path: impl AsRef<Path>) -> Result<Option<crate::audit::AuditRecord>> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[bytes.len() - 8..] != PROV_MAGIC {
            return Ok(None);
        }
        let end = bytes.len() - 8;
        if end < 8 {
            bail!("provenance trailer torn: no length/crc words");
        }
        let len =
            u32::from_le_bytes(bytes[end - 4..end].try_into().unwrap()) as usize;
        let crc_at = end - 8;
        let Some(json_at) = crc_at.checked_sub(len) else {
            bail!("provenance trailer torn: declared {len} JSON bytes, file too short");
        };
        let crc = u32::from_le_bytes(bytes[crc_at..crc_at + 4].try_into().unwrap());
        let json = &bytes[json_at..crc_at];
        if crate::coordinator::wal::crc32(json) != crc {
            bail!("provenance trailer CRC mismatch");
        }
        let text = std::str::from_utf8(json).context("provenance record is not UTF-8")?;
        let parsed = crate::util::json::Json::parse(text)
            .map_err(|e| anyhow::anyhow!("provenance record unparsable: {e}"))?;
        crate::audit::AuditRecord::from_json(&parsed).map(Some)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        let mut pos = 0usize;
        let magic = take(&bytes, &mut pos, 8)?;
        if magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let nseg = read_u32(&bytes, &mut pos)? as usize;
        let mut seg = Vec::with_capacity(nseg);
        for _ in 0..nseg {
            let np = read_u32(&bytes, &mut pos)? as usize;
            let mut ps = Vec::with_capacity(np);
            for _ in 0..np {
                let rank = read_u32(&bytes, &mut pos)? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(read_u32(&bytes, &mut pos)? as usize);
                }
                let n: usize = shape.iter().product();
                let raw = take(&bytes, &mut pos, n * 4)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                ps.push(Tensor::new(shape, data)?);
            }
            seg.push(ps);
        }
        Ok(ParamStore { seg, quant: None })
    }

    /// Shape-check against a meta inventory.
    pub fn validate(&self, meta: &ModelMeta) -> Result<()> {
        if self.seg.len() != meta.segments.len() {
            bail!("segment count {} != meta {}", self.seg.len(), meta.segments.len());
        }
        for (s, ms) in self.seg.iter().zip(&meta.segments) {
            if s.len() != ms.params.len() {
                bail!("segment {}: {} params != meta {}", ms.name, s.len(), ms.params.len());
            }
            for (t, pm) in s.iter().zip(&ms.params) {
                if t.shape != pm.shape {
                    bail!("{}.{}: shape {:?} != meta {:?}", ms.name, pm.name, t.shape, pm.shape);
                }
            }
        }
        Ok(())
    }
}

/// Pre-image of one segment, captured by [`ParamStore::snapshot_segment`]
/// before a dampening write-back and restored on error/panic so a
/// replica rolls back to its exact pre-request parameters.
pub struct SegmentSnapshot {
    tensors: Vec<Tensor>,
    quant: Option<Vec<Option<QTensor>>>,
}

/// Re-derive the int8 copies of one segment row and snap the f32
/// masters onto the dequantized grid — the dampening write-back
/// invariant, shared by the owned store and the CoW overlay.
fn requantize_row(tensors: &mut [Tensor], quant: &mut [Option<QTensor>]) {
    for (t, q) in tensors.iter_mut().zip(quant.iter_mut()) {
        if let Some(qt) = q {
            *qt = QTensor::from_weight(t);
            qt.dequantize_into(&mut t.data);
        }
    }
}

/// Uniform parameter view the execution layer reads and the unlearning
/// engine edits — implemented by the owned [`ParamStore`] (legacy
/// drifting replicas) and by [`CowParams`] (per-request deltas over a
/// frozen shared master). Everything the model graph, metrics, and
/// engine stages need, and nothing that pins the storage strategy.
pub trait ParamAccess {
    fn num_segments(&self) -> usize;

    /// Segment `k`'s f32 parameter tensors (meta order).
    fn seg(&self, k: usize) -> &[Tensor];

    /// Segment `k`'s int8 weight slots (`None` on an f32 store).
    fn qseg(&self, k: usize) -> Option<&[Option<QTensor>]>;

    /// Whether int8 weight copies are carried (store serves int8).
    fn is_quantized(&self) -> bool;

    /// Mutable access to segment `k`'s f32 tensors (the dampening
    /// scatter destination). On a CoW view this materializes the
    /// segment's private delta.
    fn seg_mut(&mut self, k: usize) -> &mut [Tensor];

    /// Capture segment `k`'s pre-image (f32 masters + int8 copies).
    fn snapshot_segment(&self, k: usize) -> SegmentSnapshot;

    /// Restore segment `k` bit for bit from a snapshot of this view.
    fn restore_segment(&mut self, k: usize, snap: SegmentSnapshot);

    /// Re-derive segment `k`'s int8 copies after an f32 edit; no-op on
    /// an f32 store.
    fn requantize_segment(&mut self, k: usize);
}

impl ParamAccess for ParamStore {
    fn num_segments(&self) -> usize {
        self.seg.len()
    }

    fn seg(&self, k: usize) -> &[Tensor] {
        &self.seg[k]
    }

    fn qseg(&self, k: usize) -> Option<&[Option<QTensor>]> {
        ParamStore::qseg(self, k)
    }

    fn is_quantized(&self) -> bool {
        ParamStore::is_quantized(self)
    }

    fn seg_mut(&mut self, k: usize) -> &mut [Tensor] {
        &mut self.seg[k]
    }

    fn snapshot_segment(&self, k: usize) -> SegmentSnapshot {
        ParamStore::snapshot_segment(self, k)
    }

    fn restore_segment(&mut self, k: usize, snap: SegmentSnapshot) {
        ParamStore::restore_segment(self, k, snap)
    }

    fn requantize_segment(&mut self, k: usize) {
        ParamStore::requantize_segment(self, k)
    }
}

/// Materialized private copy of one segment in a [`CowParams`] view.
struct SegmentDelta {
    tensors: Vec<Tensor>,
    /// `Some` exactly when the master is quantized (lockstep invariant).
    quant: Option<Vec<Option<QTensor>>>,
}

/// Copy-on-write parameter view over a frozen shared master.
///
/// Reads fall through to the `Arc<ParamStore>` master until a segment
/// is first written ([`ParamAccess::seg_mut`] /
/// [`ParamAccess::restore_segment`]), which clones exactly that
/// segment (f32 masters plus its int8 copies) into a private delta.
/// The master is never mutated, so N requests against one master are
/// bitwise independent of each other and of their interleaving — each
/// produces the same post-unlearn segment deltas it would have produced
/// alone. Dropping the view discards the deltas; [`CowParams::touched`]
/// enumerates them first if a caller wants to persist or inspect the
/// edit.
pub struct CowParams {
    master: Arc<ParamStore>,
    delta: Vec<Option<SegmentDelta>>,
}

impl CowParams {
    pub fn new(master: Arc<ParamStore>) -> CowParams {
        let n = master.seg.len();
        CowParams { master, delta: (0..n).map(|_| None).collect() }
    }

    /// The frozen master this view overlays.
    pub fn master(&self) -> &Arc<ParamStore> {
        &self.master
    }

    /// Indices of segments with a materialized delta (i.e. written to).
    pub fn touched(&self) -> Vec<usize> {
        (0..self.delta.len()).filter(|&k| self.delta[k].is_some()).collect()
    }

    fn materialize(&mut self, k: usize) -> &mut SegmentDelta {
        let slot = &mut self.delta[k];
        if slot.is_none() {
            *slot = Some(SegmentDelta {
                tensors: self.master.seg[k].clone(),
                quant: self.master.quant.as_ref().map(|q| q[k].clone()),
            });
        }
        slot.as_mut().unwrap()
    }
}

impl ParamAccess for CowParams {
    fn num_segments(&self) -> usize {
        self.delta.len()
    }

    fn seg(&self, k: usize) -> &[Tensor] {
        match &self.delta[k] {
            Some(d) => &d.tensors,
            None => &self.master.seg[k],
        }
    }

    fn qseg(&self, k: usize) -> Option<&[Option<QTensor>]> {
        if !self.master.is_quantized() {
            return None;
        }
        match &self.delta[k] {
            Some(d) => d.quant.as_deref(),
            None => ParamStore::qseg(&self.master, k),
        }
    }

    fn is_quantized(&self) -> bool {
        self.master.is_quantized()
    }

    fn seg_mut(&mut self, k: usize) -> &mut [Tensor] {
        &mut self.materialize(k).tensors
    }

    fn snapshot_segment(&self, k: usize) -> SegmentSnapshot {
        SegmentSnapshot {
            tensors: self.seg(k).to_vec(),
            quant: self.qseg(k).map(|q| q.to_vec()),
        }
    }

    fn restore_segment(&mut self, k: usize, snap: SegmentSnapshot) {
        let d = self.materialize(k);
        debug_assert_eq!(d.tensors.len(), snap.tensors.len(), "snapshot arity mismatch");
        d.tensors = snap.tensors;
        d.quant = snap.quant;
    }

    fn requantize_segment(&mut self, k: usize) {
        if !self.master.is_quantized() {
            return;
        }
        let d = self.materialize(k);
        if let Some(q) = &mut d.quant {
            requantize_row(&mut d.tensors, q);
        }
    }
}

/// Quantize one parameter slot if it is a GEMM/conv weight; snap the
/// f32 master onto the dequantized grid. Rank-1 params and the learned
/// positional embedding (`pos` — added, never multiplied) stay f32.
fn quantize_slot(t: &mut Tensor, name: &str) -> Option<QTensor> {
    if t.shape.len() < 2 || name == "pos" {
        return None;
    }
    let q = QTensor::from_weight(t);
    q.dequantize_into(&mut t.data);
    Some(q)
}

fn init_param(name: &str, shape: &[usize], rng: &mut Pcg32) -> Tensor {
    let n: usize = shape.iter().product();
    // Norm scales start at 1, biases/shifts at 0, everything else random.
    let is_scale = matches!(name, "gamma" | "g1" | "g2" | "gd" | "lng")
        || name.starts_with("ln") && name.ends_with('g');
    let is_shift = matches!(name, "beta" | "b1" | "b2" | "bd" | "lnb" | "b" | "bqkv" | "bproj")
        || (name.starts_with("ln") && name.ends_with('b'));
    if is_scale && shape.len() == 1 {
        return Tensor { shape: shape.to_vec(), data: vec![1.0; n] };
    }
    if is_shift && shape.len() == 1 {
        return Tensor { shape: shape.to_vec(), data: vec![0.0; n] };
    }
    let std = match shape.len() {
        4 => {
            // HWIO conv: He over fan_in = kh*kw*cin
            let fan_in = (shape[0] * shape[1] * shape[2]) as f32;
            (2.0 / fan_in).sqrt()
        }
        2 => {
            // dense: Glorot
            let (fi, fo) = (shape[0] as f32, shape[1] as f32);
            (2.0 / (fi + fo)).sqrt()
        }
        _ => 0.02, // positional embeddings etc.
    };
    Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, std) }
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn read_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let raw = take(b, pos, 4)?;
    Ok(u32::from_le_bytes([raw[0], raw[1], raw[2], raw[3]]))
}

fn take<'a>(b: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > b.len() {
        bail!("checkpoint truncated at byte {}", pos);
    }
    let s = &b[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_meta() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let ps = ParamStore::init(&meta, 1);
        ps.validate(&meta).unwrap();
        assert_eq!(ps.total_len(), meta.total_params());
        // norm scales are ones
        let stem = &ps.seg[0];
        assert!(stem[1].data.iter().all(|&v| v == 1.0)); // gamma
        assert!(stem[2].data.iter().all(|&v| v == 0.0)); // beta
        // conv weights are random, nonzero
        assert!(stem[0].l2() > 0.0);
    }

    #[test]
    fn deterministic_init() {
        let meta = ModelMeta::builtin("vitslim").unwrap();
        let a = ParamStore::init(&meta, 7);
        let b = ParamStore::init(&meta, 7);
        assert_eq!(a.flat().len(), b.flat().len());
        for (x, y) in a.flat().iter().zip(b.flat().iter()) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let ps = ParamStore::init(&meta, 3);
        let dir = std::env::temp_dir().join("ficabu_test_ckpt");
        let path = dir.join("rn.fcb");
        ps.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        loaded.validate(&meta).unwrap();
        for (a, b) in ps.flat().iter().zip(loaded.flat().iter()) {
            assert_eq!(a.data, b.data);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn set_flat_roundtrip() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let mut ps = ParamStore::init(&meta, 5);
        let cloned: Vec<Tensor> = ps.flat().into_iter().cloned().collect();
        ps.set_flat(cloned).unwrap();
        ps.validate(&meta).unwrap();
        assert!(ps.set_flat(vec![]).is_err());
    }

    #[test]
    fn int8_quant_changes_but_approximates() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let mut ps = ParamStore::init(&meta, 9);
        let before: Vec<f32> = ps.seg[0][0].data.clone();
        ps.fake_quant_int8();
        let after = &ps.seg[0][0].data;
        let rel: f32 = before
            .iter()
            .zip(after)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / before.iter().map(|v| v.abs()).sum::<f32>();
        assert!(rel < 0.01, "quant err {rel}");
    }

    #[test]
    fn quantize_int8_snaps_master_and_tracks_edits() {
        let meta = ModelMeta::builtin("vitslim").unwrap();
        let mut ps = ParamStore::init(&meta, 21);
        assert!(!ps.is_quantized());
        ps.quantize_int8(&meta);
        assert!(ps.is_quantized());
        // weight slots (rank >= 2, not `pos`) are quantized, others f32
        let q0 = ps.qseg(0).unwrap();
        assert!(q0[0].is_some(), "embed w must be quantized");
        assert!(q0[1].is_none(), "embed bias stays f32");
        assert!(q0[2].is_none(), "positional embedding stays f32");
        // master == dequantized int8 copy, bit for bit
        let qt = q0[0].as_ref().unwrap();
        assert_eq!(qt.dequantize().data, ps.seg[0][0].data);
        // editing a segment then requantizing restores the invariant
        for v in ps.seg[1][2].data.iter_mut() {
            *v *= 0.5;
        }
        ps.requantize_segment(1);
        let q1 = ps.qseg(1).unwrap()[2].as_ref().unwrap();
        assert_eq!(q1.dequantize().data, ps.seg[1][2].data);
        // shape check still passes: quantization preserves shapes
        ps.validate(&meta).unwrap();
    }

    #[test]
    fn set_flat_drops_quantized_copies() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let mut ps = ParamStore::init(&meta, 23);
        ps.quantize_int8(&meta);
        let cloned: Vec<Tensor> = ps.flat().into_iter().cloned().collect();
        ps.set_flat(cloned).unwrap();
        assert!(!ps.is_quantized());
    }

    #[test]
    fn segment_snapshot_restores_bitwise_f32_and_int8() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        for int8 in [false, true] {
            let mut ps = ParamStore::init(&meta, 31);
            if int8 {
                ps.quantize_int8(&meta);
            }
            let before: Vec<Vec<f32>> = ps.seg[2].iter().map(|t| t.data.clone()).collect();
            let qbefore: Option<Vec<Option<Vec<f32>>>> = ps
                .qseg(2)
                .map(|q| q.iter().map(|s| s.as_ref().map(|qt| qt.dequantize().data)).collect());
            let snap = ps.snapshot_segment(2);
            for t in ps.seg[2].iter_mut() {
                for v in t.data.iter_mut() {
                    *v = v.mul_add(0.75, 0.01);
                }
            }
            if int8 {
                ps.requantize_segment(2);
            }
            assert_ne!(ps.seg[2][0].data, before[0], "edit must actually change params");
            ps.restore_segment(2, snap);
            for (t, b) in ps.seg[2].iter().zip(&before) {
                assert!(t.data.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
            let qafter: Option<Vec<Option<Vec<f32>>>> = ps
                .qseg(2)
                .map(|q| q.iter().map(|s| s.as_ref().map(|qt| qt.dequantize().data)).collect());
            assert_eq!(qbefore, qafter, "int8 copies must restore too");
            ps.validate(&meta).unwrap();
        }
    }

    #[test]
    fn cow_overlay_isolates_writes_from_master_and_siblings() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        for int8 in [false, true] {
            let mut master = ParamStore::init(&meta, 41);
            if int8 {
                master.quantize_int8(&meta);
            }
            let frozen: Vec<Vec<f32>> =
                master.seg.iter().flat_map(|s| s.iter().map(|t| t.data.clone())).collect();
            let master = Arc::new(master);
            let mut a = CowParams::new(Arc::clone(&master));
            let mut b = CowParams::new(Arc::clone(&master));
            assert_eq!(a.num_segments(), meta.num_segments());
            assert!(a.touched().is_empty());
            // reads fall through to the master
            assert_eq!(ParamAccess::seg(&a, 1)[0].data, master.seg[1][0].data);
            assert_eq!(a.is_quantized(), int8);
            // a's write materializes only segment 1 and is invisible to
            // the master and to b
            for t in a.seg_mut(1).iter_mut() {
                for v in t.data.iter_mut() {
                    *v = v.mul_add(0.5, 0.25);
                }
            }
            if int8 {
                ParamAccess::requantize_segment(&mut a, 1);
                let q = ParamAccess::qseg(&a, 1).unwrap();
                let qt = q.iter().flatten().next().unwrap();
                let slot = q.iter().position(|s| s.is_some()).unwrap();
                assert_eq!(qt.dequantize().data, ParamAccess::seg(&a, 1)[slot].data);
            }
            assert_eq!(a.touched(), vec![1]);
            assert_ne!(ParamAccess::seg(&a, 1)[0].data, master.seg[1][0].data);
            assert_eq!(ParamAccess::seg(&b, 1)[0].data, master.seg[1][0].data);
            let after: Vec<Vec<f32>> =
                master.seg.iter().flat_map(|s| s.iter().map(|t| t.data.clone())).collect();
            assert_eq!(frozen, after, "master must stay frozen");
            // snapshot/restore round-trips bitwise on the overlay
            let snap = ParamAccess::snapshot_segment(&a, 1);
            for t in a.seg_mut(1).iter_mut() {
                t.data.iter_mut().for_each(|v| *v += 1.0);
            }
            ParamAccess::restore_segment(&mut a, 1, snap);
            if int8 {
                // restoring b's untouched segment snapshot round-trips too
                let snap_b = ParamAccess::snapshot_segment(&b, 2);
                ParamAccess::restore_segment(&mut b, 2, snap_b);
                assert_eq!(ParamAccess::seg(&b, 2)[0].data, master.seg[2][0].data);
            }
        }
    }

    #[test]
    fn cow_delta_matches_dedicated_store_edit_bitwise() {
        // the acceptance shape: the same deterministic edit applied
        // through a CoW overlay and through an owned store clone must
        // produce bitwise-identical parameters
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let master = Arc::new(ParamStore::init(&meta, 43));
        let mut owned = (*master).clone();
        let mut cow = CowParams::new(Arc::clone(&master));
        let edit = |ps: &mut dyn ParamAccess| {
            for t in ps.seg_mut(3).iter_mut() {
                for (i, v) in t.data.iter_mut().enumerate() {
                    *v = v.mul_add(0.9, (i % 7) as f32 * 1e-3);
                }
            }
        };
        edit(&mut owned);
        edit(&mut cow);
        for (x, y) in owned.seg[3].iter().zip(ParamAccess::seg(&cow, 3)) {
            assert!(x.data.iter().zip(&y.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    fn head_record() -> crate::audit::AuditRecord {
        crate::audit::AuditRecord {
            model: crate::coordinator::ModelId::default(),
            chain_seq: 2,
            prev_hash: 0x1234_5678_9abc_def0,
            spec: crate::unlearn::ForgetSpec::Class(3),
            config_hash: 0xdead_beef_0042_0007,
            git_rev: "abc123def456".to_string(),
            rolled_back: false,
            wal_seq: Some(7),
            wal_gen: 1,
            tainted: false,
            forget_acc: 0.04,
            retain_acc: 0.93,
            attest: Some(crate::audit::Attestation {
                strategy: "FiCABU".to_string(),
                precision: "f32".to_string(),
                seed: 0xedbe,
                forget_acc_before: 0.91,
                retain_acc_before: 0.92,
                mia_before: 0.8,
                mia_after: 0.1,
            }),
        }
    }

    #[test]
    fn save_leaves_no_tmp_file() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let ps = ParamStore::init(&meta, 11);
        let dir = std::env::temp_dir().join("ficabu_test_atomic_save");
        let path = dir.join("rn.fcb");
        ps.save(&path).unwrap();
        assert!(path.exists());
        assert!(!dir.join("rn.fcb.tmp").exists(), "tmp must be renamed away");
        ParamStore::load(&path).unwrap().validate(&meta).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn provenance_trailer_roundtrips_and_plain_load_ignores_it() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let ps = ParamStore::init(&meta, 13);
        let dir = std::env::temp_dir().join("ficabu_test_provenance");
        let path = dir.join("rn.fcb");
        let head = head_record();
        ps.save_with_provenance(&path, &head).unwrap();
        // the payload still loads as a plain store, trailer and all
        let loaded = ParamStore::load(&path).unwrap();
        loaded.validate(&meta).unwrap();
        for (a, b) in ps.flat().iter().zip(loaded.flat().iter()) {
            assert_eq!(a.data, b.data);
        }
        // the trailer reads back as the same canonical record
        let got = ParamStore::load_provenance(&path).unwrap().expect("trailer present");
        assert_eq!(got.core_hash(), head.core_hash());
        assert_eq!(got.chain_seq, 2);
        assert_eq!(got.wal_seq, Some(7));
        assert!((got.attest.as_ref().unwrap().mia_after - 0.1).abs() < 1e-12);
        // a plain save has no provenance, and that is not an error
        let plain = dir.join("plain.fcb");
        ps.save(&plain).unwrap();
        assert!(ParamStore::load_provenance(&plain).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_provenance_rejected_loudly() {
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let ps = ParamStore::init(&meta, 17);
        let dir = std::env::temp_dir().join("ficabu_test_provenance_bad");
        let path = dir.join("rn.fcb");
        ps.save_with_provenance(&path, &head_record()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one byte inside the JSON region (just before the 16-byte
        // crc+len+magic tail) — CRC must catch it
        let n = bytes.len();
        bytes[n - 17] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = ParamStore::load_provenance(&path).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_checkpoint_rejected() {
        let dir = std::env::temp_dir().join("ficabu_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.fcb");
        std::fs::write(&path, b"NOTMAGIC").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
