//! Cache-blocked, panel-packed GEMM core for the CpuBackend hot path.
//!
//! Classic three-level structure (the same discipline BLIS and the
//! paper's patch-streaming GEMM engine use, scaled to a CPU):
//!
//! * an `MR x NR` register-tiled micro-kernel over fixed-size arrays the
//!   compiler keeps in vector registers (f32, autovectorizable — no
//!   intrinsics, no nightly features, no new crates);
//! * `KC`-blocked panel packing: the A operand is repacked into
//!   MR-interleaved micro-panels and B into NR-interleaved micro-panels
//!   so the micro-kernel streams contiguously regardless of the logical
//!   operand layout (N/T views, or im2col patches extracted on the fly);
//! * multi-threading over disjoint row panels via `std::thread::scope`,
//!   worker count from `std::thread::available_parallelism()` and
//!   overridable with `FICABU_THREADS`.
//!
//! Packing goes through the [`ASrc`]/[`BSrc`] seams. [`Strided`] covers
//! all dense N/T operand views, and [`Im2col`]/[`Im2colT`] materialize
//! SAME-conv patch panels straight from the NHWC image, so `Conv` never
//! builds the full `[b*ho*wo, kh*kw*cin]` patch matrix.
//!
//! Determinism: each output element is accumulated in the same order
//! regardless of thread count (threads only partition rows), so results
//! are bitwise identical for any `FICABU_THREADS` value.

use std::thread;

use super::kernels::Conv;
use super::scratch::Scratch;

/// Micro-tile rows. With NR=8 this gives 8 vector accumulators (128-bit
/// lanes) plus broadcast/load temporaries — inside the 16-register
/// budget of baseline x86-64, so nothing spills.
pub const MR: usize = 4;
/// Micro-tile columns (two 4-lane vectors per row).
pub const NR: usize = 8;
/// k-dimension block: an `MR x KC` A panel (8 KiB) plus one `KC x NR`
/// B panel (16 KiB) stay L1-resident under the micro-kernel.
pub const KC: usize = 512;

/// Work (in FLOPs) below which forking threads costs more than it buys:
/// scoped workers are spawned per call (no pool yet), at tens of µs per
/// fork/join, so only GEMMs in the multi-ms single-thread range win.
const PAR_MIN_FLOPS: usize = 1 << 23;

/// Effective worker count: `FICABU_THREADS` if set to a positive
/// integer (re-read per call so tests/operators can flip it live),
/// else `available_parallelism()` (a syscall — cached once).
pub fn effective_threads() -> usize {
    static DEFAULT: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    match std::env::var("FICABU_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
    {
        Some(v) if v >= 1 => v,
        _ => *DEFAULT
            .get_or_init(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
    }
}

// ---------------------------------------------------------------------------
// pack sources
// ---------------------------------------------------------------------------

/// Left operand of a logical `[m,k] @ [k,n]` product, packed panel-wise.
pub trait ASrc: Sync {
    /// Fill `dst[p*MR + ii] = A[i0+ii, p0+p]` for `p < kc`, zero-padding
    /// rows `ii >= mr`. `dst` is the `kc*MR` prefix of a micro-panel.
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize);
}

/// Right operand, packed panel-wise.
pub trait BSrc: Sync {
    /// Fill `dst[p*NR + jj] = B[p0+p, j0+jj]` for `p < kc`, zero-padding
    /// columns `jj >= nr`. `dst` is the `kc*NR` prefix of a micro-panel.
    fn pack_b(&self, dst: &mut [f32], j0: usize, nr: usize, p0: usize, kc: usize);
}

/// Dense operand view with arbitrary row/column strides: element
/// `(r, c)` lives at `data[r*rs + c*cs]`. Covers row-major operands
/// (`cs = 1`) and transposed views (`rs = 1`) of both sides.
pub struct Strided<'a> {
    pub data: &'a [f32],
    pub rs: usize,
    pub cs: usize,
}

impl ASrc for Strided<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        for ii in 0..MR {
            if ii < mr {
                let base = (i0 + ii) * self.rs + p0 * self.cs;
                for p in 0..kc {
                    dst[p * MR + ii] = self.data[base + p * self.cs];
                }
            } else {
                for p in 0..kc {
                    dst[p * MR + ii] = 0.0;
                }
            }
        }
    }
}

impl BSrc for Strided<'_> {
    fn pack_b(&self, dst: &mut [f32], j0: usize, nr: usize, p0: usize, kc: usize) {
        for p in 0..kc {
            let base = (p0 + p) * self.rs + j0 * self.cs;
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (jj, d) in drow.iter_mut().enumerate() {
                *d = if jj < nr { self.data[base + jj * self.cs] } else { 0.0 };
            }
        }
    }
}

/// The im2col patch matrix `[b*ho*wo, kh*kw*cin]` of a SAME-padded NHWC
/// conv input, extracted panel-by-panel straight from the image — the
/// full patch matrix is never materialized.
pub struct Im2col<'a> {
    pub x: &'a [f32],
    pub conv: Conv,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

impl ASrc for Im2col<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        let cv = &self.conv;
        let (ho, wo) = cv.out_hw(self.h, self.w);
        let (ph, pw) = (cv.kh / 2, cv.kw / 2);
        debug_assert!(i0 + mr <= self.batch * ho * wo, "patch rows out of range");
        for ii in 0..MR {
            if ii >= mr {
                for p in 0..kc {
                    dst[p * MR + ii] = 0.0;
                }
                continue;
            }
            let r = i0 + ii;
            let bi = r / (ho * wo);
            let rem = r % (ho * wo);
            let (oy, ox) = (rem / wo, rem % wo);
            // walk (ky, kx, c) incrementally over the k range
            let mut c = p0 % cv.cin;
            let kyx = p0 / cv.cin;
            let (mut ky, mut kx) = (kyx / cv.kw, kyx % cv.kw);
            for p in 0..kc {
                let iy = (oy * cv.stride + ky) as isize - ph as isize;
                let ix = (ox * cv.stride + kx) as isize - pw as isize;
                dst[p * MR + ii] = if iy < 0
                    || iy >= self.h as isize
                    || ix < 0
                    || ix >= self.w as isize
                {
                    0.0
                } else {
                    self.x[((bi * self.h + iy as usize) * self.w + ix as usize) * cv.cin + c]
                };
                c += 1;
                if c == cv.cin {
                    c = 0;
                    kx += 1;
                    if kx == cv.kw {
                        kx = 0;
                        ky += 1;
                    }
                }
            }
        }
    }
}

/// Transpose of [`Im2col`]: the logical `[kh*kw*cin, b*ho*wo]` operand
/// of the grad-wrt-weights product `dW = colsᵀ @ gy`.
pub struct Im2colT<'a> {
    pub x: &'a [f32],
    pub conv: Conv,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

impl ASrc for Im2colT<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        let cv = &self.conv;
        let (ho, wo) = cv.out_hw(self.h, self.w);
        let (ph, pw) = (cv.kh / 2, cv.kw / 2);
        debug_assert!(p0 + kc <= self.batch * ho * wo, "patch columns out of range");
        // decompose the row block's kernel coordinates once
        let mut kdec = [(0usize, 0usize, 0usize); MR];
        for (ii, d) in kdec.iter_mut().enumerate().take(mr) {
            let i = i0 + ii;
            let kyx = i / cv.cin;
            *d = (kyx / cv.kw, kyx % cv.kw, i % cv.cin); // (ky, kx, c)
        }
        for p in 0..kc {
            let r = p0 + p;
            let bi = r / (ho * wo);
            let rem = r % (ho * wo);
            let (oy, ox) = (rem / wo, rem % wo);
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (ii, d) in drow.iter_mut().enumerate() {
                *d = if ii < mr {
                    let (ky, kx, c) = kdec[ii];
                    let iy = (oy * cv.stride + ky) as isize - ph as isize;
                    let ix = (ox * cv.stride + kx) as isize - pw as isize;
                    if iy < 0 || iy >= self.h as isize || ix < 0 || ix >= self.w as isize {
                        0.0
                    } else {
                        self.x[((bi * self.h + iy as usize) * self.w + ix as usize) * cv.cin + c]
                    }
                } else {
                    0.0
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// micro-kernel + panel loop
// ---------------------------------------------------------------------------

/// `acc += Ap @ Bp` over one `kc`-deep packed panel pair. Fixed-size
/// inner tiles so the accumulators live in vector registers.
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    for p in 0..kc {
        let ar: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        let br: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        for i in 0..MR {
            let a = ar[i];
            for j in 0..NR {
                acc[i][j] += a * br[j];
            }
        }
    }
}

/// Write (`first`) or accumulate (`!first`) the valid `mr x nr` corner
/// of a micro-tile into `out` (row-major, leading dimension `n`).
#[inline]
fn store_tile(
    out: &mut [f32],
    n: usize,
    r0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    acc: &[[f32; NR]; MR],
    first: bool,
) {
    for ii in 0..mr {
        let row = &mut out[(r0 + ii) * n + j0..][..nr];
        if first {
            for (o, v) in row.iter_mut().zip(&acc[ii][..nr]) {
                *o = *v;
            }
        } else {
            for (o, v) in row.iter_mut().zip(&acc[ii][..nr]) {
                *o += *v;
            }
        }
    }
}

/// One worker's share: rows `[lo, hi)` of the output, written into
/// `out_chunk` (whose row 0 is global row `lo`).
fn run_rows<A: ASrc>(
    a: &A,
    bpack: &[f32],
    lo: usize,
    hi: usize,
    k: usize,
    n: usize,
    njp: usize,
    nkb: usize,
    out_chunk: &mut [f32],
) {
    let mut apack = [0.0f32; MR * KC];
    let slot = KC * NR;
    let mut ip = lo;
    while ip < hi {
        let mr = MR.min(hi - ip);
        for kb in 0..nkb {
            let p0 = kb * KC;
            let kc = KC.min(k - p0);
            a.pack_a(&mut apack[..kc * MR], ip, mr, p0, kc);
            for jp in 0..njp {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let bp = &bpack[(kb * njp + jp) * slot..][..kc * NR];
                let mut acc = [[0.0f32; NR]; MR];
                micro_kernel(kc, &apack, bp, &mut acc);
                store_tile(out_chunk, n, ip - lo, j0, mr, nr, &acc, kb == 0);
            }
        }
        ip += MR;
    }
}

/// `out[m,n] = A[m,k] @ B[k,n]` through the packed sources, with an
/// explicit worker count (threads only partition rows, so the result is
/// bitwise independent of `threads`).
pub fn gemm_threads<A: ASrc, B: BSrc>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(out.len(), m * n, "gemm: out buffer is {}, want {m}x{n}", out.len());
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let njp = n.div_ceil(NR);
    let nkb = k.div_ceil(KC);
    let slot = KC * NR;

    // pack B once, NR-interleaved per (k-block, column-panel) slot
    let mut bpack = scratch.take_any(nkb * njp * slot);
    for kb in 0..nkb {
        let p0 = kb * KC;
        let kc = KC.min(k - p0);
        for jp in 0..njp {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let off = (kb * njp + jp) * slot;
            b.pack_b(&mut bpack[off..off + kc * NR], j0, nr, p0, kc);
        }
    }

    let panels = m.div_ceil(MR);
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    let t = if flops < PAR_MIN_FLOPS { 1 } else { threads.clamp(1, panels) };

    if t <= 1 {
        run_rows(a, &bpack, 0, m, k, n, njp, nkb, out);
    } else {
        // contiguous panel-aligned row chunks, one per worker
        let mut chunks: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest: &mut [f32] = out;
        let mut lo = 0usize;
        for ti in 0..t {
            let hi = ((panels * (ti + 1) / t) * MR).min(m);
            let (chunk, tail) = rest.split_at_mut((hi - lo) * n);
            chunks.push((lo, hi, chunk));
            rest = tail;
            lo = hi;
        }
        let bp: &[f32] = &bpack;
        thread::scope(|s| {
            let mut iter = chunks.into_iter();
            let (lo0, hi0, chunk0) = iter.next().expect("at least one worker");
            for (lo_i, hi_i, chunk) in iter {
                s.spawn(move || run_rows(a, bp, lo_i, hi_i, k, n, njp, nkb, chunk));
            }
            run_rows(a, bp, lo0, hi0, k, n, njp, nkb, chunk0);
        });
    }
    scratch.put(bpack);
}

/// [`gemm_threads`] with the worker count from the environment.
pub fn gemm<A: ASrc, B: BSrc>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    gemm_threads(scratch, a, b, m, k, n, out, effective_threads());
}

// ---------------------------------------------------------------------------
// dense entry points (the ref_matmul family)
// ---------------------------------------------------------------------------

/// `out = a[m,k] @ b[k,n]` (row-major).
pub fn matmul_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(
        scratch,
        &Strided { data: a, rs: k, cs: 1 },
        &Strided { data: b, rs: n, cs: 1 },
        m,
        k,
        n,
        out,
    );
}

/// `out = a[r,m]ᵀ @ b[r,n]` — the grad-wrt-weights product.
pub fn matmul_tn_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    gemm(
        scratch,
        &Strided { data: a, rs: 1, cs: m },
        &Strided { data: b, rs: n, cs: 1 },
        m,
        r,
        n,
        out,
    );
}

/// `out = a[m,k] @ b[n,k]ᵀ` — the grad-wrt-inputs product.
pub fn matmul_nt_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(
        scratch,
        &Strided { data: a, rs: k, cs: 1 },
        &Strided { data: b, rs: 1, cs: k },
        m,
        k,
        n,
        out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_small_matmul_exact() {
        let mut sc = Scratch::new();
        let mut out = vec![0.0f32; 4];
        matmul_into(&mut sc, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2, &mut out);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn k_zero_zeroes_out() {
        let mut sc = Scratch::new();
        let mut out = vec![7.0f32; 6];
        gemm(
            &mut sc,
            &Strided { data: &[], rs: 0, cs: 1 },
            &Strided { data: &[], rs: 3, cs: 1 },
            2,
            0,
            3,
            &mut out,
        );
        assert_eq!(out, vec![0.0; 6]);
    }

    #[test]
    fn variants_are_bitwise_consistent() {
        // identical logical operands through all three dense views give
        // identical packed panels, hence identical results
        let mut sc = Scratch::new();
        let (m, k, n) = (5, 9, 7);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.21).cos()).collect();
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut bt = vec![0.0f32; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut y = vec![0.0f32; m * n];
        let mut y_tn = vec![0.0f32; m * n];
        let mut y_nt = vec![0.0f32; m * n];
        matmul_into(&mut sc, &a, &b, m, k, n, &mut y);
        matmul_tn_into(&mut sc, &at, &b, k, m, n, &mut y_tn);
        matmul_nt_into(&mut sc, &a, &bt, m, k, n, &mut y_nt);
        assert_eq!(y, y_tn);
        assert_eq!(y, y_nt);
    }
}
