//! Cache-blocked, panel-packed GEMM core for the CpuBackend hot path.
//!
//! Classic three-level structure (the same discipline BLIS and the
//! paper's patch-streaming GEMM engine use, scaled to a CPU):
//!
//! * an `MR x NR` register-tiled micro-kernel over fixed-size arrays the
//!   compiler keeps in vector registers (f32, autovectorizable — no
//!   intrinsics, no nightly features, no new crates);
//! * `KC`-blocked panel packing: the A operand is repacked into
//!   MR-interleaved micro-panels and B into NR-interleaved micro-panels
//!   so the micro-kernel streams contiguously regardless of the logical
//!   operand layout (N/T views, or im2col patches extracted on the fly);
//! * multi-threading over disjoint row panels via `std::thread::scope`,
//!   worker count from `std::thread::available_parallelism()` and
//!   overridable with `FICABU_THREADS`.
//!
//! Packing goes through the [`ASrc`]/[`BSrc`] seams. [`Strided`] covers
//! all dense N/T operand views, and [`Im2col`]/[`Im2colT`] materialize
//! SAME-conv patch panels straight from the NHWC image, so `Conv` never
//! builds the full `[b*ho*wo, kh*kw*cin]` patch matrix.
//!
//! Determinism: each output element is accumulated in the same order
//! regardless of thread count (threads only partition rows), so results
//! are bitwise identical for any `FICABU_THREADS` value.

use std::thread;

use super::kernels::Conv;
use super::scratch::Scratch;
use crate::tensor::quant::q8;

/// Micro-tile rows. With NR=8 this gives 8 vector accumulators (128-bit
/// lanes) plus broadcast/load temporaries — inside the 16-register
/// budget of baseline x86-64, so nothing spills.
pub const MR: usize = 4;
/// Micro-tile columns (two 4-lane vectors per row).
pub const NR: usize = 8;
/// k-dimension block: an `MR x KC` A panel (8 KiB) plus one `KC x NR`
/// B panel (16 KiB) stay L1-resident under the micro-kernel.
pub const KC: usize = 512;

/// Work (in FLOPs) below which forking threads costs more than it buys:
/// scoped workers are spawned per call (no pool yet), at tens of µs per
/// fork/join, so only GEMMs in the multi-ms single-thread range win.
const PAR_MIN_FLOPS: usize = 1 << 23;

/// Effective worker count: `FICABU_THREADS` if set to a positive
/// integer (re-read per call so tests/operators can flip it live),
/// else `available_parallelism()` (a syscall — cached once).
pub fn effective_threads() -> usize {
    static DEFAULT: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    match std::env::var("FICABU_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
    {
        Some(v) if v >= 1 => v,
        _ => *DEFAULT
            .get_or_init(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1)),
    }
}

// ---------------------------------------------------------------------------
// pack sources
// ---------------------------------------------------------------------------

/// Left operand of a logical `[m,k] @ [k,n]` product, packed panel-wise.
pub trait ASrc: Sync {
    /// Fill `dst[p*MR + ii] = A[i0+ii, p0+p]` for `p < kc`, zero-padding
    /// rows `ii >= mr`. `dst` is the `kc*MR` prefix of a micro-panel.
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize);
}

/// Right operand, packed panel-wise.
pub trait BSrc: Sync {
    /// Fill `dst[p*NR + jj] = B[p0+p, j0+jj]` for `p < kc`, zero-padding
    /// columns `jj >= nr`. `dst` is the `kc*NR` prefix of a micro-panel.
    fn pack_b(&self, dst: &mut [f32], j0: usize, nr: usize, p0: usize, kc: usize);
}

/// Dense operand view with arbitrary row/column strides: element
/// `(r, c)` lives at `data[r*rs + c*cs]`. Covers row-major operands
/// (`cs = 1`) and transposed views (`rs = 1`) of both sides.
pub struct Strided<'a> {
    pub data: &'a [f32],
    pub rs: usize,
    pub cs: usize,
}

impl ASrc for Strided<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        for ii in 0..MR {
            if ii < mr {
                let base = (i0 + ii) * self.rs + p0 * self.cs;
                for p in 0..kc {
                    dst[p * MR + ii] = self.data[base + p * self.cs];
                }
            } else {
                for p in 0..kc {
                    dst[p * MR + ii] = 0.0;
                }
            }
        }
    }
}

impl BSrc for Strided<'_> {
    fn pack_b(&self, dst: &mut [f32], j0: usize, nr: usize, p0: usize, kc: usize) {
        for p in 0..kc {
            let base = (p0 + p) * self.rs + j0 * self.cs;
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (jj, d) in drow.iter_mut().enumerate() {
                *d = if jj < nr { self.data[base + jj * self.cs] } else { 0.0 };
            }
        }
    }
}

/// The im2col patch matrix `[b*ho*wo, kh*kw*cin]` of a SAME-padded NHWC
/// conv input, extracted panel-by-panel straight from the image — the
/// full patch matrix is never materialized.
pub struct Im2col<'a> {
    pub x: &'a [f32],
    pub conv: Conv,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

/// The one copy of the SAME-padded patch-row walk shared by the f32 and
/// int8 im2col pack sources: `dst[p*MR + ii] = load(image_index)` (or
/// `zero` for padding / rows `ii >= mr`), with `(ky, kx, c)` advanced
/// incrementally over the k range. `load` is where the int8 source
/// applies its quantization.
fn pack_patch_rows<T: Copy>(
    dst: &mut [T],
    zero: T,
    cv: &Conv,
    batch: usize,
    h: usize,
    w: usize,
    i0: usize,
    mr: usize,
    p0: usize,
    kc: usize,
    mut load: impl FnMut(usize) -> T,
) {
    let (ho, wo) = cv.out_hw(h, w);
    let (ph, pw) = (cv.kh / 2, cv.kw / 2);
    debug_assert!(i0 + mr <= batch * ho * wo, "patch rows out of range");
    for ii in 0..MR {
        if ii >= mr {
            for p in 0..kc {
                dst[p * MR + ii] = zero;
            }
            continue;
        }
        let r = i0 + ii;
        let bi = r / (ho * wo);
        let rem = r % (ho * wo);
        let (oy, ox) = (rem / wo, rem % wo);
        // walk (ky, kx, c) incrementally over the k range
        let mut c = p0 % cv.cin;
        let kyx = p0 / cv.cin;
        let (mut ky, mut kx) = (kyx / cv.kw, kyx % cv.kw);
        for p in 0..kc {
            let iy = (oy * cv.stride + ky) as isize - ph as isize;
            let ix = (ox * cv.stride + kx) as isize - pw as isize;
            dst[p * MR + ii] = if iy < 0 || iy >= h as isize || ix < 0 || ix >= w as isize {
                zero
            } else {
                load(((bi * h + iy as usize) * w + ix as usize) * cv.cin + c)
            };
            c += 1;
            if c == cv.cin {
                c = 0;
                kx += 1;
                if kx == cv.kw {
                    kx = 0;
                    ky += 1;
                }
            }
        }
    }
}

impl ASrc for Im2col<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        pack_patch_rows(dst, 0.0, &self.conv, self.batch, self.h, self.w, i0, mr, p0, kc, |i| {
            self.x[i]
        });
    }
}

/// Transpose of [`Im2col`]: the logical `[kh*kw*cin, b*ho*wo]` operand
/// of the grad-wrt-weights product `dW = colsᵀ @ gy`.
pub struct Im2colT<'a> {
    pub x: &'a [f32],
    pub conv: Conv,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

impl ASrc for Im2colT<'_> {
    fn pack_a(&self, dst: &mut [f32], i0: usize, mr: usize, p0: usize, kc: usize) {
        let cv = &self.conv;
        let (ho, wo) = cv.out_hw(self.h, self.w);
        let (ph, pw) = (cv.kh / 2, cv.kw / 2);
        debug_assert!(p0 + kc <= self.batch * ho * wo, "patch columns out of range");
        // decompose the row block's kernel coordinates once
        let mut kdec = [(0usize, 0usize, 0usize); MR];
        for (ii, d) in kdec.iter_mut().enumerate().take(mr) {
            let i = i0 + ii;
            let kyx = i / cv.cin;
            *d = (kyx / cv.kw, kyx % cv.kw, i % cv.cin); // (ky, kx, c)
        }
        for p in 0..kc {
            let r = p0 + p;
            let bi = r / (ho * wo);
            let rem = r % (ho * wo);
            let (oy, ox) = (rem / wo, rem % wo);
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (ii, d) in drow.iter_mut().enumerate() {
                *d = if ii < mr {
                    let (ky, kx, c) = kdec[ii];
                    let iy = (oy * cv.stride + ky) as isize - ph as isize;
                    let ix = (ox * cv.stride + kx) as isize - pw as isize;
                    if iy < 0 || iy >= self.h as isize || ix < 0 || ix >= self.w as isize {
                        0.0
                    } else {
                        self.x[((bi * self.h + iy as usize) * self.w + ix as usize) * cv.cin + c]
                    }
                } else {
                    0.0
                };
            }
        }
    }
}

// ---------------------------------------------------------------------------
// micro-kernel + panel loop
// ---------------------------------------------------------------------------

/// `acc += Ap @ Bp` over one `kc`-deep packed panel pair. Fixed-size
/// inner tiles so the accumulators live in vector registers.
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    for p in 0..kc {
        let ar: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        let br: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        for i in 0..MR {
            let a = ar[i];
            for j in 0..NR {
                acc[i][j] += a * br[j];
            }
        }
    }
}

/// Write (`first`) or accumulate (`!first`) the valid `mr x nr` corner
/// of a micro-tile into `out` (row-major, leading dimension `n`).
#[inline]
fn store_tile(
    out: &mut [f32],
    n: usize,
    r0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    acc: &[[f32; NR]; MR],
    first: bool,
) {
    for ii in 0..mr {
        let row = &mut out[(r0 + ii) * n + j0..][..nr];
        if first {
            for (o, v) in row.iter_mut().zip(&acc[ii][..nr]) {
                *o = *v;
            }
        } else {
            for (o, v) in row.iter_mut().zip(&acc[ii][..nr]) {
                *o += *v;
            }
        }
    }
}

/// One worker's share: rows `[lo, hi)` of the output, written into
/// `out_chunk` (whose row 0 is global row `lo`).
fn run_rows<A: ASrc>(
    a: &A,
    bpack: &[f32],
    lo: usize,
    hi: usize,
    k: usize,
    n: usize,
    njp: usize,
    nkb: usize,
    out_chunk: &mut [f32],
) {
    let mut apack = [0.0f32; MR * KC];
    let slot = KC * NR;
    let mut ip = lo;
    while ip < hi {
        let mr = MR.min(hi - ip);
        for kb in 0..nkb {
            let p0 = kb * KC;
            let kc = KC.min(k - p0);
            a.pack_a(&mut apack[..kc * MR], ip, mr, p0, kc);
            for jp in 0..njp {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let bp = &bpack[(kb * njp + jp) * slot..][..kc * NR];
                let mut acc = [[0.0f32; NR]; MR];
                micro_kernel(kc, &apack, bp, &mut acc);
                store_tile(out_chunk, n, ip - lo, j0, mr, nr, &acc, kb == 0);
            }
        }
        ip += MR;
    }
}

/// `out[m,n] = A[m,k] @ B[k,n]` through the packed sources, with an
/// explicit worker count (threads only partition rows, so the result is
/// bitwise independent of `threads`).
pub fn gemm_threads<A: ASrc, B: BSrc>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(out.len(), m * n, "gemm: out buffer is {}, want {m}x{n}", out.len());
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let njp = n.div_ceil(NR);
    let nkb = k.div_ceil(KC);
    let slot = KC * NR;

    // pack B once, NR-interleaved per (k-block, column-panel) slot
    let mut bpack = scratch.take_any(nkb * njp * slot);
    for kb in 0..nkb {
        let p0 = kb * KC;
        let kc = KC.min(k - p0);
        for jp in 0..njp {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let off = (kb * njp + jp) * slot;
            b.pack_b(&mut bpack[off..off + kc * NR], j0, nr, p0, kc);
        }
    }

    let panels = m.div_ceil(MR);
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    let t = if flops < PAR_MIN_FLOPS { 1 } else { threads.clamp(1, panels) };

    if t <= 1 {
        run_rows(a, &bpack, 0, m, k, n, njp, nkb, out);
    } else {
        // contiguous panel-aligned row chunks, one per worker
        let mut chunks: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest: &mut [f32] = out;
        let mut lo = 0usize;
        for ti in 0..t {
            let hi = ((panels * (ti + 1) / t) * MR).min(m);
            let (chunk, tail) = rest.split_at_mut((hi - lo) * n);
            chunks.push((lo, hi, chunk));
            rest = tail;
            lo = hi;
        }
        let bp: &[f32] = &bpack;
        thread::scope(|s| {
            let mut iter = chunks.into_iter();
            let (lo0, hi0, chunk0) = iter.next().expect("at least one worker");
            for (lo_i, hi_i, chunk) in iter {
                s.spawn(move || run_rows(a, bp, lo_i, hi_i, k, n, njp, nkb, chunk));
            }
            run_rows(a, bp, lo0, hi0, k, n, njp, nkb, chunk0);
        });
    }
    scratch.put(bpack);
}

/// [`gemm_threads`] with the worker count from the environment.
pub fn gemm<A: ASrc, B: BSrc>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    gemm_threads(scratch, a, b, m, k, n, out, effective_threads());
}

// ---------------------------------------------------------------------------
// dense entry points (the ref_matmul family)
// ---------------------------------------------------------------------------

/// `out = a[m,k] @ b[k,n]` (row-major).
pub fn matmul_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(
        scratch,
        &Strided { data: a, rs: k, cs: 1 },
        &Strided { data: b, rs: n, cs: 1 },
        m,
        k,
        n,
        out,
    );
}

/// `out = a[r,m]ᵀ @ b[r,n]` — the grad-wrt-weights product.
pub fn matmul_tn_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    r: usize,
    m: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), r * m);
    debug_assert_eq!(b.len(), r * n);
    gemm(
        scratch,
        &Strided { data: a, rs: 1, cs: m },
        &Strided { data: b, rs: n, cs: 1 },
        m,
        r,
        n,
        out,
    );
}

/// `out = a[m,k] @ b[n,k]ᵀ` — the grad-wrt-inputs product.
pub fn matmul_nt_into(
    scratch: &mut Scratch,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(
        scratch,
        &Strided { data: a, rs: k, cs: 1 },
        &Strided { data: b, rs: 1, cs: k },
        m,
        k,
        n,
        out,
    );
}

// ---------------------------------------------------------------------------
// int8 pack sources
// ---------------------------------------------------------------------------

/// Left operand of an int8 `[m,k] @ [k,n]` product, quantized into
/// MR-interleaved i8 micro-panels during packing.
pub trait ASrcI8: Sync {
    /// Fill `dst[p*MR + ii] = q(A[i0+ii, p0+p])` for `p < kc`,
    /// zero-padding rows `ii >= mr` (the int8 mirror of
    /// [`ASrc::pack_a`]).
    fn pack_a(&self, dst: &mut [i8], i0: usize, mr: usize, p0: usize, kc: usize);
}

/// Right operand (the pre-quantized weight), packed panel-wise.
pub trait BSrcI8: Sync {
    /// Fill `dst[p*NR + jj] = B[p0+p, j0+jj]` for `p < kc`, zero-padding
    /// columns `jj >= nr`.
    fn pack_b(&self, dst: &mut [i8], j0: usize, nr: usize, p0: usize, kc: usize);
}

/// Dense f32 operand quantized on the fly during packing (symmetric
/// per-tensor activation scale, `inv_scale = 1/scale` precomputed).
/// Element `(r, c)` lives at `data[r*rs + c*cs]`.
pub struct QuantStrided<'a> {
    pub data: &'a [f32],
    pub rs: usize,
    pub cs: usize,
    pub inv_scale: f32,
}

impl ASrcI8 for QuantStrided<'_> {
    fn pack_a(&self, dst: &mut [i8], i0: usize, mr: usize, p0: usize, kc: usize) {
        for ii in 0..MR {
            if ii < mr {
                let base = (i0 + ii) * self.rs + p0 * self.cs;
                for p in 0..kc {
                    dst[p * MR + ii] = q8(self.data[base + p * self.cs], self.inv_scale);
                }
            } else {
                for p in 0..kc {
                    dst[p * MR + ii] = 0;
                }
            }
        }
    }
}

/// Already-quantized dense operand view (the int8 weight): element
/// `(r, c)` at `data[r*rs + c*cs]`.
pub struct QStrided<'a> {
    pub data: &'a [i8],
    pub rs: usize,
    pub cs: usize,
}

impl BSrcI8 for QStrided<'_> {
    fn pack_b(&self, dst: &mut [i8], j0: usize, nr: usize, p0: usize, kc: usize) {
        for p in 0..kc {
            let base = (p0 + p) * self.rs + j0 * self.cs;
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (jj, d) in drow.iter_mut().enumerate() {
                *d = if jj < nr { self.data[base + jj * self.cs] } else { 0 };
            }
        }
    }
}

/// [`Im2col`] with on-the-fly int8 quantization: SAME-conv patch rows of
/// an NHWC f32 image, quantized with the image's per-tensor scale, so
/// conv stays fused on the int8 path too.
pub struct Im2colQ<'a> {
    pub x: &'a [f32],
    pub conv: Conv,
    pub batch: usize,
    pub h: usize,
    pub w: usize,
    pub inv_scale: f32,
}

impl ASrcI8 for Im2colQ<'_> {
    fn pack_a(&self, dst: &mut [i8], i0: usize, mr: usize, p0: usize, kc: usize) {
        pack_patch_rows(dst, 0, &self.conv, self.batch, self.h, self.w, i0, mr, p0, kc, |i| {
            q8(self.x[i], self.inv_scale)
        });
    }
}

// ---------------------------------------------------------------------------
// int8 micro-kernel + panel loop
// ---------------------------------------------------------------------------

/// `acc += Ap @ Bp` over one packed panel pair of `2*kc2` k-steps (kc
/// rounded up to even; pad rows are zeroed by the packer). Every i8xi8
/// product is exact in i16 and each adjacent k-pair sums without
/// overflow (2 * 127^2 < 2^15), so the pair sums accumulate exactly in
/// i32 — results are bitwise identical across kernel implementations,
/// k-block order, and thread count.
///
/// x86-64 path: the pair-sum idiom IS `pmaddwd` (SSE2, part of the
/// x86-64 baseline), retiring 8 MACs per instruction vs the 4-lane
/// f32 mul+add pair — the source of the int8 throughput win.
#[cfg(all(target_arch = "x86_64", target_feature = "sse2"))]
#[inline(always)]
fn micro_kernel_i8(kc2: usize, ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    use std::arch::x86_64::*;
    // the lane choreography below is written for the 4x8 micro-tile
    debug_assert!(MR == 4 && NR == 8);
    debug_assert!(ap.len() >= 2 * kc2 * MR && bp.len() >= 2 * kc2 * NR);
    // SAFETY: SSE2 is unconditionally available under this cfg; each
    // 8-byte load reads within the bounds asserted above (the last A
    // load ends exactly at 2*kc2*MR, the last B load at 2*kc2*NR).
    unsafe {
        let zero = _mm_setzero_si128();
        let mut va = [[zero; 2]; MR];
        for p in 0..kc2 {
            // B rows 2p and 2p+1 (8 i8 columns each) -> per-column
            // (k0, k1) i16 pairs for columns 0..3 / 4..7
            let b0 = _mm_loadl_epi64(bp.as_ptr().add(2 * p * NR) as *const __m128i);
            let b1 = _mm_loadl_epi64(bp.as_ptr().add((2 * p + 1) * NR) as *const __m128i);
            let bpairs = _mm_unpacklo_epi8(b0, b1);
            let bsign = _mm_cmpgt_epi8(zero, bpairs);
            let blo = _mm_unpacklo_epi8(bpairs, bsign); // columns 0..3
            let bhi = _mm_unpackhi_epi8(bpairs, bsign); // columns 4..7
            // A rows 2p and 2p+1 are adjacent MR-byte groups: one 8-byte
            // load carries all four rows' (k0, k1) pairs; i32 lane i of
            // `a16` is row i's sign-extended pair
            let araw = _mm_loadl_epi64(ap.as_ptr().add(2 * p * MR) as *const __m128i);
            let apairs = _mm_unpacklo_epi8(araw, _mm_srli_si128::<4>(araw));
            let asign = _mm_cmpgt_epi8(zero, apairs);
            let a16 = _mm_unpacklo_epi8(apairs, asign);
            let aa0 = _mm_shuffle_epi32::<0x00>(a16);
            let aa1 = _mm_shuffle_epi32::<0x55>(a16);
            let aa2 = _mm_shuffle_epi32::<0xaa>(a16);
            let aa3 = _mm_shuffle_epi32::<0xff>(a16);
            va[0][0] = _mm_add_epi32(va[0][0], _mm_madd_epi16(blo, aa0));
            va[0][1] = _mm_add_epi32(va[0][1], _mm_madd_epi16(bhi, aa0));
            va[1][0] = _mm_add_epi32(va[1][0], _mm_madd_epi16(blo, aa1));
            va[1][1] = _mm_add_epi32(va[1][1], _mm_madd_epi16(bhi, aa1));
            va[2][0] = _mm_add_epi32(va[2][0], _mm_madd_epi16(blo, aa2));
            va[2][1] = _mm_add_epi32(va[2][1], _mm_madd_epi16(bhi, aa2));
            va[3][0] = _mm_add_epi32(va[3][0], _mm_madd_epi16(blo, aa3));
            va[3][1] = _mm_add_epi32(va[3][1], _mm_madd_epi16(bhi, aa3));
        }
        for (i, v) in va.iter().enumerate() {
            let mut tmp = [0i32; NR];
            _mm_storeu_si128(tmp.as_mut_ptr() as *mut __m128i, v[0]);
            _mm_storeu_si128(tmp.as_mut_ptr().add(4) as *mut __m128i, v[1]);
            for j in 0..NR {
                acc[i][j] += tmp[j];
            }
        }
    }
}

/// Portable fallback: identical exact-integer semantics, structured as
/// the same i16 pair sums so autovectorizers can find the widening MAC.
#[cfg(not(all(target_arch = "x86_64", target_feature = "sse2")))]
#[inline(always)]
fn micro_kernel_i8(kc2: usize, ap: &[i8], bp: &[i8], acc: &mut [[i32; NR]; MR]) {
    for p in 0..kc2 {
        let a0: &[i8; MR] = ap[2 * p * MR..][..MR].try_into().unwrap();
        let a1: &[i8; MR] = ap[(2 * p + 1) * MR..][..MR].try_into().unwrap();
        let b0: &[i8; NR] = bp[2 * p * NR..][..NR].try_into().unwrap();
        let b1: &[i8; NR] = bp[(2 * p + 1) * NR..][..NR].try_into().unwrap();
        for i in 0..MR {
            let x0 = a0[i] as i16;
            let x1 = a1[i] as i16;
            for j in 0..NR {
                acc[i][j] += (x0 * b0[j] as i16 + x1 * b1[j] as i16) as i32;
            }
        }
    }
}

/// Requantize and write the valid `mr x nr` corner of an i32 micro-tile:
/// `out = acc * (a_scale * b_scale[col])`. Single store — the i32
/// accumulator already covers the full k extent.
#[inline]
fn store_tile_i8(
    out: &mut [f32],
    n: usize,
    r0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    acc: &[[i32; NR]; MR],
    a_scale: f32,
    b_scales: &[f32],
) {
    for ii in 0..mr {
        let row = &mut out[(r0 + ii) * n + j0..][..nr];
        for (jj, o) in row.iter_mut().enumerate() {
            *o = acc[ii][jj] as f32 * (a_scale * b_scales[j0 + jj]);
        }
    }
}

/// One worker's share of the int8 product: rows `[lo, hi)` into
/// `out_chunk` (row 0 = global row `lo`). A panels for *all* k-blocks
/// of a row panel are packed at once into the caller-provided `apack`
/// (`nkb * MR * KC` i8 — 4x denser than f32) so the i32 accumulator
/// spans the full k extent without f32 round-trips.
fn run_rows_i8<A: ASrcI8>(
    a: &A,
    bpack: &[i8],
    apack: &mut [i8],
    a_scale: f32,
    b_scales: &[f32],
    lo: usize,
    hi: usize,
    k: usize,
    n: usize,
    njp: usize,
    nkb: usize,
    out_chunk: &mut [f32],
) {
    let slot = KC * NR;
    debug_assert_eq!(apack.len(), nkb * MR * KC);
    let mut ip = lo;
    while ip < hi {
        let mr = MR.min(hi - ip);
        for kb in 0..nkb {
            let p0 = kb * KC;
            let kc = KC.min(k - p0);
            let ap = &mut apack[kb * MR * KC..(kb + 1) * MR * KC];
            a.pack_a(&mut ap[..kc * MR], ip, mr, p0, kc);
            if kc % 2 == 1 {
                ap[kc * MR..(kc + 1) * MR].fill(0); // zero pad row for the pair kernel
            }
        }
        for jp in 0..njp {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let mut acc = [[0i32; NR]; MR];
            for kb in 0..nkb {
                let p0 = kb * KC;
                let kc = KC.min(k - p0);
                let ap = &apack[kb * MR * KC..(kb + 1) * MR * KC];
                let bp = &bpack[(kb * njp + jp) * slot..][..slot];
                micro_kernel_i8(kc.div_ceil(2), ap, bp, &mut acc);
            }
            store_tile_i8(out_chunk, n, ip - lo, j0, mr, nr, &acc, a_scale, b_scales);
        }
        ip += MR;
    }
}

/// True-int8 `out[m,n] = A[m,k] @ B[k,n]`: i8 panels, i8 x i8 -> i32
/// accumulation, one per-output-channel requantization at the store.
/// Threads partition rows exactly like [`gemm_threads`], and integer
/// accumulation is order-free, so results are bitwise independent of
/// `threads` (and of the micro-kernel implementation).
pub fn gemm_i8_threads<A: ASrcI8, B: BSrcI8>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    a_scale: f32,
    b_scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    threads: usize,
) {
    assert_eq!(out.len(), m * n, "gemm_i8: out buffer is {}, want {m}x{n}", out.len());
    assert_eq!(b_scales.len(), n, "gemm_i8: {} scales for n={n}", b_scales.len());
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        out.fill(0.0);
        return;
    }
    // i32 accumulator headroom: |acc| <= 127^2 * k must stay below 2^31.
    // A hard assert: this is the exported kernel API, and a release-mode
    // wrap would silently corrupt every output element.
    assert!(k <= 133_000, "int8 GEMM k={k} exceeds the i32 accumulator budget");
    let njp = n.div_ceil(NR);
    let nkb = k.div_ceil(KC);
    let slot = KC * NR;

    // pack (and pad) B once, NR-interleaved per (k-block, column-panel)
    let mut bpack = scratch.take_i8(nkb * njp * slot);
    for kb in 0..nkb {
        let p0 = kb * KC;
        let kc = KC.min(k - p0);
        for jp in 0..njp {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let off = (kb * njp + jp) * slot;
            b.pack_b(&mut bpack[off..off + kc * NR], j0, nr, p0, kc);
            if kc % 2 == 1 {
                bpack[off + kc * NR..off + (kc + 1) * NR].fill(0);
            }
        }
    }

    let panels = m.div_ceil(MR);
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    // same fork/join break-even as the f32 path: even at ~4x the MAC
    // rate, a GEMM past the threshold still runs long enough per core
    // to amortize the spawn (and f32-vs-int8 comparisons at one shape
    // then use identical worker counts)
    let t = if flops < PAR_MIN_FLOPS { 1 } else { threads.clamp(1, panels) };

    // the calling thread's A-pack buffer comes from the arena (workers
    // spawned below are outside the single-threaded Scratch and allocate
    // their own — amortized by the fork threshold)
    let mut apack = scratch.take_i8(nkb * MR * KC);
    if t <= 1 {
        run_rows_i8(a, &bpack, &mut apack, a_scale, b_scales, 0, m, k, n, njp, nkb, out);
    } else {
        // contiguous panel-aligned row chunks, one per worker
        let mut chunks: Vec<(usize, usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest: &mut [f32] = out;
        let mut lo = 0usize;
        for ti in 0..t {
            let hi = ((panels * (ti + 1) / t) * MR).min(m);
            let (chunk, tail) = rest.split_at_mut((hi - lo) * n);
            chunks.push((lo, hi, chunk));
            rest = tail;
            lo = hi;
        }
        let bp: &[i8] = &bpack;
        thread::scope(|s| {
            let mut iter = chunks.into_iter();
            let (lo0, hi0, chunk0) = iter.next().expect("at least one worker");
            for (lo_i, hi_i, chunk) in iter {
                s.spawn(move || {
                    let mut wpack = vec![0i8; nkb * MR * KC];
                    run_rows_i8(
                        a, bp, &mut wpack, a_scale, b_scales, lo_i, hi_i, k, n, njp, nkb, chunk,
                    )
                });
            }
            run_rows_i8(
                a, bp, &mut apack, a_scale, b_scales, lo0, hi0, k, n, njp, nkb, chunk0,
            );
        });
    }
    scratch.put_i8(apack);
    scratch.put_i8(bpack);
}

/// [`gemm_i8_threads`] with the worker count from the environment.
pub fn gemm_i8<A: ASrcI8, B: BSrcI8>(
    scratch: &mut Scratch,
    a: &A,
    b: &B,
    a_scale: f32,
    b_scales: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    gemm_i8_threads(scratch, a, b, a_scale, b_scales, m, k, n, out, effective_threads());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_small_matmul_exact() {
        let mut sc = Scratch::new();
        let mut out = vec![0.0f32; 4];
        matmul_into(&mut sc, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2, &mut out);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn k_zero_zeroes_out() {
        let mut sc = Scratch::new();
        let mut out = vec![7.0f32; 6];
        gemm(
            &mut sc,
            &Strided { data: &[], rs: 0, cs: 1 },
            &Strided { data: &[], rs: 3, cs: 1 },
            2,
            0,
            3,
            &mut out,
        );
        assert_eq!(out, vec![0.0; 6]);
    }

    #[test]
    fn int8_small_matmul_exact() {
        // integers on the grid: scale 1 quantization is lossless, so the
        // int8 product must equal the exact integer result
        let mut sc = Scratch::new();
        let a = [1.0f32, 2.0, 3.0, 4.0]; // amax 4 -> scale 4/127
        let bq: Vec<i8> = vec![5, 6, 7, 8];
        let b_scales = [1.0f32, 1.0];
        let a_scale = crate::tensor::quant::scale_for(&a);
        let mut out = vec![0.0f32; 4];
        gemm_i8(
            &mut sc,
            &QuantStrided { data: &a, rs: 2, cs: 1, inv_scale: 1.0 / a_scale },
            &QStrided { data: &bq, rs: 2, cs: 1 },
            a_scale,
            &b_scales,
            2,
            2,
            2,
            &mut out,
        );
        // qa = round(a/scale): [32, 64, 95, 127]
        let qa = [32i32, 64, 95, 127];
        let want = [
            (qa[0] * 5 + qa[1] * 7) as f32 * a_scale,
            (qa[0] * 6 + qa[1] * 8) as f32 * a_scale,
            (qa[2] * 5 + qa[3] * 7) as f32 * a_scale,
            (qa[2] * 6 + qa[3] * 8) as f32 * a_scale,
        ];
        assert_eq!(out, want);
    }

    #[test]
    fn int8_k_zero_zeroes_out() {
        let mut sc = Scratch::new();
        let mut out = vec![7.0f32; 6];
        gemm_i8(
            &mut sc,
            &QuantStrided { data: &[], rs: 0, cs: 1, inv_scale: 1.0 },
            &QStrided { data: &[], rs: 3, cs: 1 },
            1.0,
            &[1.0; 3],
            2,
            0,
            3,
            &mut out,
        );
        assert_eq!(out, vec![0.0; 6]);
    }

    #[test]
    fn int8_odd_k_pad_rows_are_inert() {
        // k = 3 exercises the zero pad row of the pair kernel
        let mut sc = Scratch::new();
        let a = [127.0f32, 127.0, 127.0]; // scale 1, quantizes to 127
        let bq: Vec<i8> = vec![1, 2, 3];
        let mut out = vec![0.0f32; 1];
        gemm_i8(
            &mut sc,
            &QuantStrided { data: &a, rs: 3, cs: 1, inv_scale: 1.0 },
            &QStrided { data: &bq, rs: 1, cs: 1 },
            1.0,
            &[1.0],
            1,
            3,
            1,
            &mut out,
        );
        assert_eq!(out[0], (127 * (1 + 2 + 3)) as f32);
    }

    #[test]
    fn variants_are_bitwise_consistent() {
        // identical logical operands through all three dense views give
        // identical packed panels, hence identical results
        let mut sc = Scratch::new();
        let (m, k, n) = (5, 9, 7);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.21).cos()).collect();
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut bt = vec![0.0f32; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut y = vec![0.0f32; m * n];
        let mut y_tn = vec![0.0f32; m * n];
        let mut y_nt = vec![0.0f32; m * n];
        matmul_into(&mut sc, &a, &b, m, k, n, &mut y);
        matmul_tn_into(&mut sc, &at, &b, k, m, n, &mut y_tn);
        matmul_nt_into(&mut sc, &a, &bt, m, k, n, &mut y_nt);
        assert_eq!(y, y_tn);
        assert_eq!(y, y_nt);
    }
}
