//! Per-segment interpreters: forward and VJP for every segment kind of
//! the built-in topologies (`python/compile/model.py` semantics).
//!
//! Each [`SegmentDef`] is constructed once from the meta inventory
//! (`SegmentDef::from_meta`) and then applied batch-agnostically:
//! `fwd(params, x[B,...]) -> y`, `bwd(params, x, gy) -> (param grads in
//! meta order, gx)`. The VJPs are hand-derived (this is what `jax.vjp`
//! produced on the XLA path) and cross-checked against finite
//! differences in `tests/backend_golden.rs`.

// Index-heavy numeric loops read better with explicit ranges.
#![allow(clippy::needless_range_loop)]

use anyhow::{bail, Result};

use crate::config::builtin::GN_GROUPS;
use crate::config::ModelMeta;
use crate::tensor::Tensor;

use super::kernels::{
    add_bias, col_sum, gelu, gelu_bwd, group_norm_bwd, group_norm_fwd, layer_norm_bwd,
    layer_norm_fwd, matmul, matmul_nt, matmul_tn, relu, relu_bwd, softmax_bwd, softmax_rows,
    Conv,
};

/// Static per-segment execution plan.
pub(crate) enum SegmentDef {
    /// conv3x3 s1 + GroupNorm + relu.
    Stem { h: usize, w: usize, conv: Conv },
    /// BasicBlock: two conv3x3 + GN (+ optional 1x1 downsample path),
    /// residual add, relu.
    Block { h: usize, w: usize, conv1: Conv, conv2: Conv, down: Option<Conv> },
    /// Global-average-pool + linear classifier (ResNet head).
    HeadGap { hw: usize, c: usize, classes: usize },
    /// LayerNorm + token-mean-pool + linear classifier (ViT head).
    HeadVit { tokens: usize, dim: usize, classes: usize },
    /// Patchify + linear embed + learned positional embedding.
    Embed { img: usize, chans: usize, patch: usize, grid: usize, dim: usize },
    /// Pre-LN transformer encoder block.
    Encoder { tokens: usize, dim: usize, heads: usize, mlp: usize },
}

/// Require parameter `idx` of a segment to declare exactly `want`.
/// Run-time tensors are checked against the meta by the module wrapper,
/// so meta-internal consistency here makes the interpreters panic-free
/// on arbitrary (artifact-supplied) inventories.
fn expect_param(seg: &crate::config::SegmentMeta, idx: usize, want: &[usize]) -> Result<()> {
    let got = &seg.params[idx].shape;
    if got != want {
        bail!(
            "{}.{}: inventory declares shape {:?}, geometry requires {:?}",
            seg.name,
            seg.params[idx].name,
            got,
            want
        );
    }
    Ok(())
}

fn expect_out(seg: &crate::config::SegmentMeta, want: &[usize]) -> Result<()> {
    if seg.out_shape != want {
        bail!(
            "{}: inventory declares out_shape {:?}, geometry requires {:?}",
            seg.name,
            seg.out_shape,
            want
        );
    }
    Ok(())
}

impl SegmentDef {
    /// Build the plan for segment `k`, validating the inventory: every
    /// parameter shape and the out_shape must be consistent with the
    /// geometry derived from in_shape, or this is an `Err` (never a
    /// panic or silently wrong math on a malformed meta.json).
    pub(crate) fn from_meta(meta: &ModelMeta, k: usize) -> Result<SegmentDef> {
        if k >= meta.num_segments() {
            bail!("segment {k} out of range ({})", meta.num_segments());
        }
        let seg = &meta.segments[k];
        let np = seg.params.len();
        match seg.kind.as_str() {
            "stem" => {
                if np != 3 || seg.params[0].shape.len() != 4 || seg.in_shape.len() != 3 {
                    bail!("stem `{}`: malformed inventory", seg.name);
                }
                let ws = seg.params[0].shape.clone();
                let (h, w) = (seg.in_shape[0], seg.in_shape[1]);
                let conv = Conv { kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3], stride: 1 };
                if ws[0] == 0 || ws[1] == 0 || ws[2] != seg.in_shape[2] {
                    bail!("stem `{}`: kernel/in_shape mismatch", seg.name);
                }
                expect_param(seg, 1, &[conv.cout])?;
                expect_param(seg, 2, &[conv.cout])?;
                let (ho, wo) = conv.out_hw(h, w);
                expect_out(seg, &[ho, wo, conv.cout])?;
                Ok(SegmentDef::Stem { h, w, conv })
            }
            "block" => {
                if !(np == 6 || np == 9) || seg.in_shape.len() != 3 || seg.out_shape.len() != 3 {
                    bail!("block `{}`: malformed inventory", seg.name);
                }
                let (h, w) = (seg.in_shape[0], seg.in_shape[1]);
                let (cin, cout) = (seg.in_shape[2], seg.out_shape[2]);
                if seg.out_shape[0] == 0 || h % seg.out_shape[0] != 0 {
                    bail!("block `{}`: bad spatial shapes", seg.name);
                }
                let stride = h / seg.out_shape[0];
                let down = np == 9;
                if down != (stride != 1 || cin != cout) {
                    bail!("block `{}`: downsample params inconsistent", seg.name);
                }
                let conv1 = Conv { kh: 3, kw: 3, cin, cout, stride };
                let conv2 = Conv { kh: 3, kw: 3, cin: cout, cout, stride: 1 };
                expect_param(seg, 0, &[3, 3, cin, cout])?;
                expect_param(seg, 1, &[cout])?;
                expect_param(seg, 2, &[cout])?;
                expect_param(seg, 3, &[3, 3, cout, cout])?;
                expect_param(seg, 4, &[cout])?;
                expect_param(seg, 5, &[cout])?;
                if down {
                    expect_param(seg, 6, &[1, 1, cin, cout])?;
                    expect_param(seg, 7, &[cout])?;
                    expect_param(seg, 8, &[cout])?;
                }
                let (ho, wo) = conv1.out_hw(h, w);
                expect_out(seg, &[ho, wo, cout])?;
                Ok(SegmentDef::Block {
                    h,
                    w,
                    conv1,
                    conv2,
                    down: down.then_some(Conv { kh: 1, kw: 1, cin, cout, stride }),
                })
            }
            "head" if seg.in_shape.len() == 3 => {
                if np != 2 || seg.out_shape.len() != 1 {
                    bail!("head `{}`: expected (w, b)", seg.name);
                }
                let c = seg.in_shape[2];
                let classes = seg.out_shape[0];
                expect_param(seg, 0, &[c, classes])?;
                expect_param(seg, 1, &[classes])?;
                Ok(SegmentDef::HeadGap {
                    hw: seg.in_shape[0] * seg.in_shape[1],
                    c,
                    classes,
                })
            }
            "head" => {
                if np != 4 || seg.in_shape.len() != 2 || seg.out_shape.len() != 1 {
                    bail!("head `{}`: expected (lng, lnb, w, b)", seg.name);
                }
                let (tokens, dim) = (seg.in_shape[0], seg.in_shape[1]);
                let classes = seg.out_shape[0];
                expect_param(seg, 0, &[dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[dim, classes])?;
                expect_param(seg, 3, &[classes])?;
                Ok(SegmentDef::HeadVit { tokens, dim, classes })
            }
            "embed" => {
                if np != 3 || seg.in_shape.len() != 3 || seg.out_shape.len() != 2 {
                    bail!("embed `{}`: malformed inventory", seg.name);
                }
                let img = seg.in_shape[0];
                let chans = seg.in_shape[2];
                let tokens = seg.out_shape[0];
                let dim = seg.out_shape[1];
                let grid = (1..=img).find(|g| g * g == tokens).unwrap_or(0);
                if grid == 0 || img % grid != 0 || seg.in_shape[1] != img {
                    bail!("embed `{}`: token grid {} not square in {}", seg.name, tokens, img);
                }
                let patch = img / grid;
                expect_param(seg, 0, &[patch * patch * chans, dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[tokens, dim])?;
                Ok(SegmentDef::Embed { img, chans, patch, grid, dim })
            }
            "encoder" => {
                if np != 12 || seg.in_shape.len() != 2 || seg.params[8].shape.len() != 2 {
                    bail!("encoder `{}`: malformed inventory", seg.name);
                }
                let (tokens, dim) = (seg.in_shape[0], seg.in_shape[1]);
                if meta.heads == 0 || dim % meta.heads != 0 {
                    bail!(
                        "encoder `{}`: dim {} not divisible by {} heads",
                        seg.name,
                        dim,
                        meta.heads
                    );
                }
                let mlp = seg.params[8].shape[1];
                expect_param(seg, 0, &[dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[dim, 3 * dim])?;
                expect_param(seg, 3, &[3 * dim])?;
                expect_param(seg, 4, &[dim, dim])?;
                expect_param(seg, 5, &[dim])?;
                expect_param(seg, 6, &[dim])?;
                expect_param(seg, 7, &[dim])?;
                expect_param(seg, 8, &[dim, mlp])?;
                expect_param(seg, 9, &[mlp])?;
                expect_param(seg, 10, &[mlp, dim])?;
                expect_param(seg, 11, &[dim])?;
                expect_out(seg, &[tokens, dim])?;
                Ok(SegmentDef::Encoder { tokens, dim, heads: meta.heads, mlp })
            }
            other => bail!(
                "unsupported segment kind `{other}` for the CpuBackend (segment `{}`)",
                seg.name
            ),
        }
    }

    /// Forward: `(params..., x[B,...]) -> y`.
    pub(crate) fn fwd(&self, ps: &[&Tensor], x: &Tensor) -> Result<Tensor> {
        let b = x.batch();
        match self {
            SegmentDef::Stem { h, w, conv } => {
                let c1 = conv.fwd(&x.data, &ps[0].data, b, *h, *w);
                let (ho, wo) = conv.out_hw(*h, *w);
                let mut y = group_norm_fwd(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, &ps[1].data, &ps[2].data,
                );
                relu(&mut y);
                Tensor::new(vec![b, ho, wo, conv.cout], y)
            }
            SegmentDef::Block { h, w, conv1, conv2, down } => {
                let cout = conv1.cout;
                let c1 = conv1.fwd(&x.data, &ps[0].data, b, *h, *w);
                let (ho, wo) = conv1.out_hw(*h, *w);
                let hw = ho * wo;
                let o1 =
                    group_norm_fwd(&c1, b, hw, cout, GN_GROUPS, &ps[1].data, &ps[2].data);
                let mut h1 = o1;
                relu(&mut h1);
                let c2 = conv2.fwd(&h1, &ps[3].data, b, ho, wo);
                let o2 =
                    group_norm_fwd(&c2, b, hw, cout, GN_GROUPS, &ps[4].data, &ps[5].data);
                let sc = match down {
                    Some(cd) => {
                        let cdo = cd.fwd(&x.data, &ps[6].data, b, *h, *w);
                        group_norm_fwd(&cdo, b, hw, cout, GN_GROUPS, &ps[7].data, &ps[8].data)
                    }
                    None => x.data.clone(),
                };
                let mut y: Vec<f32> = o2.iter().zip(&sc).map(|(a, s)| a + s).collect();
                relu(&mut y);
                Tensor::new(vec![b, ho, wo, cout], y)
            }
            SegmentDef::HeadGap { hw, c, classes } => {
                let pooled = gap_pool(&x.data, b, *hw, *c);
                let mut y = matmul(&pooled, &ps[0].data, b, *c, *classes);
                add_bias(&mut y, &ps[1].data);
                Tensor::new(vec![b, *classes], y)
            }
            SegmentDef::HeadVit { tokens, dim, classes } => {
                let r = b * tokens;
                let hn = layer_norm_fwd(&x.data, r, *dim, &ps[0].data, &ps[1].data);
                let pooled = token_pool(&hn, b, *tokens, *dim);
                let mut y = matmul(&pooled, &ps[2].data, b, *dim, *classes);
                add_bias(&mut y, &ps[3].data);
                Tensor::new(vec![b, *classes], y)
            }
            SegmentDef::Embed { img, chans, patch, grid, dim } => {
                let tokens = grid * grid;
                let pdim = patch * patch * chans;
                let xp = patchify(&x.data, b, *img, *chans, *patch, *grid);
                let mut y = matmul(&xp, &ps[0].data, b * tokens, pdim, *dim);
                add_bias(&mut y, &ps[1].data);
                let pos = &ps[2].data;
                for bi in 0..b {
                    let base = bi * tokens * dim;
                    for (yv, &pv) in y[base..base + tokens * dim].iter_mut().zip(pos) {
                        *yv += pv;
                    }
                }
                Tensor::new(vec![b, tokens, *dim], y)
            }
            SegmentDef::Encoder { tokens, dim, heads, mlp } => {
                let y = self.encoder_fwd(ps, &x.data, b, *tokens, *dim, *heads, *mlp);
                Tensor::new(vec![b, *tokens, *dim], y)
            }
        }
    }

    /// VJP: `(params..., x, gy) -> (param grads in meta order, gx)`.
    pub(crate) fn bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let b = x.batch();
        match self {
            SegmentDef::Stem { h, w, conv } => {
                let c1 = conv.fwd(&x.data, &ps[0].data, b, *h, *w);
                let (ho, wo) = conv.out_hw(*h, *w);
                let o = group_norm_fwd(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, &ps[1].data, &ps[2].data,
                );
                let mut g = gy.data.clone();
                relu_bwd(&o, &mut g);
                let (dc1, dgamma, dbeta) = group_norm_bwd(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, &ps[1].data, &g,
                );
                let (dx, dw) = conv.bwd(&x.data, &ps[0].data, &dc1, b, *h, *w);
                Ok((
                    vec![
                        Tensor::new(ps[0].shape.clone(), dw)?,
                        Tensor::vec1(dgamma),
                        Tensor::vec1(dbeta),
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Block { h, w, conv1, conv2, down } => {
                self.block_bwd(ps, x, gy, b, *h, *w, conv1, conv2, down.as_ref())
            }
            SegmentDef::HeadGap { hw, c, classes } => {
                let pooled = gap_pool(&x.data, b, *hw, *c);
                let dw = matmul_tn(&pooled, &gy.data, b, *c, *classes);
                let db = col_sum(&gy.data, *classes);
                let dpooled = matmul_nt(&gy.data, &ps[0].data, b, *classes, *c);
                let mut dx = vec![0.0f32; b * hw * c];
                let inv = 1.0 / *hw as f32;
                for bi in 0..b {
                    for s in 0..*hw {
                        let base = (bi * hw + s) * c;
                        for ch in 0..*c {
                            dx[base + ch] = dpooled[bi * c + ch] * inv;
                        }
                    }
                }
                Ok((
                    vec![Tensor::new(ps[0].shape.clone(), dw)?, Tensor::vec1(db)],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::HeadVit { tokens, dim, classes } => {
                let r = b * tokens;
                let hn = layer_norm_fwd(&x.data, r, *dim, &ps[0].data, &ps[1].data);
                let pooled = token_pool(&hn, b, *tokens, *dim);
                let dw = matmul_tn(&pooled, &gy.data, b, *dim, *classes);
                let db = col_sum(&gy.data, *classes);
                let dpooled = matmul_nt(&gy.data, &ps[2].data, b, *classes, *dim);
                // broadcast back over tokens
                let inv = 1.0 / *tokens as f32;
                let mut dh = vec![0.0f32; r * dim];
                for bi in 0..b {
                    for t in 0..*tokens {
                        let base = (bi * tokens + t) * dim;
                        for dd in 0..*dim {
                            dh[base + dd] = dpooled[bi * dim + dd] * inv;
                        }
                    }
                }
                let (dx, dlng, dlnb) =
                    layer_norm_bwd(&x.data, r, *dim, &ps[0].data, &dh);
                Ok((
                    vec![
                        Tensor::vec1(dlng),
                        Tensor::vec1(dlnb),
                        Tensor::new(ps[2].shape.clone(), dw)?,
                        Tensor::vec1(db),
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Embed { img, chans, patch, grid, dim } => {
                let tokens = grid * grid;
                let pdim = patch * patch * chans;
                let r = b * tokens;
                let xp = patchify(&x.data, b, *img, *chans, *patch, *grid);
                let dw = matmul_tn(&xp, &gy.data, r, pdim, *dim);
                let db = col_sum(&gy.data, *dim);
                let mut dpos = vec![0.0f32; tokens * dim];
                for bi in 0..b {
                    let base = bi * tokens * dim;
                    for (dp, &gv) in dpos.iter_mut().zip(&gy.data[base..base + tokens * dim]) {
                        *dp += gv;
                    }
                }
                let dxp = matmul_nt(&gy.data, &ps[0].data, r, *dim, pdim);
                let dx = unpatchify(&dxp, b, *img, *chans, *patch, *grid);
                Ok((
                    vec![
                        Tensor::new(ps[0].shape.clone(), dw)?,
                        Tensor::vec1(db),
                        Tensor::new(ps[2].shape.clone(), dpos)?,
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Encoder { tokens, dim, heads, mlp } => {
                self.encoder_bwd(ps, x, gy, b, *tokens, *dim, *heads, *mlp)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn block_bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
        b: usize,
        h: usize,
        w: usize,
        conv1: &Conv,
        conv2: &Conv,
        down: Option<&Conv>,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let cout = conv1.cout;
        // --- recompute forward intermediates ---
        let c1 = conv1.fwd(&x.data, &ps[0].data, b, h, w);
        let (ho, wo) = conv1.out_hw(h, w);
        let hw = ho * wo;
        let o1 = group_norm_fwd(&c1, b, hw, cout, GN_GROUPS, &ps[1].data, &ps[2].data);
        let mut h1 = o1.clone();
        relu(&mut h1);
        let c2 = conv2.fwd(&h1, &ps[3].data, b, ho, wo);
        let o2 = group_norm_fwd(&c2, b, hw, cout, GN_GROUPS, &ps[4].data, &ps[5].data);
        let (cdo, sc) = match down {
            Some(cd) => {
                let cdo = cd.fwd(&x.data, &ps[6].data, b, h, w);
                let sc =
                    group_norm_fwd(&cdo, b, hw, cout, GN_GROUPS, &ps[7].data, &ps[8].data);
                (cdo, sc)
            }
            None => (Vec::new(), x.data.clone()),
        };
        let pre: Vec<f32> = o2.iter().zip(&sc).map(|(a, s)| a + s).collect();

        // --- backward ---
        let mut g = gy.data.clone();
        relu_bwd(&pre, &mut g); // grad at o2 and sc alike
        let (dc2, dg2, db2) = group_norm_bwd(&c2, b, hw, cout, GN_GROUPS, &ps[4].data, &g);
        let (mut dh1, dw2) = conv2.bwd(&h1, &ps[3].data, &dc2, b, ho, wo);
        relu_bwd(&o1, &mut dh1);
        let (dc1, dg1, db1) = group_norm_bwd(&c1, b, hw, cout, GN_GROUPS, &ps[1].data, &dh1);
        let (dx1, dw1) = conv1.bwd(&x.data, &ps[0].data, &dc1, b, h, w);

        let mut grads = vec![
            Tensor::new(ps[0].shape.clone(), dw1)?,
            Tensor::vec1(dg1),
            Tensor::vec1(db1),
            Tensor::new(ps[3].shape.clone(), dw2)?,
            Tensor::vec1(dg2),
            Tensor::vec1(db2),
        ];
        let mut dx = dx1;
        match down {
            Some(cd) => {
                let (dcdo, dgd, dbd) =
                    group_norm_bwd(&cdo, b, hw, cout, GN_GROUPS, &ps[7].data, &g);
                let (dx2, dwd) = cd.bwd(&x.data, &ps[6].data, &dcdo, b, h, w);
                for (a, v) in dx.iter_mut().zip(&dx2) {
                    *a += v;
                }
                grads.push(Tensor::new(ps[6].shape.clone(), dwd)?);
                grads.push(Tensor::vec1(dgd));
                grads.push(Tensor::vec1(dbd));
            }
            None => {
                for (a, v) in dx.iter_mut().zip(&g) {
                    *a += v;
                }
            }
        }
        Ok((grads, Tensor::new(x.shape.clone(), dx)?))
    }

    #[allow(clippy::too_many_arguments)]
    fn encoder_fwd(
        &self,
        ps: &[&Tensor],
        x: &[f32],
        b: usize,
        tokens: usize,
        dim: usize,
        heads: usize,
        mlp: usize,
    ) -> Vec<f32> {
        let r = b * tokens;
        let d3 = 3 * dim;
        let hd = dim / heads;
        let inv = 1.0 / (hd as f32).sqrt();
        let xh = layer_norm_fwd(x, r, dim, &ps[0].data, &ps[1].data);
        let mut qkv = matmul(&xh, &ps[2].data, r, dim, d3);
        add_bias(&mut qkv, &ps[3].data);
        let mut o = vec![0.0f32; r * dim];
        for bi in 0..b {
            for hh in 0..heads {
                let q = gather_head(&qkv, bi, tokens, d3, hh * hd, hd);
                let k = gather_head(&qkv, bi, tokens, d3, dim + hh * hd, hd);
                let v = gather_head(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd);
                let mut att = matmul_nt(&q, &k, tokens, hd, tokens);
                for a in att.iter_mut() {
                    *a *= inv;
                }
                softmax_rows(&mut att, tokens);
                let oh = matmul(&att, &v, tokens, tokens, hd);
                scatter_head(&mut o, &oh, bi, tokens, dim, hh * hd, hd);
            }
        }
        let mut proj = matmul(&o, &ps[4].data, r, dim, dim);
        add_bias(&mut proj, &ps[5].data);
        let x2: Vec<f32> = x.iter().zip(&proj).map(|(a, p)| a + p).collect();
        let h2 = layer_norm_fwd(&x2, r, dim, &ps[6].data, &ps[7].data);
        let mut z1 = matmul(&h2, &ps[8].data, r, dim, mlp);
        add_bias(&mut z1, &ps[9].data);
        let a = gelu(&z1);
        let mut y = matmul(&a, &ps[10].data, r, mlp, dim);
        add_bias(&mut y, &ps[11].data);
        for (yv, xv) in y.iter_mut().zip(&x2) {
            *yv += xv;
        }
        y
    }

    #[allow(clippy::too_many_arguments)]
    fn encoder_bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
        b: usize,
        tokens: usize,
        dim: usize,
        heads: usize,
        mlp: usize,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let r = b * tokens;
        let d3 = 3 * dim;
        let hd = dim / heads;
        let inv = 1.0 / (hd as f32).sqrt();

        // --- recompute forward intermediates ---
        let xh = layer_norm_fwd(&x.data, r, dim, &ps[0].data, &ps[1].data);
        let mut qkv = matmul(&xh, &ps[2].data, r, dim, d3);
        add_bias(&mut qkv, &ps[3].data);
        let mut o = vec![0.0f32; r * dim];
        let mut atts: Vec<Vec<f32>> = Vec::with_capacity(b * heads);
        for bi in 0..b {
            for hh in 0..heads {
                let q = gather_head(&qkv, bi, tokens, d3, hh * hd, hd);
                let k = gather_head(&qkv, bi, tokens, d3, dim + hh * hd, hd);
                let v = gather_head(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd);
                let mut att = matmul_nt(&q, &k, tokens, hd, tokens);
                for a in att.iter_mut() {
                    *a *= inv;
                }
                softmax_rows(&mut att, tokens);
                let oh = matmul(&att, &v, tokens, tokens, hd);
                scatter_head(&mut o, &oh, bi, tokens, dim, hh * hd, hd);
                atts.push(att);
            }
        }
        let mut proj = matmul(&o, &ps[4].data, r, dim, dim);
        add_bias(&mut proj, &ps[5].data);
        let x2: Vec<f32> = x.data.iter().zip(&proj).map(|(a, p)| a + p).collect();
        let h2 = layer_norm_fwd(&x2, r, dim, &ps[6].data, &ps[7].data);
        let mut z1 = matmul(&h2, &ps[8].data, r, dim, mlp);
        add_bias(&mut z1, &ps[9].data);
        let a = gelu(&z1);

        // --- backward: mlp sub-block ---
        let g = &gy.data;
        let db2 = col_sum(g, dim);
        let dw2 = matmul_tn(&a, g, r, mlp, dim);
        let da = matmul_nt(g, &ps[10].data, r, dim, mlp);
        let dz1 = gelu_bwd(&z1, &da);
        let db1 = col_sum(&dz1, mlp);
        let dw1 = matmul_tn(&h2, &dz1, r, dim, mlp);
        let dh2 = matmul_nt(&dz1, &ps[8].data, r, mlp, dim);
        let (dx2_ln, dln2g, dln2b) = layer_norm_bwd(&x2, r, dim, &ps[6].data, &dh2);
        let dx2: Vec<f32> = g.iter().zip(&dx2_ln).map(|(a, l)| a + l).collect();

        // --- projection ---
        let dbproj = col_sum(&dx2, dim);
        let dwproj = matmul_tn(&o, &dx2, r, dim, dim);
        let do_ = matmul_nt(&dx2, &ps[4].data, r, dim, dim);

        // --- attention ---
        let mut dqkv = vec![0.0f32; r * d3];
        for bi in 0..b {
            for hh in 0..heads {
                let att = &atts[bi * heads + hh];
                let q = gather_head(&qkv, bi, tokens, d3, hh * hd, hd);
                let k = gather_head(&qkv, bi, tokens, d3, dim + hh * hd, hd);
                let v = gather_head(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd);
                let doh = gather_head(&do_, bi, tokens, dim, hh * hd, hd);
                let datt = matmul_nt(&doh, &v, tokens, hd, tokens);
                let dv = matmul_tn(att, &doh, tokens, tokens, hd);
                let mut ds = softmax_bwd(att, &datt, tokens);
                for s in ds.iter_mut() {
                    *s *= inv;
                }
                let dq = matmul(&ds, &k, tokens, tokens, hd);
                let dk = matmul_tn(&ds, &q, tokens, tokens, hd);
                scatter_head(&mut dqkv, &dq, bi, tokens, d3, hh * hd, hd);
                scatter_head(&mut dqkv, &dk, bi, tokens, d3, dim + hh * hd, hd);
                scatter_head(&mut dqkv, &dv, bi, tokens, d3, 2 * dim + hh * hd, hd);
            }
        }
        let dbqkv = col_sum(&dqkv, d3);
        let dwqkv = matmul_tn(&xh, &dqkv, r, dim, d3);
        let dxh = matmul_nt(&dqkv, &ps[2].data, r, d3, dim);
        let (dx_ln1, dln1g, dln1b) = layer_norm_bwd(&x.data, r, dim, &ps[0].data, &dxh);
        let dx: Vec<f32> = dx2.iter().zip(&dx_ln1).map(|(a, l)| a + l).collect();

        Ok((
            vec![
                Tensor::vec1(dln1g),
                Tensor::vec1(dln1b),
                Tensor::new(ps[2].shape.clone(), dwqkv)?,
                Tensor::vec1(dbqkv),
                Tensor::new(ps[4].shape.clone(), dwproj)?,
                Tensor::vec1(dbproj),
                Tensor::vec1(dln2g),
                Tensor::vec1(dln2b),
                Tensor::new(ps[8].shape.clone(), dw1)?,
                Tensor::vec1(db1),
                Tensor::new(ps[10].shape.clone(), dw2)?,
                Tensor::vec1(db2),
            ],
            Tensor::new(x.shape.clone(), dx)?,
        ))
    }
}

/// `pooled[b,c] = mean over hw` for `x[b,hw,c]`.
fn gap_pool(x: &[f32], b: usize, hw: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * c];
    let inv = 1.0 / hw as f32;
    for bi in 0..b {
        for s in 0..hw {
            let base = (bi * hw + s) * c;
            let orow = &mut out[bi * c..(bi + 1) * c];
            for (ov, &xv) in orow.iter_mut().zip(&x[base..base + c]) {
                *ov += xv * inv;
            }
        }
    }
    out
}

/// `pooled[b,d] = mean over tokens` for `x[b,t,d]` (same layout as gap).
fn token_pool(x: &[f32], b: usize, tokens: usize, d: usize) -> Vec<f32> {
    gap_pool(x, b, tokens, d)
}

/// NHWC image -> `[b, tokens, patch*patch*chans]` token rows.
fn patchify(x: &[f32], b: usize, img: usize, chans: usize, patch: usize, grid: usize) -> Vec<f32> {
    let tokens = grid * grid;
    let pdim = patch * patch * chans;
    let mut out = vec![0.0f32; b * tokens * pdim];
    for bi in 0..b {
        for ti in 0..grid {
            for tj in 0..grid {
                let t = ti * grid + tj;
                for py in 0..patch {
                    for px in 0..patch {
                        let src = ((bi * img + ti * patch + py) * img + tj * patch + px) * chans;
                        let dst = ((bi * tokens + t) * pdim) + (py * patch + px) * chans;
                        out[dst..dst + chans].copy_from_slice(&x[src..src + chans]);
                    }
                }
            }
        }
    }
    out
}

/// Inverse of [`patchify`] (bijective, so plain assignment).
fn unpatchify(
    xp: &[f32],
    b: usize,
    img: usize,
    chans: usize,
    patch: usize,
    grid: usize,
) -> Vec<f32> {
    let tokens = grid * grid;
    let pdim = patch * patch * chans;
    let mut out = vec![0.0f32; b * img * img * chans];
    for bi in 0..b {
        for ti in 0..grid {
            for tj in 0..grid {
                let t = ti * grid + tj;
                for py in 0..patch {
                    for px in 0..patch {
                        let dst = ((bi * img + ti * patch + py) * img + tj * patch + px) * chans;
                        let src = ((bi * tokens + t) * pdim) + (py * patch + px) * chans;
                        out[dst..dst + chans].copy_from_slice(&xp[src..src + chans]);
                    }
                }
            }
        }
    }
    out
}

/// Extract head columns `[tokens, hd]` at `col` from `[b, tokens, width]`.
fn gather_head(
    buf: &[f32],
    bi: usize,
    tokens: usize,
    width: usize,
    col: usize,
    hd: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; tokens * hd];
    for t in 0..tokens {
        let src = (bi * tokens + t) * width + col;
        out[t * hd..(t + 1) * hd].copy_from_slice(&buf[src..src + hd]);
    }
    out
}

/// Scatter head columns back (adds into the destination).
fn scatter_head(
    buf: &mut [f32],
    head: &[f32],
    bi: usize,
    tokens: usize,
    width: usize,
    col: usize,
    hd: usize,
) {
    for t in 0..tokens {
        let dst = (bi * tokens + t) * width + col;
        for j in 0..hd {
            buf[dst + j] += head[t * hd + j];
        }
    }
}
