//! Per-segment interpreters: forward and VJP for every segment kind of
//! the built-in topologies (`python/compile/model.py` semantics).
//!
//! Each [`SegmentDef`] is constructed once from the meta inventory
//! (`SegmentDef::from_meta`) and then applied batch-agnostically:
//! `fwd(params, x[B,...], scratch) -> y`, `bwd(params, x, gy, scratch)
//! -> (param grads in meta order, gx)`. The VJPs are hand-derived (this
//! is what `jax.vjp` produced on the XLA path) and cross-checked against
//! finite differences in `tests/backend_golden.rs`.
//!
//! All GEMMs run on the tiled core in [`super::gemm`] and every
//! intermediate activation/grad buffer is taken from the backend's
//! [`Scratch`] arena, so steady-state passes allocate only their output
//! tensors.

// Index-heavy numeric loops read better with explicit ranges.
#![allow(clippy::needless_range_loop)]

use anyhow::{bail, Result};

use crate::config::builtin::GN_GROUPS;
use crate::config::ModelMeta;
use crate::runtime::ArgRef;
use crate::tensor::Tensor;

use super::gemm;
use super::kernels::{
    add_bias, col_sum, gelu_bwd_inplace, gelu_inplace, gelu_into, group_norm_bwd_into,
    group_norm_fwd_into, layer_norm_bwd, layer_norm_bwd_into, layer_norm_fwd_into,
    matmul_i8_into, relu, relu_bwd, softmax_bwd_into, softmax_rows, Conv,
};
use super::scratch::Scratch;

/// f32 data of param slot `i`. Quantized slots are GEMM/conv weights
/// only, so an int8 argument in any other position is a caller bug the
/// interpreter rejects instead of mis-executing.
fn fp<'a>(ps: &[ArgRef<'a>], i: usize) -> Result<&'a [f32]> {
    match ps[i] {
        ArgRef::F32(t) => Ok(&t.data),
        ArgRef::Quant(_) => bail!("param {i}: expected an f32 tensor, got an int8 weight"),
    }
}

/// Dense `out = x @ w`, dispatching on the weight slot's precision.
fn matmul_w(
    sc: &mut Scratch,
    x: &[f32],
    w: ArgRef,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    match w {
        ArgRef::F32(t) => gemm::matmul_into(sc, x, &t.data, m, k, n, out),
        ArgRef::Quant(q) => matmul_i8_into(sc, x, q, m, k, n, out),
    }
}

/// Conv forward, dispatching on the weight slot's precision.
fn conv_fwd_w(
    sc: &mut Scratch,
    cv: &Conv,
    x: &[f32],
    w: ArgRef,
    b: usize,
    h: usize,
    wd: usize,
    y: &mut [f32],
) {
    match w {
        ArgRef::F32(t) => cv.fwd_into(sc, x, &t.data, b, h, wd, y),
        ArgRef::Quant(q) => cv.fwd_i8_into(sc, x, q, b, h, wd, y),
    }
}

/// Static per-segment execution plan.
pub(crate) enum SegmentDef {
    /// conv3x3 s1 + GroupNorm + relu.
    Stem { h: usize, w: usize, conv: Conv },
    /// BasicBlock: two conv3x3 + GN (+ optional 1x1 downsample path),
    /// residual add, relu.
    Block { h: usize, w: usize, conv1: Conv, conv2: Conv, down: Option<Conv> },
    /// Global-average-pool + linear classifier (ResNet head).
    HeadGap { hw: usize, c: usize, classes: usize },
    /// LayerNorm + token-mean-pool + linear classifier (ViT head).
    HeadVit { tokens: usize, dim: usize, classes: usize },
    /// Patchify + linear embed + learned positional embedding.
    Embed { img: usize, chans: usize, patch: usize, grid: usize, dim: usize },
    /// Pre-LN transformer encoder block.
    Encoder { tokens: usize, dim: usize, heads: usize, mlp: usize },
}

/// Require parameter `idx` of a segment to declare exactly `want`.
/// Run-time tensors are checked against the meta by the module wrapper,
/// so meta-internal consistency here makes the interpreters panic-free
/// on arbitrary (artifact-supplied) inventories.
fn expect_param(seg: &crate::config::SegmentMeta, idx: usize, want: &[usize]) -> Result<()> {
    let got = &seg.params[idx].shape;
    if got != want {
        bail!(
            "{}.{}: inventory declares shape {:?}, geometry requires {:?}",
            seg.name,
            seg.params[idx].name,
            got,
            want
        );
    }
    Ok(())
}

fn expect_out(seg: &crate::config::SegmentMeta, want: &[usize]) -> Result<()> {
    if seg.out_shape != want {
        bail!(
            "{}: inventory declares out_shape {:?}, geometry requires {:?}",
            seg.name,
            seg.out_shape,
            want
        );
    }
    Ok(())
}

impl SegmentDef {
    /// Build the plan for segment `k`, validating the inventory: every
    /// parameter shape and the out_shape must be consistent with the
    /// geometry derived from in_shape, or this is an `Err` (never a
    /// panic or silently wrong math on a malformed meta.json).
    pub(crate) fn from_meta(meta: &ModelMeta, k: usize) -> Result<SegmentDef> {
        if k >= meta.num_segments() {
            bail!("segment {k} out of range ({})", meta.num_segments());
        }
        let seg = &meta.segments[k];
        let np = seg.params.len();
        match seg.kind.as_str() {
            "stem" => {
                if np != 3 || seg.params[0].shape.len() != 4 || seg.in_shape.len() != 3 {
                    bail!("stem `{}`: malformed inventory", seg.name);
                }
                let ws = seg.params[0].shape.clone();
                let (h, w) = (seg.in_shape[0], seg.in_shape[1]);
                let conv = Conv { kh: ws[0], kw: ws[1], cin: ws[2], cout: ws[3], stride: 1 };
                if ws[0] == 0 || ws[1] == 0 || ws[2] != seg.in_shape[2] {
                    bail!("stem `{}`: kernel/in_shape mismatch", seg.name);
                }
                expect_param(seg, 1, &[conv.cout])?;
                expect_param(seg, 2, &[conv.cout])?;
                let (ho, wo) = conv.out_hw(h, w);
                expect_out(seg, &[ho, wo, conv.cout])?;
                Ok(SegmentDef::Stem { h, w, conv })
            }
            "block" => {
                if !(np == 6 || np == 9) || seg.in_shape.len() != 3 || seg.out_shape.len() != 3 {
                    bail!("block `{}`: malformed inventory", seg.name);
                }
                let (h, w) = (seg.in_shape[0], seg.in_shape[1]);
                let (cin, cout) = (seg.in_shape[2], seg.out_shape[2]);
                if seg.out_shape[0] == 0 || h % seg.out_shape[0] != 0 {
                    bail!("block `{}`: bad spatial shapes", seg.name);
                }
                let stride = h / seg.out_shape[0];
                let down = np == 9;
                if down != (stride != 1 || cin != cout) {
                    bail!("block `{}`: downsample params inconsistent", seg.name);
                }
                let conv1 = Conv { kh: 3, kw: 3, cin, cout, stride };
                let conv2 = Conv { kh: 3, kw: 3, cin: cout, cout, stride: 1 };
                expect_param(seg, 0, &[3, 3, cin, cout])?;
                expect_param(seg, 1, &[cout])?;
                expect_param(seg, 2, &[cout])?;
                expect_param(seg, 3, &[3, 3, cout, cout])?;
                expect_param(seg, 4, &[cout])?;
                expect_param(seg, 5, &[cout])?;
                if down {
                    expect_param(seg, 6, &[1, 1, cin, cout])?;
                    expect_param(seg, 7, &[cout])?;
                    expect_param(seg, 8, &[cout])?;
                }
                let (ho, wo) = conv1.out_hw(h, w);
                expect_out(seg, &[ho, wo, cout])?;
                Ok(SegmentDef::Block {
                    h,
                    w,
                    conv1,
                    conv2,
                    down: down.then_some(Conv { kh: 1, kw: 1, cin, cout, stride }),
                })
            }
            "head" if seg.in_shape.len() == 3 => {
                if np != 2 || seg.out_shape.len() != 1 {
                    bail!("head `{}`: expected (w, b)", seg.name);
                }
                let c = seg.in_shape[2];
                let classes = seg.out_shape[0];
                expect_param(seg, 0, &[c, classes])?;
                expect_param(seg, 1, &[classes])?;
                Ok(SegmentDef::HeadGap {
                    hw: seg.in_shape[0] * seg.in_shape[1],
                    c,
                    classes,
                })
            }
            "head" => {
                if np != 4 || seg.in_shape.len() != 2 || seg.out_shape.len() != 1 {
                    bail!("head `{}`: expected (lng, lnb, w, b)", seg.name);
                }
                let (tokens, dim) = (seg.in_shape[0], seg.in_shape[1]);
                let classes = seg.out_shape[0];
                expect_param(seg, 0, &[dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[dim, classes])?;
                expect_param(seg, 3, &[classes])?;
                Ok(SegmentDef::HeadVit { tokens, dim, classes })
            }
            "embed" => {
                if np != 3 || seg.in_shape.len() != 3 || seg.out_shape.len() != 2 {
                    bail!("embed `{}`: malformed inventory", seg.name);
                }
                let img = seg.in_shape[0];
                let chans = seg.in_shape[2];
                let tokens = seg.out_shape[0];
                let dim = seg.out_shape[1];
                let grid = (1..=img).find(|g| g * g == tokens).unwrap_or(0);
                if grid == 0 || img % grid != 0 || seg.in_shape[1] != img {
                    bail!("embed `{}`: token grid {} not square in {}", seg.name, tokens, img);
                }
                let patch = img / grid;
                expect_param(seg, 0, &[patch * patch * chans, dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[tokens, dim])?;
                Ok(SegmentDef::Embed { img, chans, patch, grid, dim })
            }
            "encoder" => {
                if np != 12 || seg.in_shape.len() != 2 || seg.params[8].shape.len() != 2 {
                    bail!("encoder `{}`: malformed inventory", seg.name);
                }
                let (tokens, dim) = (seg.in_shape[0], seg.in_shape[1]);
                if meta.heads == 0 || dim % meta.heads != 0 {
                    bail!(
                        "encoder `{}`: dim {} not divisible by {} heads",
                        seg.name,
                        dim,
                        meta.heads
                    );
                }
                let mlp = seg.params[8].shape[1];
                expect_param(seg, 0, &[dim])?;
                expect_param(seg, 1, &[dim])?;
                expect_param(seg, 2, &[dim, 3 * dim])?;
                expect_param(seg, 3, &[3 * dim])?;
                expect_param(seg, 4, &[dim, dim])?;
                expect_param(seg, 5, &[dim])?;
                expect_param(seg, 6, &[dim])?;
                expect_param(seg, 7, &[dim])?;
                expect_param(seg, 8, &[dim, mlp])?;
                expect_param(seg, 9, &[mlp])?;
                expect_param(seg, 10, &[mlp, dim])?;
                expect_param(seg, 11, &[dim])?;
                expect_out(seg, &[tokens, dim])?;
                Ok(SegmentDef::Encoder { tokens, dim, heads: meta.heads, mlp })
            }
            other => bail!(
                "unsupported segment kind `{other}` for the CpuBackend (segment `{}`)",
                seg.name
            ),
        }
    }

    /// Forward: `(params..., x[B,...]) -> y`. Parameter slots arrive as
    /// [`ArgRef`]s: GEMM/conv weight slots may be int8 (dispatched to
    /// the true-int8 core), everything else is f32.
    pub(crate) fn fwd(&self, ps: &[ArgRef], x: &Tensor, sc: &mut Scratch) -> Result<Tensor> {
        let b = x.batch();
        match self {
            SegmentDef::Stem { h, w, conv } => {
                let (ho, wo) = conv.out_hw(*h, *w);
                let mut c1 = sc.take_any(b * ho * wo * conv.cout);
                conv_fwd_w(sc, conv, &x.data, ps[0], b, *h, *w, &mut c1);
                let mut y = vec![0.0f32; c1.len()];
                group_norm_fwd_into(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, fp(ps, 1)?, fp(ps, 2)?, &mut y,
                );
                sc.put(c1);
                relu(&mut y);
                Tensor::new(vec![b, ho, wo, conv.cout], y)
            }
            SegmentDef::Block { h, w, conv1, conv2, down } => {
                let cout = conv1.cout;
                let (ho, wo) = conv1.out_hw(*h, *w);
                let hw = ho * wo;
                let len = b * hw * cout;
                let mut c1 = sc.take_any(len);
                conv_fwd_w(sc, conv1, &x.data, ps[0], b, *h, *w, &mut c1);
                let mut h1 = sc.take(len);
                group_norm_fwd_into(
                    &c1, b, hw, cout, GN_GROUPS, fp(ps, 1)?, fp(ps, 2)?, &mut h1,
                );
                relu(&mut h1);
                // c1 is dead — reuse it for the second conv's output
                conv_fwd_w(sc, conv2, &h1, ps[3], b, ho, wo, &mut c1);
                sc.put(h1);
                let mut y = vec![0.0f32; len];
                group_norm_fwd_into(
                    &c1, b, hw, cout, GN_GROUPS, fp(ps, 4)?, fp(ps, 5)?, &mut y,
                );
                sc.put(c1);
                match down {
                    Some(cd) => {
                        let mut cdo = sc.take_any(len);
                        conv_fwd_w(sc, cd, &x.data, ps[6], b, *h, *w, &mut cdo);
                        let mut scb = sc.take(len);
                        group_norm_fwd_into(
                            &cdo, b, hw, cout, GN_GROUPS, fp(ps, 7)?, fp(ps, 8)?, &mut scb,
                        );
                        sc.put(cdo);
                        for (yv, sv) in y.iter_mut().zip(&scb) {
                            *yv += sv;
                        }
                        sc.put(scb);
                    }
                    None => {
                        for (yv, sv) in y.iter_mut().zip(&x.data) {
                            *yv += sv;
                        }
                    }
                }
                relu(&mut y);
                Tensor::new(vec![b, ho, wo, cout], y)
            }
            SegmentDef::HeadGap { hw, c, classes } => {
                let mut pooled = sc.take_any(b * c);
                gap_pool_into(&x.data, b, *hw, *c, &mut pooled);
                let mut y = vec![0.0f32; b * classes];
                matmul_w(sc, &pooled, ps[0], b, *c, *classes, &mut y);
                sc.put(pooled);
                add_bias(&mut y, fp(ps, 1)?);
                Tensor::new(vec![b, *classes], y)
            }
            SegmentDef::HeadVit { tokens, dim, classes } => {
                let r = b * tokens;
                let mut hn = sc.take_any(r * dim);
                layer_norm_fwd_into(&x.data, r, *dim, fp(ps, 0)?, fp(ps, 1)?, &mut hn);
                let mut pooled = sc.take_any(b * dim);
                gap_pool_into(&hn, b, *tokens, *dim, &mut pooled); // token mean-pool
                sc.put(hn);
                let mut y = vec![0.0f32; b * classes];
                matmul_w(sc, &pooled, ps[2], b, *dim, *classes, &mut y);
                sc.put(pooled);
                add_bias(&mut y, fp(ps, 3)?);
                Tensor::new(vec![b, *classes], y)
            }
            SegmentDef::Embed { img, chans, patch, grid, dim } => {
                let tokens = grid * grid;
                let pdim = patch * patch * chans;
                let mut xp = sc.take_any(b * tokens * pdim);
                patchify_into(&x.data, b, *img, *chans, *patch, *grid, &mut xp);
                let mut y = vec![0.0f32; b * tokens * dim];
                matmul_w(sc, &xp, ps[0], b * tokens, pdim, *dim, &mut y);
                sc.put(xp);
                add_bias(&mut y, fp(ps, 1)?);
                let pos = fp(ps, 2)?;
                for bi in 0..b {
                    let base = bi * tokens * dim;
                    for (yv, &pv) in y[base..base + tokens * dim].iter_mut().zip(pos) {
                        *yv += pv;
                    }
                }
                Tensor::new(vec![b, tokens, *dim], y)
            }
            SegmentDef::Encoder { tokens, dim, heads, mlp } => {
                let y = self.encoder_fwd(ps, &x.data, b, *tokens, *dim, *heads, *mlp, sc)?;
                Tensor::new(vec![b, *tokens, *dim], y)
            }
        }
    }

    /// VJP: `(params..., x, gy) -> (param grads in meta order, gx)`.
    pub(crate) fn bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
        sc: &mut Scratch,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let b = x.batch();
        match self {
            SegmentDef::Stem { h, w, conv } => {
                let (ho, wo) = conv.out_hw(*h, *w);
                let len = b * ho * wo * conv.cout;
                let mut c1 = sc.take_any(len);
                conv.fwd_into(sc, &x.data, &ps[0].data, b, *h, *w, &mut c1);
                let mut o = sc.take(len);
                group_norm_fwd_into(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, &ps[1].data, &ps[2].data, &mut o,
                );
                let mut g = sc.take_from(&gy.data);
                relu_bwd(&o, &mut g);
                sc.put(o);
                let mut dc1 = sc.take(len);
                let (dgamma, dbeta) = group_norm_bwd_into(
                    &c1, b, ho * wo, conv.cout, GN_GROUPS, &ps[1].data, &g, &mut dc1,
                );
                sc.put(c1);
                sc.put(g);
                let mut dx = vec![0.0f32; b * h * w * conv.cin];
                let mut dw = vec![0.0f32; conv.kh * conv.kw * conv.cin * conv.cout];
                conv.bwd_into(sc, &x.data, &ps[0].data, &dc1, b, *h, *w, &mut dx, &mut dw);
                sc.put(dc1);
                Ok((
                    vec![
                        Tensor::new(ps[0].shape.clone(), dw)?,
                        Tensor::vec1(dgamma),
                        Tensor::vec1(dbeta),
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Block { h, w, conv1, conv2, down } => {
                self.block_bwd(ps, x, gy, b, *h, *w, conv1, conv2, down.as_ref(), sc)
            }
            SegmentDef::HeadGap { hw, c, classes } => {
                let mut pooled = sc.take_any(b * c);
                gap_pool_into(&x.data, b, *hw, *c, &mut pooled);
                let mut dw = vec![0.0f32; c * classes];
                gemm::matmul_tn_into(sc, &pooled, &gy.data, b, *c, *classes, &mut dw);
                sc.put(pooled);
                let db = col_sum(&gy.data, *classes);
                let mut dpooled = sc.take_any(b * c);
                gemm::matmul_nt_into(sc, &gy.data, &ps[0].data, b, *classes, *c, &mut dpooled);
                let mut dx = vec![0.0f32; b * hw * c];
                let inv = 1.0 / *hw as f32;
                for bi in 0..b {
                    for s in 0..*hw {
                        let base = (bi * hw + s) * c;
                        for ch in 0..*c {
                            dx[base + ch] = dpooled[bi * c + ch] * inv;
                        }
                    }
                }
                sc.put(dpooled);
                Ok((
                    vec![Tensor::new(ps[0].shape.clone(), dw)?, Tensor::vec1(db)],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::HeadVit { tokens, dim, classes } => {
                let r = b * tokens;
                let mut hn = sc.take_any(r * dim);
                layer_norm_fwd_into(&x.data, r, *dim, &ps[0].data, &ps[1].data, &mut hn);
                let mut pooled = sc.take_any(b * dim);
                gap_pool_into(&hn, b, *tokens, *dim, &mut pooled);
                sc.put(hn);
                let mut dw = vec![0.0f32; dim * classes];
                gemm::matmul_tn_into(sc, &pooled, &gy.data, b, *dim, *classes, &mut dw);
                sc.put(pooled);
                let db = col_sum(&gy.data, *classes);
                let mut dpooled = sc.take_any(b * dim);
                gemm::matmul_nt_into(sc, &gy.data, &ps[2].data, b, *classes, *dim, &mut dpooled);
                // broadcast back over tokens
                let inv = 1.0 / *tokens as f32;
                let mut dh = sc.take_any(r * dim);
                for bi in 0..b {
                    for t in 0..*tokens {
                        let base = (bi * tokens + t) * dim;
                        for dd in 0..*dim {
                            dh[base + dd] = dpooled[bi * dim + dd] * inv;
                        }
                    }
                }
                sc.put(dpooled);
                let (dx, dlng, dlnb) = layer_norm_bwd(&x.data, r, *dim, &ps[0].data, &dh);
                sc.put(dh);
                Ok((
                    vec![
                        Tensor::vec1(dlng),
                        Tensor::vec1(dlnb),
                        Tensor::new(ps[2].shape.clone(), dw)?,
                        Tensor::vec1(db),
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Embed { img, chans, patch, grid, dim } => {
                let tokens = grid * grid;
                let pdim = patch * patch * chans;
                let r = b * tokens;
                let mut xp = sc.take_any(r * pdim);
                patchify_into(&x.data, b, *img, *chans, *patch, *grid, &mut xp);
                let mut dw = vec![0.0f32; pdim * dim];
                gemm::matmul_tn_into(sc, &xp, &gy.data, r, pdim, *dim, &mut dw);
                sc.put(xp);
                let db = col_sum(&gy.data, *dim);
                let mut dpos = vec![0.0f32; tokens * dim];
                for bi in 0..b {
                    let base = bi * tokens * dim;
                    for (dp, &gv) in dpos.iter_mut().zip(&gy.data[base..base + tokens * dim]) {
                        *dp += gv;
                    }
                }
                let mut dxp = sc.take_any(r * pdim);
                gemm::matmul_nt_into(sc, &gy.data, &ps[0].data, r, *dim, pdim, &mut dxp);
                let mut dx = vec![0.0f32; b * img * img * chans];
                unpatchify_into(&dxp, b, *img, *chans, *patch, *grid, &mut dx);
                sc.put(dxp);
                Ok((
                    vec![
                        Tensor::new(ps[0].shape.clone(), dw)?,
                        Tensor::vec1(db),
                        Tensor::new(ps[2].shape.clone(), dpos)?,
                    ],
                    Tensor::new(x.shape.clone(), dx)?,
                ))
            }
            SegmentDef::Encoder { tokens, dim, heads, mlp } => {
                self.encoder_bwd(ps, x, gy, b, *tokens, *dim, *heads, *mlp, sc)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn block_bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
        b: usize,
        h: usize,
        w: usize,
        conv1: &Conv,
        conv2: &Conv,
        down: Option<&Conv>,
        sc: &mut Scratch,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let cout = conv1.cout;
        let (ho, wo) = conv1.out_hw(h, w);
        let hw = ho * wo;
        let len = b * hw * cout;
        // --- recompute forward intermediates ---
        let mut c1 = sc.take_any(len);
        conv1.fwd_into(sc, &x.data, &ps[0].data, b, h, w, &mut c1);
        let mut h1 = sc.take(len);
        group_norm_fwd_into(&c1, b, hw, cout, GN_GROUPS, &ps[1].data, &ps[2].data, &mut h1);
        relu(&mut h1); // h1 > 0 exactly where the pre-relu o1 > 0
        let mut c2 = sc.take_any(len);
        conv2.fwd_into(sc, &h1, &ps[3].data, b, ho, wo, &mut c2);
        let mut pre = sc.take(len); // o2, then o2 + shortcut
        group_norm_fwd_into(&c2, b, hw, cout, GN_GROUPS, &ps[4].data, &ps[5].data, &mut pre);
        let cdo = match down {
            Some(cd) => {
                let mut cdo = sc.take_any(len);
                cd.fwd_into(sc, &x.data, &ps[6].data, b, h, w, &mut cdo);
                let mut scb = sc.take(len);
                group_norm_fwd_into(
                    &cdo, b, hw, cout, GN_GROUPS, &ps[7].data, &ps[8].data, &mut scb,
                );
                for (p, s) in pre.iter_mut().zip(&scb) {
                    *p += s;
                }
                sc.put(scb);
                Some(cdo)
            }
            None => {
                for (p, s) in pre.iter_mut().zip(&x.data) {
                    *p += s;
                }
                None
            }
        };

        // --- backward ---
        let mut g = sc.take_from(&gy.data);
        relu_bwd(&pre, &mut g); // grad at o2 and sc alike
        sc.put(pre);
        let mut dc2 = sc.take(len);
        let (dg2, db2) =
            group_norm_bwd_into(&c2, b, hw, cout, GN_GROUPS, &ps[4].data, &g, &mut dc2);
        sc.put(c2);
        let mut dh1 = sc.take_any(len);
        let mut dw2 = vec![0.0f32; conv2.kh * conv2.kw * conv2.cin * conv2.cout];
        conv2.bwd_into(sc, &h1, &ps[3].data, &dc2, b, ho, wo, &mut dh1, &mut dw2);
        sc.put(dc2);
        relu_bwd(&h1, &mut dh1);
        sc.put(h1);
        let mut dc1 = sc.take(len);
        let (dg1, db1) =
            group_norm_bwd_into(&c1, b, hw, cout, GN_GROUPS, &ps[1].data, &dh1, &mut dc1);
        sc.put(c1);
        sc.put(dh1);
        let mut dx = vec![0.0f32; b * h * w * conv1.cin];
        let mut dw1 = vec![0.0f32; conv1.kh * conv1.kw * conv1.cin * conv1.cout];
        conv1.bwd_into(sc, &x.data, &ps[0].data, &dc1, b, h, w, &mut dx, &mut dw1);
        sc.put(dc1);

        let mut grads = vec![
            Tensor::new(ps[0].shape.clone(), dw1)?,
            Tensor::vec1(dg1),
            Tensor::vec1(db1),
            Tensor::new(ps[3].shape.clone(), dw2)?,
            Tensor::vec1(dg2),
            Tensor::vec1(db2),
        ];
        match (down, cdo) {
            (Some(cd), Some(cdo)) => {
                let mut dcdo = sc.take(len);
                let (dgd, dbd) =
                    group_norm_bwd_into(&cdo, b, hw, cout, GN_GROUPS, &ps[7].data, &g, &mut dcdo);
                sc.put(cdo);
                let mut dx2 = sc.take_any(b * h * w * cd.cin);
                let mut dwd = vec![0.0f32; cd.kh * cd.kw * cd.cin * cd.cout];
                cd.bwd_into(sc, &x.data, &ps[6].data, &dcdo, b, h, w, &mut dx2, &mut dwd);
                sc.put(dcdo);
                for (a, v) in dx.iter_mut().zip(&dx2) {
                    *a += v;
                }
                sc.put(dx2);
                grads.push(Tensor::new(ps[6].shape.clone(), dwd)?);
                grads.push(Tensor::vec1(dgd));
                grads.push(Tensor::vec1(dbd));
            }
            _ => {
                for (a, v) in dx.iter_mut().zip(&g) {
                    *a += v;
                }
            }
        }
        sc.put(g);
        Ok((grads, Tensor::new(x.shape.clone(), dx)?))
    }

    /// Encoder forward. The four weight GEMMs (qkv, proj, mlp up/down)
    /// dispatch on their slot's precision; the attention score/context
    /// GEMMs are activation-activation products and stay f32, mirroring
    /// the weight-stationary int8 streaming of the hardware.
    #[allow(clippy::too_many_arguments)]
    fn encoder_fwd(
        &self,
        ps: &[ArgRef],
        x: &[f32],
        b: usize,
        tokens: usize,
        dim: usize,
        heads: usize,
        mlp: usize,
        sc: &mut Scratch,
    ) -> Result<Vec<f32>> {
        let r = b * tokens;
        let d3 = 3 * dim;
        let hd = dim / heads;
        let inv = 1.0 / (hd as f32).sqrt();
        let mut xh = sc.take_any(r * dim);
        layer_norm_fwd_into(x, r, dim, fp(ps, 0)?, fp(ps, 1)?, &mut xh);
        let mut qkv = sc.take_any(r * d3);
        matmul_w(sc, &xh, ps[2], r, dim, d3, &mut qkv);
        sc.put(xh);
        add_bias(&mut qkv, fp(ps, 3)?);
        let mut o = sc.take(r * dim); // zeroed: heads scatter-add into it
        let mut q = sc.take_any(tokens * hd);
        let mut kb = sc.take_any(tokens * hd);
        let mut v = sc.take_any(tokens * hd);
        let mut att = sc.take_any(tokens * tokens);
        let mut oh = sc.take_any(tokens * hd);
        for bi in 0..b {
            for hh in 0..heads {
                gather_head_into(&qkv, bi, tokens, d3, hh * hd, hd, &mut q);
                gather_head_into(&qkv, bi, tokens, d3, dim + hh * hd, hd, &mut kb);
                gather_head_into(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd, &mut v);
                gemm::matmul_nt_into(sc, &q, &kb, tokens, hd, tokens, &mut att);
                for a in att.iter_mut() {
                    *a *= inv;
                }
                softmax_rows(&mut att, tokens);
                gemm::matmul_into(sc, &att, &v, tokens, tokens, hd, &mut oh);
                scatter_head(&mut o, &oh, bi, tokens, dim, hh * hd, hd);
            }
        }
        sc.put(q);
        sc.put(kb);
        sc.put(v);
        sc.put(att);
        sc.put(oh);
        sc.put(qkv);
        let mut x2 = sc.take_any(r * dim); // attention projection, then + x
        matmul_w(sc, &o, ps[4], r, dim, dim, &mut x2);
        sc.put(o);
        add_bias(&mut x2, fp(ps, 5)?);
        for (pv, &xv) in x2.iter_mut().zip(x) {
            *pv += xv;
        }
        let mut h2 = sc.take_any(r * dim);
        layer_norm_fwd_into(&x2, r, dim, fp(ps, 6)?, fp(ps, 7)?, &mut h2);
        let mut z1 = sc.take_any(r * mlp);
        matmul_w(sc, &h2, ps[8], r, dim, mlp, &mut z1);
        sc.put(h2);
        add_bias(&mut z1, fp(ps, 9)?);
        gelu_inplace(&mut z1);
        let mut y = vec![0.0f32; r * dim];
        matmul_w(sc, &z1, ps[10], r, mlp, dim, &mut y);
        sc.put(z1);
        add_bias(&mut y, fp(ps, 11)?);
        for (yv, xv) in y.iter_mut().zip(&x2) {
            *yv += xv;
        }
        sc.put(x2);
        Ok(y)
    }

    #[allow(clippy::too_many_arguments)]
    fn encoder_bwd(
        &self,
        ps: &[&Tensor],
        x: &Tensor,
        gy: &Tensor,
        b: usize,
        tokens: usize,
        dim: usize,
        heads: usize,
        mlp: usize,
        sc: &mut Scratch,
    ) -> Result<(Vec<Tensor>, Tensor)> {
        let r = b * tokens;
        let d3 = 3 * dim;
        let hd = dim / heads;
        let inv = 1.0 / (hd as f32).sqrt();

        // --- recompute forward intermediates ---
        let mut xh = sc.take_any(r * dim);
        layer_norm_fwd_into(&x.data, r, dim, &ps[0].data, &ps[1].data, &mut xh);
        let mut qkv = sc.take_any(r * d3);
        gemm::matmul_into(sc, &xh, &ps[2].data, r, dim, d3, &mut qkv);
        add_bias(&mut qkv, &ps[3].data);
        let mut o = sc.take(r * dim);
        let mut q = sc.take_any(tokens * hd);
        let mut kb = sc.take_any(tokens * hd);
        let mut v = sc.take_any(tokens * hd);
        let mut oh = sc.take_any(tokens * hd);
        // all b*heads softmax maps staged in ONE buffer (kept for the
        // VJP) so the arena parks a single large slab, not b*heads tiles
        let tt = tokens * tokens;
        let mut atts = sc.take_any(b * heads * tt);
        for bi in 0..b {
            for hh in 0..heads {
                gather_head_into(&qkv, bi, tokens, d3, hh * hd, hd, &mut q);
                gather_head_into(&qkv, bi, tokens, d3, dim + hh * hd, hd, &mut kb);
                gather_head_into(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd, &mut v);
                let att = &mut atts[(bi * heads + hh) * tt..(bi * heads + hh + 1) * tt];
                gemm::matmul_nt_into(sc, &q, &kb, tokens, hd, tokens, att);
                for a in att.iter_mut() {
                    *a *= inv;
                }
                softmax_rows(att, tokens);
                gemm::matmul_into(sc, att, &v, tokens, tokens, hd, &mut oh);
                scatter_head(&mut o, &oh, bi, tokens, dim, hh * hd, hd);
            }
        }
        let mut x2 = sc.take_any(r * dim);
        gemm::matmul_into(sc, &o, &ps[4].data, r, dim, dim, &mut x2);
        add_bias(&mut x2, &ps[5].data);
        for (pv, &xv) in x2.iter_mut().zip(&x.data) {
            *pv += xv;
        }
        let mut h2 = sc.take_any(r * dim);
        layer_norm_fwd_into(&x2, r, dim, &ps[6].data, &ps[7].data, &mut h2);
        let mut z1 = sc.take_any(r * mlp);
        gemm::matmul_into(sc, &h2, &ps[8].data, r, dim, mlp, &mut z1);
        add_bias(&mut z1, &ps[9].data);
        let mut a = sc.take_any(r * mlp);
        gelu_into(&z1, &mut a);

        // --- backward: mlp sub-block ---
        let g = &gy.data;
        let db2 = col_sum(g, dim);
        let mut dw2 = vec![0.0f32; mlp * dim];
        gemm::matmul_tn_into(sc, &a, g, r, mlp, dim, &mut dw2);
        sc.put(a);
        let mut dz1 = sc.take_any(r * mlp); // da, masked in place to dz1
        gemm::matmul_nt_into(sc, g, &ps[10].data, r, dim, mlp, &mut dz1);
        gelu_bwd_inplace(&z1, &mut dz1);
        sc.put(z1);
        let db1 = col_sum(&dz1, mlp);
        let mut dw1 = vec![0.0f32; dim * mlp];
        gemm::matmul_tn_into(sc, &h2, &dz1, r, dim, mlp, &mut dw1);
        sc.put(h2);
        let mut dh2 = sc.take_any(r * dim);
        gemm::matmul_nt_into(sc, &dz1, &ps[8].data, r, mlp, dim, &mut dh2);
        sc.put(dz1);
        let mut dx2 = sc.take_any(r * dim);
        let (dln2g, dln2b) = layer_norm_bwd_into(&x2, r, dim, &ps[6].data, &dh2, &mut dx2);
        sc.put(dh2);
        for (dv, &gv) in dx2.iter_mut().zip(g) {
            *dv += gv;
        }
        sc.put(x2);

        // --- projection ---
        let dbproj = col_sum(&dx2, dim);
        let mut dwproj = vec![0.0f32; dim * dim];
        gemm::matmul_tn_into(sc, &o, &dx2, r, dim, dim, &mut dwproj);
        sc.put(o);
        let mut do_ = sc.take_any(r * dim);
        gemm::matmul_nt_into(sc, &dx2, &ps[4].data, r, dim, dim, &mut do_);

        // --- attention ---
        let mut dqkv = sc.take(r * d3); // zeroed: heads scatter-add into it
        let mut datt = sc.take_any(tokens * tokens);
        let mut ds = sc.take_any(tokens * tokens);
        let mut doh = sc.take_any(tokens * hd);
        let mut dq = sc.take_any(tokens * hd);
        let mut dk = sc.take_any(tokens * hd);
        let mut dvh = sc.take_any(tokens * hd);
        for bi in 0..b {
            for hh in 0..heads {
                let att = &atts[(bi * heads + hh) * tt..(bi * heads + hh + 1) * tt];
                gather_head_into(&qkv, bi, tokens, d3, hh * hd, hd, &mut q);
                gather_head_into(&qkv, bi, tokens, d3, dim + hh * hd, hd, &mut kb);
                gather_head_into(&qkv, bi, tokens, d3, 2 * dim + hh * hd, hd, &mut v);
                gather_head_into(&do_, bi, tokens, dim, hh * hd, hd, &mut doh);
                gemm::matmul_nt_into(sc, &doh, &v, tokens, hd, tokens, &mut datt);
                gemm::matmul_tn_into(sc, att, &doh, tokens, tokens, hd, &mut dvh);
                softmax_bwd_into(att, &datt, tokens, &mut ds);
                for s in ds.iter_mut() {
                    *s *= inv;
                }
                gemm::matmul_into(sc, &ds, &kb, tokens, tokens, hd, &mut dq);
                gemm::matmul_tn_into(sc, &ds, &q, tokens, tokens, hd, &mut dk);
                scatter_head(&mut dqkv, &dq, bi, tokens, d3, hh * hd, hd);
                scatter_head(&mut dqkv, &dk, bi, tokens, d3, dim + hh * hd, hd);
                scatter_head(&mut dqkv, &dvh, bi, tokens, d3, 2 * dim + hh * hd, hd);
            }
        }
        sc.put(atts);
        sc.put(datt);
        sc.put(ds);
        sc.put(doh);
        sc.put(dq);
        sc.put(dk);
        sc.put(dvh);
        sc.put(q);
        sc.put(kb);
        sc.put(v);
        sc.put(oh);
        sc.put(do_);
        sc.put(qkv);
        let dbqkv = col_sum(&dqkv, d3);
        let mut dwqkv = vec![0.0f32; dim * d3];
        gemm::matmul_tn_into(sc, &xh, &dqkv, r, dim, d3, &mut dwqkv);
        sc.put(xh);
        let mut dxh = sc.take_any(r * dim);
        gemm::matmul_nt_into(sc, &dqkv, &ps[2].data, r, d3, dim, &mut dxh);
        sc.put(dqkv);
        let (mut dx, dln1g, dln1b) = layer_norm_bwd(&x.data, r, dim, &ps[0].data, &dxh);
        sc.put(dxh);
        for (dv, &av) in dx.iter_mut().zip(&dx2) {
            *dv += av;
        }
        sc.put(dx2);

        Ok((
            vec![
                Tensor::vec1(dln1g),
                Tensor::vec1(dln1b),
                Tensor::new(ps[2].shape.clone(), dwqkv)?,
                Tensor::vec1(dbqkv),
                Tensor::new(ps[4].shape.clone(), dwproj)?,
                Tensor::vec1(dbproj),
                Tensor::vec1(dln2g),
                Tensor::vec1(dln2b),
                Tensor::new(ps[8].shape.clone(), dw1)?,
                Tensor::vec1(db1),
                Tensor::new(ps[10].shape.clone(), dw2)?,
                Tensor::vec1(db2),
            ],
            Tensor::new(x.shape.clone(), dx)?,
        ))
    }
}

/// `pooled[b,c] = mean over hw` for `x[b,hw,c]` (also the token
/// mean-pool: same layout with `hw = tokens`). Fully overwrites `out`.
fn gap_pool_into(x: &[f32], b: usize, hw: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), b * c);
    out.fill(0.0);
    let inv = 1.0 / hw as f32;
    for bi in 0..b {
        for s in 0..hw {
            let base = (bi * hw + s) * c;
            let orow = &mut out[bi * c..(bi + 1) * c];
            for (ov, &xv) in orow.iter_mut().zip(&x[base..base + c]) {
                *ov += xv * inv;
            }
        }
    }
}

/// NHWC image -> `[b, tokens, patch*patch*chans]` token rows (fully
/// overwrites `out`).
fn patchify_into(
    x: &[f32],
    b: usize,
    img: usize,
    chans: usize,
    patch: usize,
    grid: usize,
    out: &mut [f32],
) {
    let tokens = grid * grid;
    let pdim = patch * patch * chans;
    debug_assert_eq!(out.len(), b * tokens * pdim);
    for bi in 0..b {
        for ti in 0..grid {
            for tj in 0..grid {
                let t = ti * grid + tj;
                for py in 0..patch {
                    for px in 0..patch {
                        let src = ((bi * img + ti * patch + py) * img + tj * patch + px) * chans;
                        let dst = ((bi * tokens + t) * pdim) + (py * patch + px) * chans;
                        out[dst..dst + chans].copy_from_slice(&x[src..src + chans]);
                    }
                }
            }
        }
    }
}

/// Inverse of [`patchify_into`] (bijective, so plain assignment; fully
/// overwrites `out`).
fn unpatchify_into(
    xp: &[f32],
    b: usize,
    img: usize,
    chans: usize,
    patch: usize,
    grid: usize,
    out: &mut [f32],
) {
    let tokens = grid * grid;
    let pdim = patch * patch * chans;
    debug_assert_eq!(out.len(), b * img * img * chans);
    for bi in 0..b {
        for ti in 0..grid {
            for tj in 0..grid {
                let t = ti * grid + tj;
                for py in 0..patch {
                    for px in 0..patch {
                        let dst = ((bi * img + ti * patch + py) * img + tj * patch + px) * chans;
                        let src = ((bi * tokens + t) * pdim) + (py * patch + px) * chans;
                        out[dst..dst + chans].copy_from_slice(&xp[src..src + chans]);
                    }
                }
            }
        }
    }
}

/// Extract head columns `[tokens, hd]` at `col` from `[b, tokens, width]`
/// (fully overwrites `out`).
fn gather_head_into(
    buf: &[f32],
    bi: usize,
    tokens: usize,
    width: usize,
    col: usize,
    hd: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), tokens * hd);
    for t in 0..tokens {
        let src = (bi * tokens + t) * width + col;
        out[t * hd..(t + 1) * hd].copy_from_slice(&buf[src..src + hd]);
    }
}

/// Scatter head columns back (adds into the destination).
fn scatter_head(
    buf: &mut [f32],
    head: &[f32],
    bi: usize,
    tokens: usize,
    width: usize,
    col: usize,
    hd: usize,
) {
    for t in 0..tokens {
        let dst = (bi * tokens + t) * width + col;
        for j in 0..hd {
            buf[dst + j] += head[t * hd + j];
        }
    }
}
