//! Zero-alloc scratch arena for the CpuBackend hot path.
//!
//! Every forward/VJP interpreter pass used to allocate a fresh `Vec` for
//! each im2col patch matrix, packed GEMM panel, and activation/grad
//! temporary. Under the per-layer unlearning loop those allocations
//! recur with identical sizes thousands of times, so the interpreters
//! `take`/`put` buffers from a [`Scratch`] pool instead. Buffers are
//! handed out as plain `Vec<f32>` so a caller can still keep one (e.g.
//! to move into an output `Tensor`) — anything not `put` back simply
//! stops being pooled.
//!
//! The pool is **per worker thread** ([`with`]), not baked into the
//! compiled modules: module bodies are immutable `Send + Sync` programs
//! shared across fleet workers behind `Arc<Executable>`, so each thread
//! that executes them brings its own arena. A worker's pool converges to
//! the buffer sizes of the models *it* serves; threads never contend.
//! The GEMM worker threads still never touch the arena — the packed-B
//! panel is taken before the fork and returned after the join.

use std::cell::RefCell;

thread_local! {
    /// The calling thread's scratch arena (one per fleet worker / test
    /// thread, created on first use).
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with the calling thread's [`Scratch`] arena.
///
/// The arena is borrowed for the duration of `f`; module bodies take it
/// once at their entry point and thread `&mut Scratch` through their
/// kernels (nested `with` calls would panic on the `RefCell`, exactly
/// like the nested `borrow_mut` of the old backend-owned arena).
pub fn with<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|sc| f(&mut sc.borrow_mut()))
}

/// Upper bound on parked buffers; beyond this the smallest is dropped so
/// the pool converges to the few large panel/activation sizes that
/// dominate the hot path instead of hoarding every tile ever seen.
const MAX_POOLED: usize = 32;

/// Best-fit take shared by the f32 and i8 pools: the smallest parked
/// buffer that already holds `len`, else the largest so regrowth
/// converges. Returns the buffer (length/contents unadjusted) and
/// whether a fresh allocation was needed.
fn pool_take<T>(pool: &mut Vec<Vec<T>>, len: usize) -> (Vec<T>, bool) {
    let mut best: Option<usize> = None;
    for (i, buf) in pool.iter().enumerate() {
        best = match best {
            None => Some(i),
            Some(j) => {
                let (c, cj) = (buf.capacity(), pool[j].capacity());
                let (fits, jfits) = (c >= len, cj >= len);
                if (fits && (!jfits || c < cj)) || (!fits && !jfits && c > cj) {
                    Some(i)
                } else {
                    Some(j)
                }
            }
        };
    }
    let v = match best {
        Some(i) => pool.swap_remove(i),
        None => Vec::new(),
    };
    if v.capacity() < len {
        // fresh allocation instead of reserve(): a realloc would
        // memcpy stale contents every taker discards anyway
        return (Vec::with_capacity(len), true);
    }
    (v, false)
}

/// Park a buffer, evicting the smallest once the pool exceeds
/// [`MAX_POOLED`]. Zero-capacity buffers are dropped.
fn pool_put<T>(pool: &mut Vec<Vec<T>>, buf: Vec<T>) {
    if buf.capacity() == 0 {
        return;
    }
    pool.push(buf);
    if pool.len() > MAX_POOLED {
        if let Some(i) = (0..pool.len()).min_by_key(|&i| pool[i].capacity()) {
            pool.swap_remove(i);
        }
    }
}

/// Reusable `f32` buffer pool. `take` returns a zero-filled buffer of
/// the exact requested length, reusing parked capacity when possible;
/// `put` parks a buffer for the next taker. A small parallel `i8` pool
/// serves the int8 GEMM panel packs.
#[derive(Debug, Default)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
    pool_i8: Vec<Vec<i8>>,
    takes: u64,
    grows: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A buffer with capacity for at least `len` elements, length and
    /// contents unadjusted ([`pool_take`] best-fit).
    fn take_raw(&mut self, len: usize) -> Vec<f32> {
        self.takes += 1;
        let (v, grew) = pool_take(&mut self.pool, len);
        if grew {
            self.grows += 1;
        }
        v
    }

    /// Borrow a zero-filled buffer of exactly `len` elements — for
    /// destinations that are accumulated into (scatter-adds) or only
    /// partially written (GroupNorm residual channels).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_raw(len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Borrow a buffer of exactly `len` elements with *arbitrary*
    /// (stale but initialized) contents — for destinations the caller
    /// fully overwrites (GEMM outputs, packs, norms over the last dim).
    /// Skips the zero-fill memset [`Scratch::take`] pays.
    pub fn take_any(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_raw(len);
        v.resize(len, 0.0); // zero-fills only the growth tail, if any
        v
    }

    /// Borrow a buffer initialized to a copy of `src` (no zero-fill pass).
    pub fn take_from(&mut self, src: &[f32]) -> Vec<f32> {
        let mut v = self.take_raw(src.len());
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// Park a buffer for reuse. Zero-capacity buffers are dropped.
    pub fn put(&mut self, buf: Vec<f32>) {
        pool_put(&mut self.pool, buf);
    }

    /// Borrow an `i8` buffer of exactly `len` elements with arbitrary
    /// (stale but initialized) contents — the int8 pack buffers are
    /// fully written (pad rows zeroed explicitly by the packer).
    pub fn take_i8(&mut self, len: usize) -> Vec<i8> {
        self.takes += 1;
        let (mut v, grew) = pool_take(&mut self.pool_i8, len);
        if grew {
            self.grows += 1;
        }
        v.resize(len, 0);
        v
    }

    /// Park an `i8` buffer for reuse.
    pub fn put_i8(&mut self, buf: Vec<i8>) {
        pool_put(&mut self.pool_i8, buf);
    }

    /// `take*` calls so far (reuse diagnostics for tests/benches).
    pub fn takes(&self) -> u64 {
        self.takes
    }

    /// `take*` calls that had to allocate or regrow (the cold path; a
    /// steady-state hot loop should stop advancing this counter).
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Total `f32` capacity currently parked in the pool.
    pub fn pooled_floats(&self) -> usize {
        self.pool.iter().map(|b| b.capacity()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_and_reuses_capacity() {
        let mut sc = Scratch::new();
        let mut a = sc.take(1024);
        assert_eq!(a.len(), 1024);
        assert!(a.iter().all(|&v| v == 0.0));
        a.iter_mut().for_each(|v| *v = 7.0);
        sc.put(a);
        let b = sc.take(512);
        assert_eq!(b.len(), 512);
        assert!(b.capacity() >= 1024, "parked buffer should be reused");
        assert!(b.iter().all(|&v| v == 0.0), "reused buffer must be re-zeroed");
        sc.put(b);
        assert_eq!(sc.takes(), 2);
        assert_eq!(sc.grows(), 1);
    }

    #[test]
    fn take_from_copies_without_zeroing() {
        let mut sc = Scratch::new();
        let src = [1.0f32, 2.0, 3.0];
        let v = sc.take_from(&src);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn take_any_has_exact_len_and_skips_zeroing() {
        let mut sc = Scratch::new();
        sc.put(vec![7.0f32; 100]);
        let v = sc.take_any(60);
        assert_eq!(v.len(), 60);
        assert_eq!(v[0], 7.0, "stale contents are allowed (and expected)");
        sc.put(v);
        let w = sc.take_any(200);
        assert_eq!(w.len(), 200);
        assert!(w[100..].iter().all(|&x| x == 0.0), "growth tail is zeroed");
    }

    #[test]
    fn i8_pool_reuses_and_stays_bounded() {
        let mut sc = Scratch::new();
        let mut a = sc.take_i8(256);
        assert_eq!(a.len(), 256);
        a.iter_mut().for_each(|v| *v = 7);
        sc.put_i8(a);
        let b = sc.take_i8(128);
        assert_eq!(b.len(), 128);
        assert!(b.capacity() >= 256, "parked i8 buffer should be reused");
        sc.put_i8(b);
        for i in 0..4 * MAX_POOLED {
            sc.put_i8(vec![0; i + 1]);
        }
        assert!(sc.pool_i8.len() <= MAX_POOLED);
    }

    #[test]
    fn pool_is_bounded() {
        let mut sc = Scratch::new();
        for i in 0..4 * MAX_POOLED {
            sc.put(vec![0.0; i + 1]);
        }
        assert!(sc.pool.len() <= MAX_POOLED);
        // the survivors are the big ones
        assert!(sc.pool.iter().all(|b| b.capacity() > MAX_POOLED));
    }
}
