//! CpuBackend — a pure-Rust interpreter for every module spec.
//!
//! This is the default execution backend: forward inference, the gy
//! gradient chain, Algorithm 1's back-end-first loop, training, and the
//! engine IPs all run on stock stable Rust with no Python artifacts and
//! no XLA. Kernels live in [`kernels`] (semantics of
//! `python/compile/kernels/ref.py`) on top of the tiled multi-threaded
//! GEMM core in [`gemm`]; per-segment interpreters live in the private
//! `segment` module.
//! Forward modules additionally accept per-channel int8 weights through
//! the mixed-precision [`ArgRef`] seam and execute them on the true
//! int8 GEMM core (the paper's §IV-A deployment mode); the gradient
//! chain stays f32.
//! Module bodies draw im2col panels, packed GEMM panels, and
//! activation/grad temporaries from the calling thread's
//! [`scratch::Scratch`] arena ([`scratch::with`]), so buffers are reused
//! across segments and steps instead of reallocated — and the compiled
//! modules themselves stay immutable `Send + Sync` data, shareable
//! across fleet workers. Every module validates arity and shapes
//! before touching data — an edge device fails loudly, never UB
//! (`tests/failure_injection`).

// Index-heavy numeric loops read better with explicit ranges.
#![allow(clippy::needless_range_loop)]

pub mod gemm;
pub mod kernels;
pub mod scratch;
mod segment;

use anyhow::{bail, Result};

use crate::config::{ModelMeta, SegmentMeta};
use crate::tensor::Tensor;

use super::{ArgRef, Backend, ModuleImpl, ModuleSpec};
use scratch::Scratch;
use segment::SegmentDef;

/// The interpreter backend. All module state is built at `compile` time
/// from the spec's inventory; mutable per-call state (the scratch
/// arena) is per *executing thread*, never per module, so everything
/// this backend builds is plain `Send + Sync` data.
#[derive(Debug, Default)]
pub struct CpuBackend;

impl CpuBackend {
    pub fn new() -> CpuBackend {
        CpuBackend::default()
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu-interpreter"
    }

    fn compile(&self, spec: &ModuleSpec) -> Result<Box<dyn ModuleImpl>> {
        Ok(match spec {
            ModuleSpec::SegmentFwd { meta, seg } => {
                let def = SegmentDef::from_meta(meta, *seg)?; // bounds-checks seg
                Box::new(SegmentFwdModule { seg: meta.segments[*seg].clone(), def })
            }
            ModuleSpec::SegmentBwd { meta, seg } => {
                let def = SegmentDef::from_meta(meta, *seg)?;
                Box::new(SegmentBwdModule { seg: meta.segments[*seg].clone(), def })
            }
            ModuleSpec::Logits { meta } => Box::new(LogitsModule::new(meta)?),
            ModuleSpec::TrainStep { meta } => Box::new(TrainStepModule {
                chain: LogitsModule::new(meta)?,
            }),
            ModuleSpec::LossGrad { meta } => Box::new(LossGradModule {
                classes: meta.num_classes,
            }),
            ModuleSpec::Fimd { shared } => Box::new(FimdModule { tile: shared.tile }),
            ModuleSpec::Dampen { shared } => Box::new(DampenModule { tile: shared.tile }),
            ModuleSpec::Gemm { .. } => Box::new(GemmModule),
        })
    }
}

// ---------------------------------------------------------------------------
// validation helpers
// ---------------------------------------------------------------------------

fn check_arity<T>(args: &[T], want: usize, what: &str) -> Result<()> {
    if args.len() != want {
        bail!("{what}: expected {want} arguments, got {}", args.len());
    }
    Ok(())
}

/// Check a batched tensor `[B, ...sample]`; returns B.
fn check_batched(t: &Tensor, sample: &[usize], what: &str) -> Result<usize> {
    if t.shape.len() != sample.len() + 1 || t.shape[1..] != *sample || t.shape[0] == 0 {
        bail!(
            "{what}: expected shape [B{}], got {:?}",
            sample.iter().map(|d| format!(", {d}")).collect::<String>(),
            t.shape
        );
    }
    Ok(t.shape[0])
}

fn check_params(seg: &SegmentMeta, args: &[&Tensor]) -> Result<()> {
    for (t, pm) in args.iter().zip(&seg.params) {
        if t.shape != pm.shape {
            bail!(
                "{}.{}: expected shape {:?}, got {:?}",
                seg.name,
                pm.name,
                pm.shape,
                t.shape
            );
        }
    }
    Ok(())
}

/// Mixed-precision parameter check: shapes as [`check_params`], plus a
/// per-output-channel scale count for int8 weight slots.
fn check_params_mixed(seg: &SegmentMeta, args: &[ArgRef]) -> Result<()> {
    for (a, pm) in args.iter().zip(&seg.params) {
        if a.shape() != pm.shape.as_slice() {
            bail!(
                "{}.{}: expected shape {:?}, got {:?}",
                seg.name,
                pm.name,
                pm.shape,
                a.shape()
            );
        }
        if let ArgRef::Quant(q) = a {
            let cols = pm.shape.last().copied().unwrap_or(0);
            if q.scales.len() != cols {
                bail!(
                    "{}.{}: int8 weight has {} scales for {} output channels",
                    seg.name,
                    pm.name,
                    q.scales.len(),
                    cols
                );
            }
        }
    }
    Ok(())
}

fn check_tile(t: &Tensor, tile: usize, what: &str) -> Result<()> {
    if t.shape != [tile] {
        bail!("{what}: expected shape [{tile}], got {:?}", t.shape);
    }
    Ok(())
}

fn check_scalarish(t: &Tensor, what: &str) -> Result<f32> {
    if t.len() != 1 {
        bail!("{what}: expected a scalar, got shape {:?}", t.shape);
    }
    Ok(t.data[0])
}

// ---------------------------------------------------------------------------
// segment modules
// ---------------------------------------------------------------------------

struct SegmentFwdModule {
    seg: SegmentMeta,
    def: SegmentDef,
}

impl ModuleImpl for SegmentFwdModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let margs: Vec<ArgRef> = args.iter().map(|&t| ArgRef::F32(t)).collect();
        self.run_mixed(&margs)
    }

    fn run_mixed(&self, args: &[ArgRef]) -> Result<Vec<Tensor>> {
        let np = self.seg.params.len();
        check_arity(args, np + 1, &format!("fwd[{}]", self.seg.name))?;
        check_params_mixed(&self.seg, &args[..np])?;
        let x = match args[np].f32() {
            Some(t) => t,
            None => bail!("fwd[{}]: x must be f32", self.seg.name),
        };
        check_batched(x, &self.seg.in_shape, "x")?;
        let y = scratch::with(|sc| self.def.fwd(&args[..np], x, sc))?;
        Ok(vec![y])
    }
}

struct SegmentBwdModule {
    seg: SegmentMeta,
    def: SegmentDef,
}

impl ModuleImpl for SegmentBwdModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let np = self.seg.params.len();
        check_arity(args, np + 2, &format!("bwd[{}]", self.seg.name))?;
        check_params(&self.seg, &args[..np])?;
        let b = check_batched(args[np], &self.seg.in_shape, "x")?;
        let b2 = check_batched(args[np + 1], &self.seg.out_shape, "gy")?;
        if b != b2 {
            bail!("bwd[{}]: x batch {b} != gy batch {b2}", self.seg.name);
        }
        let (mut grads, gx) =
            scratch::with(|sc| self.def.bwd(&args[..np], args[np], args[np + 1], sc))?;
        grads.push(gx);
        Ok(grads)
    }
}

// ---------------------------------------------------------------------------
// whole-model modules
// ---------------------------------------------------------------------------

/// Shared forward chain for `logits` and `train_step`.
struct LogitsModule {
    meta: ModelMeta,
    defs: Vec<SegmentDef>,
    param_count: usize,
}

impl LogitsModule {
    fn new(meta: &ModelMeta) -> Result<LogitsModule> {
        let defs = (0..meta.num_segments())
            .map(|k| SegmentDef::from_meta(meta, k))
            .collect::<Result<Vec<_>>>()?;
        let param_count = meta.segments.iter().map(|s| s.params.len()).sum();
        Ok(LogitsModule { meta: meta.clone(), defs, param_count })
    }

    fn check_all_params(&self, args: &[ArgRef]) -> Result<()> {
        let mut off = 0;
        for seg in &self.meta.segments {
            check_params_mixed(seg, &args[off..off + seg.params.len()])?;
            off += seg.params.len();
        }
        Ok(())
    }

    /// Forward through every segment; optionally cache segment inputs.
    fn forward(
        &self,
        args: &[ArgRef],
        x: &Tensor,
        mut cache: Option<&mut Vec<Tensor>>,
        sc: &mut Scratch,
    ) -> Result<Tensor> {
        let mut h = x.clone();
        let mut off = 0;
        for (seg, def) in self.meta.segments.iter().zip(&self.defs) {
            if let Some(c) = cache.as_mut() {
                c.push(h.clone());
            }
            h = def.fwd(&args[off..off + seg.params.len()], &h, sc)?;
            off += seg.params.len();
        }
        Ok(h)
    }
}

impl ModuleImpl for LogitsModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let margs: Vec<ArgRef> = args.iter().map(|&t| ArgRef::F32(t)).collect();
        self.run_mixed(&margs)
    }

    fn run_mixed(&self, args: &[ArgRef]) -> Result<Vec<Tensor>> {
        check_arity(args, self.param_count + 1, "logits")?;
        self.check_all_params(&args[..self.param_count])?;
        let x = match args[self.param_count].f32() {
            Some(t) => t,
            None => bail!("logits: x must be f32"),
        };
        check_batched(x, &self.meta.input_shape, "x")?;
        let logits = scratch::with(|sc| self.forward(&args[..self.param_count], x, None, sc))?;
        Ok(vec![logits])
    }
}

/// One SGD step: full forward (caching segment inputs), mean-NLL loss,
/// reverse-chain VJP, in-place parameter update.
struct TrainStepModule {
    chain: LogitsModule,
}

impl ModuleImpl for TrainStepModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let n = self.chain.param_count;
        let meta = &self.chain.meta;
        check_arity(args, n + 3, "train_step")?;
        let margs: Vec<ArgRef> = args[..n].iter().map(|&t| ArgRef::F32(t)).collect();
        self.chain.check_all_params(&margs)?;
        let x = args[n];
        let onehot = args[n + 1];
        let lr = check_scalarish(args[n + 2], "lr")?;
        let b = check_batched(x, &meta.input_shape, "x")?;
        check_batched(onehot, &[meta.num_classes], "onehot")?;
        if onehot.batch() != b {
            bail!("train_step: onehot batch {} != x batch {b}", onehot.batch());
        }

        scratch::with(|sc| {
        let mut inputs = Vec::with_capacity(meta.num_segments());
        let logits = self.chain.forward(&margs, x, Some(&mut inputs), sc)?;

        // mean NLL + dlogits via log-sum-exp (model.py cross_entropy)
        let classes = meta.num_classes;
        let mut loss = 0.0f32;
        let mut gy_data = vec![0.0f32; b * classes];
        for i in 0..b {
            let row = logits.row(i);
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
            let lse = m + z.ln();
            let orow = onehot.row(i);
            let dot: f32 = row.iter().zip(orow).map(|(l, o)| l * o).sum();
            loss += lse - dot;
            for c in 0..classes {
                gy_data[i * classes + c] = ((row[c] - lse).exp() - orow[c]) / b as f32;
            }
        }
        loss /= b as f32;

        // reverse-chain VJP + SGD update
        let mut gy = Tensor::new(vec![b, classes], gy_data)?;
        let mut new_params: Vec<Vec<Tensor>> = vec![Vec::new(); meta.num_segments()];
        let mut offsets = Vec::with_capacity(meta.num_segments());
        let mut off = 0;
        for seg in &meta.segments {
            offsets.push(off);
            off += seg.params.len();
        }
        for k in (0..meta.num_segments()).rev() {
            let np = meta.segments[k].params.len();
            let ps = &args[offsets[k]..offsets[k] + np];
            let (grads, gx) = self.chain.defs[k].bwd(ps, &inputs[k], &gy, sc)?;
            gy = gx;
            new_params[k] = ps
                .iter()
                .zip(&grads)
                .map(|(p, g)| {
                    let data = p.data.iter().zip(&g.data).map(|(pv, gv)| pv - lr * gv).collect();
                    Tensor { shape: p.shape.clone(), data }
                })
                .collect();
        }

        let mut out: Vec<Tensor> = new_params.into_iter().flatten().collect();
        out.push(Tensor::scalar(loss));
        Ok(out)
        })
    }
}

/// dlogits of the mean NLL: `(softmax(logits) - onehot) / B`.
struct LossGradModule {
    classes: usize,
}

impl ModuleImpl for LossGradModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        check_arity(args, 2, "loss_grad")?;
        let logits = args[0];
        let onehot = args[1];
        let b = check_batched(logits, &[self.classes], "logits")?;
        check_batched(onehot, &[self.classes], "onehot")?;
        if onehot.batch() != b {
            bail!("loss_grad: onehot batch {} != logits batch {b}", onehot.batch());
        }
        let probs = logits.softmax_rows();
        let data = probs
            .data
            .iter()
            .zip(&onehot.data)
            .map(|(p, o)| (p - o) / b as f32)
            .collect();
        Ok(vec![Tensor::new(logits.shape.clone(), data)?])
    }
}

// ---------------------------------------------------------------------------
// engine IP modules
// ---------------------------------------------------------------------------

/// FIMD tile update: `(grad, acc, scale) -> (acc + scale * grad^2,)`.
struct FimdModule {
    tile: usize,
}

impl ModuleImpl for FimdModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        check_arity(args, 3, "fimd")?;
        check_tile(args[0], self.tile, "grad")?;
        check_tile(args[1], self.tile, "acc")?;
        let scale = check_scalarish(args[2], "scale")?;
        let acc = kernels::fimd_update(&args[0].data, &args[1].data, scale);
        Ok(vec![Tensor::vec1(acc)])
    }
}

/// Dampening tile pass:
/// `(theta, idf, id, alpha, lam) -> (theta', mask)`.
struct DampenModule {
    tile: usize,
}

impl ModuleImpl for DampenModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        check_arity(args, 5, "dampen")?;
        check_tile(args[0], self.tile, "theta")?;
        check_tile(args[1], self.tile, "i_df")?;
        check_tile(args[2], self.tile, "i_d")?;
        let alpha = check_scalarish(args[3], "alpha")?;
        let lam = check_scalarish(args[4], "lambda")?;
        let (theta, mask) =
            kernels::dampen(&args[0].data, &args[1].data, &args[2].data, alpha, lam);
        Ok(vec![Tensor::vec1(theta), Tensor::vec1(mask)])
    }
}

/// Patch-GEMM engine demo: plain 2-D `x @ y` on the tiled core.
struct GemmModule;

impl ModuleImpl for GemmModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        check_arity(args, 2, "gemm")?;
        let (x, y) = (args[0], args[1]);
        if x.shape.len() != 2 || y.shape.len() != 2 || x.shape[1] != y.shape[0] {
            bail!("gemm: incompatible shapes {:?} x {:?}", x.shape, y.shape);
        }
        let (m, k, n) = (x.shape[0], x.shape[1], y.shape[1]);
        let mut out = vec![0.0f32; m * n];
        scratch::with(|sc| gemm::matmul_into(sc, &x.data, &y.data, m, k, n, &mut out));
        Ok(vec![Tensor::new(vec![m, n], out)?])
    }
}
