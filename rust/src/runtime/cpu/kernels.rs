//! Kernels for the CpuBackend.
//!
//! Semantics mirror the pure-jnp oracles in `python/compile/kernels/ref.py`
//! (GEMM, FIMD update, dampening, SAME conv) and the shared primitives in
//! `python/compile/model.py` (GroupNorm, LayerNorm, gelu, softmax).
//!
//! The GEMM family and the conv lowering now run on the tuned compute
//! core in [`super::gemm`]: cache-blocked panel packing, a register-tiled
//! micro-kernel, and row-panel multi-threading (`FICABU_THREADS`), with
//! conv patch extraction fused into the packing step so the im2col
//! matrix is never materialized. The forward path additionally has a
//! true-int8 lowering ([`matmul_i8_into`], [`Conv::fwd_i8_into`]):
//! per-channel int8 weights, activations quantized during packing, and
//! an i8 x i8 -> i32 micro-kernel with one requantization at the store.
//! The PR-1 triple-loop references are retained in [`naive`] as
//! correctness oracles and bench baselines.
//! Hot paths should use the `_into` variants together with a
//! [`Scratch`] arena; the `Vec`-returning forms are conveniences for
//! tests and one-shot callers.

// Index-heavy numeric loops read better with explicit ranges.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use crate::config::builtin::NORM_EPS;
use crate::tensor::quant::{self, QTensor};

use super::gemm;
use super::scratch::Scratch;

// ---------------------------------------------------------------------------
// GEMM family (ref_matmul) — tiled core, Vec conveniences
// ---------------------------------------------------------------------------

/// `a[m,k] @ b[k,n] -> [m,n]` (row-major, f32 accumulate).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    gemm::matmul_into(&mut Scratch::new(), a, b, m, k, n, &mut out);
    out
}

/// `a[r,m]^T @ b[r,n] -> [m,n]` — the grad-wrt-weights product.
pub fn matmul_tn(a: &[f32], b: &[f32], r: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    gemm::matmul_tn_into(&mut Scratch::new(), a, b, r, m, n, &mut out);
    out
}

/// `a[m,k] @ b[n,k]^T -> [m,n]` — the grad-wrt-inputs product.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    gemm::matmul_nt_into(&mut Scratch::new(), a, b, m, k, n, &mut out);
    out
}

/// True-int8 `out = x[m,k] @ wq[k,n]`: the activation is quantized per
/// tensor during panel packing, the weight arrives pre-quantized per
/// output channel, accumulation is i8 x i8 -> i32, and one
/// requantization happens at the store. Bitwise-deterministic across
/// thread counts (integer accumulation is order-free).
pub fn matmul_i8_into(
    scratch: &mut Scratch,
    x: &[f32],
    wq: &QTensor,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(wq.data.len(), k * n);
    debug_assert_eq!(wq.scales.len(), n);
    let a_scale = quant::scale_for(x);
    gemm::gemm_i8(
        scratch,
        &gemm::QuantStrided { data: x, rs: k, cs: 1, inv_scale: 1.0 / a_scale },
        &gemm::QStrided { data: &wq.data, rs: n, cs: 1 },
        a_scale,
        &wq.scales,
        m,
        k,
        n,
        out,
    );
}

/// Add a `[cols]` bias to every row of a `[rows, cols]` buffer in place.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums of a `[rows, cols]` buffer — the grad-wrt-bias reduction.
pub fn col_sum(x: &[f32], cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cols];
    for row in x.chunks_exact(cols) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// SAME conv, NHWC/HWIO (ref_conv2d) — im2col fused into GEMM packing
// ---------------------------------------------------------------------------

/// Static conv geometry: kernel `[kh, kw, cin, cout]`, SAME padding
/// `kh/2`, square stride.
#[derive(Debug, Clone, Copy)]
pub struct Conv {
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
}

impl Conv {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        let (ph, pw) = (self.kh / 2, self.kw / 2);
        (
            (h + 2 * ph - self.kh) / self.stride + 1,
            (w + 2 * pw - self.kw) / self.stride + 1,
        )
    }

    /// Forward conv into `y[b,ho,wo,cout]`. Patch rows are extracted
    /// during GEMM panel packing — the `[b*ho*wo, kh*kw*cin]` im2col
    /// matrix is never materialized.
    pub fn fwd_into(
        &self,
        scratch: &mut Scratch,
        x: &[f32],
        wk: &[f32],
        b: usize,
        h: usize,
        w: usize,
        y: &mut [f32],
    ) {
        let (ho, wo) = self.out_hw(h, w);
        let kk = self.kh * self.kw * self.cin;
        debug_assert_eq!(x.len(), b * h * w * self.cin);
        debug_assert_eq!(wk.len(), kk * self.cout);
        gemm::gemm(
            scratch,
            &gemm::Im2col { x, conv: *self, batch: b, h, w },
            &gemm::Strided { data: wk, rs: self.cout, cs: 1 },
            b * ho * wo,
            kk,
            self.cout,
            y,
        );
    }

    /// Forward conv: `y[b,ho,wo,cout]` (allocating convenience).
    pub fn fwd(&self, x: &[f32], wk: &[f32], b: usize, h: usize, w: usize) -> Vec<f32> {
        let (ho, wo) = self.out_hw(h, w);
        let mut y = vec![0.0f32; b * ho * wo * self.cout];
        self.fwd_into(&mut Scratch::new(), x, wk, b, h, w, &mut y);
        y
    }

    /// True-int8 forward conv: the HWIO weight arrives pre-quantized per
    /// output channel, image patches are quantized with the image's
    /// per-tensor scale *during* fused im2col packing — the int8 patch
    /// matrix is never materialized either.
    pub fn fwd_i8_into(
        &self,
        scratch: &mut Scratch,
        x: &[f32],
        wq: &QTensor,
        b: usize,
        h: usize,
        w: usize,
        y: &mut [f32],
    ) {
        let (ho, wo) = self.out_hw(h, w);
        let kk = self.kh * self.kw * self.cin;
        debug_assert_eq!(x.len(), b * h * w * self.cin);
        debug_assert_eq!(wq.data.len(), kk * self.cout);
        debug_assert_eq!(wq.scales.len(), self.cout);
        let a_scale = quant::scale_for(x);
        gemm::gemm_i8(
            scratch,
            &gemm::Im2colQ { x, conv: *self, batch: b, h, w, inv_scale: 1.0 / a_scale },
            &gemm::QStrided { data: &wq.data, rs: self.cout, cs: 1 },
            a_scale,
            &wq.scales,
            b * ho * wo,
            kk,
            self.cout,
            y,
        );
    }

    /// VJP into `dx[b,h,w,cin]` and `dw[kh,kw,cin,cout]` for output
    /// grads `gy[b,ho,wo,cout]`. The weight-grad GEMM reads its patch
    /// operand straight from the image (fused packing); only the
    /// patch-grad matrix for the col2im scatter is staged in scratch.
    pub fn bwd_into(
        &self,
        scratch: &mut Scratch,
        x: &[f32],
        wk: &[f32],
        gy: &[f32],
        b: usize,
        h: usize,
        w: usize,
        dx: &mut [f32],
        dw: &mut [f32],
    ) {
        let (ho, wo) = self.out_hw(h, w);
        let rows = b * ho * wo;
        let kk = self.kh * self.kw * self.cin;
        debug_assert_eq!(gy.len(), rows * self.cout);
        // dW = colsᵀ @ gy
        gemm::gemm(
            scratch,
            &gemm::Im2colT { x, conv: *self, batch: b, h, w },
            &gemm::Strided { data: gy, rs: self.cout, cs: 1 },
            kk,
            rows,
            self.cout,
            dw,
        );
        // dcols = gy @ wkᵀ, then scatter-add back onto the image
        let mut dcols = scratch.take_any(rows * kk);
        gemm::gemm(
            scratch,
            &gemm::Strided { data: gy, rs: self.cout, cs: 1 },
            &gemm::Strided { data: wk, rs: 1, cs: self.cout },
            rows,
            self.cout,
            kk,
            &mut dcols,
        );
        self.col2im_into(&dcols, b, h, w, dx);
        scratch.put(dcols);
    }

    /// VJP: returns `(dx, dw)` (allocating convenience).
    pub fn bwd(
        &self,
        x: &[f32],
        wk: &[f32],
        gy: &[f32],
        b: usize,
        h: usize,
        w: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut dx = vec![0.0f32; b * h * w * self.cin];
        let mut dw = vec![0.0f32; self.kh * self.kw * self.cin * self.cout];
        self.bwd_into(&mut Scratch::new(), x, wk, gy, b, h, w, &mut dx, &mut dw);
        (dx, dw)
    }

    /// Scatter-add of patch-row grads back onto the input image
    /// (`dx` is fully overwritten).
    fn col2im_into(&self, dcols: &[f32], b: usize, h: usize, w: usize, dx: &mut [f32]) {
        let (ho, wo) = self.out_hw(h, w);
        let (ph, pw) = (self.kh / 2, self.kw / 2);
        let kk = self.kh * self.kw * self.cin;
        dx.fill(0.0);
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * kk;
                    for ky in 0..self.kh {
                        let iy = (oy * self.stride + ky) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..self.kw {
                            let ix = (ox * self.stride + kx) as isize - pw as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let src = ((bi * h + iy as usize) * w + ix as usize) * self.cin;
                            let dst = row + (ky * self.kw + kx) * self.cin;
                            for c in 0..self.cin {
                                dx[src + c] += dcols[dst + c];
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PR-1 reference loops — oracles + bench baselines
// ---------------------------------------------------------------------------

/// The PR-1 triple-loop reference kernels, kept as correctness oracles
/// for the tiled core (property tests in `tests/gemm_tiled.rs`) and as
/// the measured baseline in `benches/bench_runtime.rs`.
///
/// Branch-free: the old `if av != 0.0` skip in the dense inner loops
/// pessimized dense panels (a data-dependent branch per k step) and the
/// tiled kernel makes it obsolete. No current GEMM operand is provably
/// sparse — the dampening masks never feed a matmul — so no sparsity
/// skipping survives anywhere.
pub mod naive {
    use super::Conv;

    /// `a[m,k] @ b[k,n] -> [m,n]`, axpy-ordered triple loop.
    pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// `a[r,m]^T @ b[r,n] -> [m,n]`.
    pub fn matmul_tn(a: &[f32], b: &[f32], r: usize, m: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), r * m);
        debug_assert_eq!(b.len(), r * n);
        let mut out = vec![0.0f32; m * n];
        for p in 0..r {
            let arow = &a[p * m..(p + 1) * m];
            let brow = &b[p * n..(p + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// `a[m,k] @ b[n,k]^T -> [m,n]`.
    pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
        out
    }

    /// Scalar int8 oracle: quantize -> integer accumulate -> requantize,
    /// the exact arithmetic contract of the tiled int8 core. Integer
    /// accumulation is order-free and the quantization/requantization
    /// expressions are shared (`quant::q8`, `acc * (a_scale * w_scale)`),
    /// so the tiled path must match this oracle **bitwise**.
    pub fn matmul_i8(
        x: &[f32],
        wq: &[i8],
        w_scales: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        debug_assert_eq!(x.len(), m * k);
        debug_assert_eq!(wq.len(), k * n);
        debug_assert_eq!(w_scales.len(), n);
        let a_scale = crate::tensor::quant::scale_for(x);
        let inv = 1.0 / a_scale;
        let xq: Vec<i8> = x.iter().map(|&v| crate::tensor::quant::q8(v, inv)).collect();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0i32;
                for p in 0..k {
                    acc += xq[i * k + p] as i32 * wq[p * n + j] as i32;
                }
                out[i * n + j] = acc as f32 * (a_scale * w_scales[j]);
            }
        }
        out
    }

    /// Int8 conv oracle through a materialized im2col matrix. The
    /// activation scale comes from the *image* (like the fused path),
    /// not from the patch matrix — padding zeros and stride-skipped
    /// pixels must not change the quantization grid.
    pub fn conv_fwd_i8(
        cv: &Conv,
        x: &[f32],
        wq: &[i8],
        w_scales: &[f32],
        b: usize,
        h: usize,
        w: usize,
    ) -> Vec<f32> {
        let (ho, wo) = cv.out_hw(h, w);
        let rows = b * ho * wo;
        let kk = cv.kh * cv.kw * cv.cin;
        let a_scale = crate::tensor::quant::scale_for(x);
        let inv = 1.0 / a_scale;
        let cols = im2col(cv, x, b, h, w);
        let colsq: Vec<i8> = cols.iter().map(|&v| crate::tensor::quant::q8(v, inv)).collect();
        let mut out = vec![0.0f32; rows * cv.cout];
        for i in 0..rows {
            for j in 0..cv.cout {
                let mut acc = 0i32;
                for p in 0..kk {
                    acc += colsq[i * kk + p] as i32 * wq[p * cv.cout + j] as i32;
                }
                out[i * cv.cout + j] = acc as f32 * (a_scale * w_scales[j]);
            }
        }
        out
    }

    /// Materialize `x[b,h,w,cin]` into patch rows `[b*ho*wo, kh*kw*cin]`.
    pub fn im2col(cv: &Conv, x: &[f32], b: usize, h: usize, w: usize) -> Vec<f32> {
        let (ho, wo) = cv.out_hw(h, w);
        let (ph, pw) = (cv.kh / 2, cv.kw / 2);
        let kk = cv.kh * cv.kw * cv.cin;
        let mut cols = vec![0.0f32; b * ho * wo * kk];
        for bi in 0..b {
            for oy in 0..ho {
                for ox in 0..wo {
                    let row = ((bi * ho + oy) * wo + ox) * kk;
                    for ky in 0..cv.kh {
                        let iy = (oy * cv.stride + ky) as isize - ph as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..cv.kw {
                            let ix = (ox * cv.stride + kx) as isize - pw as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let src = ((bi * h + iy as usize) * w + ix as usize) * cv.cin;
                            let dst = row + (ky * cv.kw + kx) * cv.cin;
                            cols[dst..dst + cv.cin].copy_from_slice(&x[src..src + cv.cin]);
                        }
                    }
                }
            }
        }
        cols
    }

    /// Forward conv through a materialized im2col matrix.
    pub fn conv_fwd(cv: &Conv, x: &[f32], wk: &[f32], b: usize, h: usize, w: usize) -> Vec<f32> {
        let (ho, wo) = cv.out_hw(h, w);
        let cols = im2col(cv, x, b, h, w);
        matmul(&cols, wk, b * ho * wo, cv.kh * cv.kw * cv.cin, cv.cout)
    }

    /// Conv VJP `(dx, dw)` through a materialized im2col matrix.
    pub fn conv_bwd(
        cv: &Conv,
        x: &[f32],
        wk: &[f32],
        gy: &[f32],
        b: usize,
        h: usize,
        w: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (ho, wo) = cv.out_hw(h, w);
        let rows = b * ho * wo;
        let kk = cv.kh * cv.kw * cv.cin;
        let cols = im2col(cv, x, b, h, w);
        let dw = matmul_tn(&cols, gy, rows, kk, cv.cout);
        let dcols = matmul_nt(gy, wk, rows, cv.cout, kk);
        let mut dx = vec![0.0f32; b * h * w * cv.cin];
        cv.col2im_into(&dcols, b, h, w, &mut dx);
        (dx, dw)
    }
}

// ---------------------------------------------------------------------------
// Normalization (model.py group_norm / layer_norm)
// ---------------------------------------------------------------------------

/// GroupNorm over `[b, hw, c]` with `g = min(groups, c)` channel groups
/// into a caller-provided (zeroed) `y`: residual channels beyond `g *
/// (c/g)` are left untouched, matching the allocating form.
pub fn group_norm_fwd_into(
    x: &[f32],
    b: usize,
    hw: usize,
    c: usize,
    groups: usize,
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), x.len());
    let g = groups.min(c);
    let cg = c / g;
    let m = (hw * cg) as f32;
    for bi in 0..b {
        for gi in 0..g {
            let (mu, inv) = group_stats(x, bi, gi, hw, c, cg, m);
            for s in 0..hw {
                let base = (bi * hw + s) * c + gi * cg;
                for j in 0..cg {
                    let ch = gi * cg + j;
                    let xn = (x[base + j] - mu) * inv;
                    y[base + j] = xn * gamma[ch] + beta[ch];
                }
            }
        }
    }
}

/// GroupNorm forward (allocating convenience).
pub fn group_norm_fwd(
    x: &[f32],
    b: usize,
    hw: usize,
    c: usize,
    groups: usize,
    gamma: &[f32],
    beta: &[f32],
) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    group_norm_fwd_into(x, b, hw, c, groups, gamma, beta, &mut y);
    y
}

fn group_stats(
    x: &[f32],
    bi: usize,
    gi: usize,
    hw: usize,
    c: usize,
    cg: usize,
    m: f32,
) -> (f32, f32) {
    let mut sum = 0.0f32;
    for s in 0..hw {
        let base = (bi * hw + s) * c + gi * cg;
        for j in 0..cg {
            sum += x[base + j];
        }
    }
    let mu = sum / m;
    let mut var = 0.0f32;
    for s in 0..hw {
        let base = (bi * hw + s) * c + gi * cg;
        for j in 0..cg {
            let d = x[base + j] - mu;
            var += d * d;
        }
    }
    (mu, 1.0 / (var / m + NORM_EPS).sqrt())
}

/// GroupNorm VJP into a caller-provided (zeroed) `dx`; returns
/// `(dgamma, dbeta)`.
pub fn group_norm_bwd_into(
    x: &[f32],
    b: usize,
    hw: usize,
    c: usize,
    groups: usize,
    gamma: &[f32],
    gy: &[f32],
    dx: &mut [f32],
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dx.len(), x.len());
    let g = groups.min(c);
    let cg = c / g;
    let m = (hw * cg) as f32;
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    for bi in 0..b {
        for gi in 0..g {
            let (mu, inv) = group_stats(x, bi, gi, hw, c, cg, m);
            // reductions over the normalization set
            let mut s1 = 0.0f32; // sum dxn
            let mut s2 = 0.0f32; // sum dxn * xn
            for s in 0..hw {
                let base = (bi * hw + s) * c + gi * cg;
                for j in 0..cg {
                    let ch = gi * cg + j;
                    let xn = (x[base + j] - mu) * inv;
                    let dxn = gy[base + j] * gamma[ch];
                    s1 += dxn;
                    s2 += dxn * xn;
                    dgamma[ch] += gy[base + j] * xn;
                    dbeta[ch] += gy[base + j];
                }
            }
            for s in 0..hw {
                let base = (bi * hw + s) * c + gi * cg;
                for j in 0..cg {
                    let ch = gi * cg + j;
                    let xn = (x[base + j] - mu) * inv;
                    let dxn = gy[base + j] * gamma[ch];
                    dx[base + j] = inv * (dxn - s1 / m - xn * s2 / m);
                }
            }
        }
    }
    (dgamma, dbeta)
}

/// GroupNorm VJP: `(dx, dgamma, dbeta)` (allocating convenience).
pub fn group_norm_bwd(
    x: &[f32],
    b: usize,
    hw: usize,
    c: usize,
    groups: usize,
    gamma: &[f32],
    gy: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let (dgamma, dbeta) = group_norm_bwd_into(x, b, hw, c, groups, gamma, gy, &mut dx);
    (dx, dgamma, dbeta)
}

/// LayerNorm over the last dim of `[rows, d]` into `y` (fully written).
pub fn layer_norm_fwd_into(
    x: &[f32],
    rows: usize,
    d: usize,
    gamma: &[f32],
    beta: &[f32],
    y: &mut [f32],
) {
    debug_assert_eq!(y.len(), x.len());
    for i in 0..rows {
        let r = &x[i * d..(i + 1) * d];
        let (mu, inv) = row_stats(r);
        let o = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            o[j] = (r[j] - mu) * inv * gamma[j] + beta[j];
        }
    }
}

/// LayerNorm forward (allocating convenience).
pub fn layer_norm_fwd(x: &[f32], rows: usize, d: usize, gamma: &[f32], beta: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    layer_norm_fwd_into(x, rows, d, gamma, beta, &mut y);
    y
}

fn row_stats(r: &[f32]) -> (f32, f32) {
    let d = r.len() as f32;
    let mu = r.iter().sum::<f32>() / d;
    let var = r.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d;
    (mu, 1.0 / (var + NORM_EPS).sqrt())
}

/// LayerNorm VJP into `dx` (fully written); returns `(dgamma, dbeta)`.
pub fn layer_norm_bwd_into(
    x: &[f32],
    rows: usize,
    d: usize,
    gamma: &[f32],
    gy: &[f32],
    dx: &mut [f32],
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dx.len(), x.len());
    let m = d as f32;
    let mut dgamma = vec![0.0f32; d];
    let mut dbeta = vec![0.0f32; d];
    for i in 0..rows {
        let r = &x[i * d..(i + 1) * d];
        let gr = &gy[i * d..(i + 1) * d];
        let (mu, inv) = row_stats(r);
        let mut s1 = 0.0f32;
        let mut s2 = 0.0f32;
        for j in 0..d {
            let xn = (r[j] - mu) * inv;
            let dxn = gr[j] * gamma[j];
            s1 += dxn;
            s2 += dxn * xn;
            dgamma[j] += gr[j] * xn;
            dbeta[j] += gr[j];
        }
        let o = &mut dx[i * d..(i + 1) * d];
        for j in 0..d {
            let xn = (r[j] - mu) * inv;
            let dxn = gr[j] * gamma[j];
            o[j] = inv * (dxn - s1 / m - xn * s2 / m);
        }
    }
    (dgamma, dbeta)
}

/// LayerNorm VJP: `(dx, dgamma, dbeta)` (allocating convenience).
pub fn layer_norm_bwd(
    x: &[f32],
    rows: usize,
    d: usize,
    gamma: &[f32],
    gy: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0f32; x.len()];
    let (dgamma, dbeta) = layer_norm_bwd_into(x, rows, d, gamma, gy, &mut dx);
    (dx, dgamma, dbeta)
}

// ---------------------------------------------------------------------------
// Activations
// ---------------------------------------------------------------------------

pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// `g *= (pre > 0)` — relu VJP against the pre-activation values.
pub fn relu_bwd(pre: &[f32], g: &mut [f32]) {
    for (gv, &p) in g.iter_mut().zip(pre) {
        if p <= 0.0 {
            *gv = 0.0;
        }
    }
}

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_A: f32 = 0.044_715;

#[inline]
fn gelu_scalar(v: f32) -> f32 {
    let u = GELU_C * (v + GELU_A * v * v * v);
    0.5 * v * (1.0 + u.tanh())
}

/// Tanh-approximate gelu (jax.nn.gelu default) into `out`.
pub fn gelu_into(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = gelu_scalar(v);
    }
}

/// Tanh-approximate gelu in place.
pub fn gelu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = gelu_scalar(*v);
    }
}

/// Tanh-approximate gelu (allocating convenience).
pub fn gelu(x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    gelu_into(x, &mut out);
    out
}

/// Gelu VJP in place: `g *= gelu'(x)`.
pub fn gelu_bwd_inplace(x: &[f32], g: &mut [f32]) {
    for (gv, &v) in g.iter_mut().zip(x) {
        let u = GELU_C * (v + GELU_A * v * v * v);
        let t = u.tanh();
        let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
        *gv *= 0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du;
    }
}

/// Gelu VJP: `g * gelu'(x)` (allocating convenience).
pub fn gelu_bwd(x: &[f32], g: &[f32]) -> Vec<f32> {
    let mut out = g.to_vec();
    gelu_bwd_inplace(x, &mut out);
    out
}

/// Row-wise softmax in place over `[rows, cols]`.
pub fn softmax_rows(x: &mut [f32], cols: usize) {
    for row in x.chunks_exact_mut(cols) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
}

/// Softmax VJP per row into `out`: `ds = s * (g - <g, s>)`.
pub fn softmax_bwd_into(s: &[f32], g: &[f32], cols: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), s.len());
    for ((srow, grow), orow) in s
        .chunks_exact(cols)
        .zip(g.chunks_exact(cols))
        .zip(out.chunks_exact_mut(cols))
    {
        let dot: f32 = srow.iter().zip(grow).map(|(&sv, &gv)| sv * gv).sum();
        for ((o, &sv), &gv) in orow.iter_mut().zip(srow).zip(grow) {
            *o = sv * (gv - dot);
        }
    }
}

/// Softmax VJP per row (allocating convenience).
pub fn softmax_bwd(s: &[f32], g: &[f32], cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; s.len()];
    softmax_bwd_into(s, g, cols, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Engine IP kernels (ref_fimd_update / ref_dampen)
// ---------------------------------------------------------------------------

/// `acc + scale * grad^2` elementwise — eq. (2) accumulation.
pub fn fimd_update(grad: &[f32], acc: &[f32], scale: f32) -> Vec<f32> {
    grad.iter()
        .zip(acc)
        .map(|(&g, &a)| a + scale * g * g)
        .collect()
}

/// Selection + beta + update — eq. (3)/(4). Returns `(theta', mask)`.
/// The selection branch is inherent to the semantics (and the mask is
/// the only provably sparse signal here — it never feeds a GEMM).
pub fn dampen(
    theta: &[f32],
    i_df: &[f32],
    i_d: &[f32],
    alpha: f32,
    lambda: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut out = Vec::with_capacity(theta.len());
    let mut mask = Vec::with_capacity(theta.len());
    for i in 0..theta.len() {
        let sel = i_df[i] > alpha * i_d[i];
        if sel {
            let beta = (lambda * i_d[i] / i_df[i].max(1e-30)).min(1.0);
            out.push(beta * theta[i]);
            mask.push(1.0);
        } else {
            out.push(theta[i]);
            mask.push(0.0);
        }
    }
    (out, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_exact() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let y = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_variants_agree() {
        let a = [1.0f32, -2.0, 3.0, 0.5, 4.0, -1.0]; // [2,3]
        let b = [2.0f32, 1.0, 0.0, -1.0, 1.5, 2.0]; // [3,2]
        let y = matmul(&a, &b, 2, 3, 2);
        // a^T laid out as [3,2], use tn with r=3? compare via transpose:
        let at = [1.0f32, 0.5, -2.0, 4.0, 3.0, -1.0]; // [3,2] = a^T
        let y_tn = matmul_tn(&at, &b, 3, 2, 2);
        assert_eq!(y, y_tn);
        let bt = [2.0f32, 0.0, 1.5, 1.0, -1.0, 2.0]; // [2,3] = b^T
        let y_nt = matmul_nt(&a, &bt, 2, 3, 2);
        assert_eq!(y, y_nt);
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights is a channel mix: cin=cout=1, w=[2]
        let cv = Conv { kh: 1, kw: 1, cin: 1, cout: 1, stride: 1 };
        let x = [1.0f32, 2.0, 3.0, 4.0]; // [1,2,2,1]
        let y = cv.fwd(&x, &[2.0], 1, 2, 2);
        assert_eq!(y, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn conv_same_padding_3x3() {
        // all-ones 3x3 kernel on a 3x3 ones image: interior 9, edges 6, corners 4
        let cv = Conv { kh: 3, kw: 3, cin: 1, cout: 1, stride: 1 };
        let x = [1.0f32; 9];
        let w = [1.0f32; 9];
        let y = cv.fwd(&x, &w, 1, 3, 3);
        assert_eq!(y[4], 9.0); // center
        assert_eq!(y[0], 4.0); // corner
        assert_eq!(y[1], 6.0); // edge
    }

    #[test]
    fn conv_stride_two_dims() {
        let cv = Conv { kh: 3, kw: 3, cin: 2, cout: 3, stride: 2 };
        assert_eq!(cv.out_hw(32, 32), (16, 16));
        let cv1 = Conv { kh: 1, kw: 1, cin: 2, cout: 3, stride: 2 };
        assert_eq!(cv1.out_hw(32, 32), (16, 16));
    }

    #[test]
    fn group_norm_normalizes() {
        // b=1, hw=4, c=4, groups=2 -> per-group mean 0 / var 1 pre-affine
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let gamma = vec![1.0f32; 4];
        let beta = vec![0.0f32; 4];
        let y = group_norm_fwd(&x, 1, 4, 4, 2, &gamma, &beta);
        // group 0 = channels {0,1}: mean of its 8 values must map to ~0
        let g0: f32 = (0..4).flat_map(|s| [y[s * 4], y[s * 4 + 1]]).sum();
        assert!(g0.abs() < 1e-4, "group mean {g0}");
        let v0: f32 = (0..4)
            .flat_map(|s| [y[s * 4], y[s * 4 + 1]])
            .map(|v| v * v)
            .sum::<f32>()
            / 8.0;
        assert!((v0 - 1.0).abs() < 1e-3, "group var {v0}");
    }

    #[test]
    fn layer_norm_rows_standardized() {
        let x = [1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let gamma = vec![1.0f32; 4];
        let beta = vec![0.0f32; 4];
        let y = layer_norm_fwd(&x, 2, 4, &gamma, &beta);
        for r in y.chunks_exact(4) {
            let mu: f32 = r.iter().sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_probabilities() {
        let mut x = vec![1.0f32, 2.0, 3.0, 0.0, 0.0, 0.0];
        softmax_rows(&mut x, 3);
        for r in x.chunks_exact(3) {
            let s: f32 = r.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!((x[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_bwd_orthogonal_to_ones() {
        // rows of ds sum to zero (softmax is shift invariant)
        let mut s = vec![0.2f32, 0.5, 0.3];
        softmax_rows(&mut s, 3); // make it an actual softmax output
        let ds = softmax_bwd(&s, &[0.7, -0.3, 1.1], 3);
        let sum: f32 = ds.iter().sum();
        assert!(sum.abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        let y = gelu(&[0.0, 1.0, -1.0]);
        assert_eq!(y[0], 0.0);
        assert!((y[1] - 0.841_192).abs() < 1e-4);
        assert!((y[2] + 0.158_808).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_finite_difference() {
        let xs = [-2.0f32, -0.5, 0.0, 0.3, 1.7];
        let g = gelu_bwd(&xs, &[1.0; 5]);
        let eps = 1e-3f32;
        for (i, &x) in xs.iter().enumerate() {
            let hi = gelu(&[x + eps])[0];
            let lo = gelu(&[x - eps])[0];
            let fd = (hi - lo) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 1e-3, "x={x}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn fimd_matches_ref() {
        let acc = fimd_update(&[1.0, -2.0, 3.0], &[0.5, 0.5, 0.5], 0.25);
        assert_eq!(acc, vec![0.75, 1.5, 2.75]);
    }

    #[test]
    fn dampen_matches_ref() {
        // ref_dampen: sel = idf > alpha*id; beta = min(lam*id/max(idf,1e-30), 1)
        let (t, m) = dampen(&[4.0, 4.0, 4.0], &[20.0, 0.5, 0.0], &[1.0, 1.0, 1.0], 10.0, 1.0);
        assert_eq!(m, vec![1.0, 0.0, 0.0]);
        assert!((t[0] - 0.2).abs() < 1e-6);
        assert_eq!(t[1], 4.0);
    }
}
