//! Backend-agnostic execution runtime.
//!
//! The coordinator never names a compute library: it asks the [`Runtime`]
//! for the module described by a [`ModuleSpec`] (a segment forward, the
//! fused logits graph, the FIMD engine tile, ...) and receives an opaque
//! [`Executable`] handle with positional-argument semantics matching the
//! AOT export contract (`params..., x[, gy]`; outputs in export order).
//!
//! Two backends implement the seam today:
//!
//! * [`cpu::CpuBackend`] (default) — a pure-Rust interpreter with
//!   reference GEMM / conv / FIMD / dampening kernels matching
//!   `python/compile/kernels/ref.py`. No artifacts, no Python, no XLA.
//! * `xla::XlaBackend` (`backend-xla` feature) — the original PJRT path:
//!   loads the HLO-text artifacts produced by `make artifacts`, compiles
//!   once, executes many. Builds offline against the vendored API stub;
//!   runtime execution needs the real `xla` bindings.
//!
//! Later GPU/NPU/hwsim-in-the-loop backends plug into the same trait.
//!
//! Executables are cached by spec key, so the per-layer unlearning loop
//! pays module construction once per process — mirroring the
//! compile-once/execute-many discipline of the PJRT path.

pub mod cpu;
#[cfg(feature = "backend-xla")]
pub mod xla;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::config::{ModelMeta, SharedMeta};
use crate::tensor::quant::QTensor;
use crate::tensor::Tensor;

/// Numeric precision a forward pass executes in. `F32` is the reference
/// path; `Int8` is the paper's deployment mode (§IV-A): weights stored
/// as per-channel int8, GEMM streaming in i8 x i8 -> i32, gradients and
/// engine IPs in f32 over dequantized bursts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    /// Element size in bytes (drives the hwsim DDR traffic model).
    pub fn bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Int8 => 1,
        }
    }

    /// Canonical wire name, used by the registry's `GET /models` payload
    /// and the audit subsystem's attestation records.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// Positional module argument: an f32 host tensor, or a pre-quantized
/// int8 weight with per-output-channel scales. Quantized arguments only
/// appear in *forward* positions of backends that execute true int8
/// GEMM; every other module keeps the all-f32 [`ModuleImpl::run`]
/// contract.
#[derive(Clone, Copy)]
pub enum ArgRef<'a> {
    F32(&'a Tensor),
    Quant(&'a QTensor),
}

impl<'a> ArgRef<'a> {
    pub fn shape(&self) -> &[usize] {
        match self {
            ArgRef::F32(t) => &t.shape,
            ArgRef::Quant(q) => &q.shape,
        }
    }

    /// The f32 tensor, or `None` for a quantized argument.
    pub fn f32(&self) -> Option<&'a Tensor> {
        match *self {
            ArgRef::F32(t) => Some(t),
            ArgRef::Quant(_) => None,
        }
    }
}

/// Aggregate compile/run statistics.
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compiles: u64,
    pub compile_ms: f64,
    pub runs: u64,
    pub run_ms: f64,
}

/// What computation a module performs — the backend-agnostic module
/// identity. Model-graph modules carry the full inventory so a backend
/// can either map them to artifact files (XLA) or build an interpreter
/// (CPU) without further context.
#[derive(Clone)]
pub enum ModuleSpec {
    /// Segment k forward: `(params_k..., x) -> (y,)`.
    SegmentFwd { meta: ModelMeta, seg: usize },
    /// Segment k VJP: `(params_k..., x, gy) -> (grads_k..., gx)`.
    SegmentBwd { meta: ModelMeta, seg: usize },
    /// Whole-model forward: `(all params..., x) -> (logits,)`.
    Logits { meta: ModelMeta },
    /// One SGD step: `(all params..., x, onehot, lr) -> (params'..., loss)`.
    TrainStep { meta: ModelMeta },
    /// dlogits of the mean NLL: `(logits, onehot) -> (dlogits,)`.
    LossGrad { meta: ModelMeta },
    /// FIMD IP tile update: `(grad, acc, scale) -> (acc',)`.
    Fimd { shared: SharedMeta },
    /// Dampening IP tile pass:
    /// `(theta, idf, id, alpha, lam) -> (theta', mask)`.
    Dampen { shared: SharedMeta },
    /// Patch-GEMM engine demo: `(x, y) -> (x @ y,)`.
    Gemm { shared: SharedMeta },
}

/// Structural fingerprint of a model inventory. Cache keys must reflect
/// the *content* of the spec, not just the model name: two inventories
/// sharing a name (e.g. a builtin and a differently-exported artifact
/// meta) would otherwise alias in the executable cache and silently run
/// each other's modules. Also the `spec_key` identity the model
/// registry lists per tenant (`GET /models`).
pub fn meta_fingerprint(meta: &ModelMeta) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    meta.dir.hash(&mut h);
    meta.name.hash(&mut h);
    meta.num_classes.hash(&mut h);
    meta.input_shape.hash(&mut h);
    meta.batch.hash(&mut h);
    meta.microbatch.hash(&mut h);
    meta.heads.hash(&mut h);
    for s in &meta.segments {
        s.name.hash(&mut h);
        s.kind.hash(&mut h);
        s.in_shape.hash(&mut h);
        s.out_shape.hash(&mut h);
        for p in &s.params {
            p.name.hash(&mut h);
            p.shape.hash(&mut h);
        }
    }
    h.finish()
}

impl ModuleSpec {
    /// Cache key — stable across identical specs, distinct across
    /// inventories that merely share a model name.
    pub fn key(&self) -> String {
        let model = |meta: &ModelMeta| format!("{}-{:016x}", meta.name, meta_fingerprint(meta));
        match self {
            ModuleSpec::SegmentFwd { meta, seg } => {
                format!("model/{}/fwd/{seg}", model(meta))
            }
            ModuleSpec::SegmentBwd { meta, seg } => {
                format!("model/{}/bwd/{seg}", model(meta))
            }
            ModuleSpec::Logits { meta } => format!("model/{}/logits", model(meta)),
            ModuleSpec::TrainStep { meta } => format!("model/{}/train_step", model(meta)),
            ModuleSpec::LossGrad { meta } => format!("model/{}/loss_grad", model(meta)),
            ModuleSpec::Fimd { shared } => {
                format!("shared/fimd/{}/{}", shared.dir.display(), shared.tile)
            }
            ModuleSpec::Dampen { shared } => {
                format!("shared/dampen/{}/{}", shared.dir.display(), shared.tile)
            }
            ModuleSpec::Gemm { shared } => {
                format!("shared/gemm/{}/{}", shared.dir.display(), shared.gemm_demo)
            }
        }
    }

    /// Human-readable module name for error contexts and stats.
    pub fn label(&self) -> String {
        let seg_name = |meta: &ModelMeta, seg: usize| {
            meta.segments
                .get(seg)
                .map(|s| s.name.clone())
                .unwrap_or_else(|| format!("#{seg}"))
        };
        match self {
            ModuleSpec::SegmentFwd { meta, seg } => {
                format!("fwd[{}]({})", seg_name(meta, *seg), meta.name)
            }
            ModuleSpec::SegmentBwd { meta, seg } => {
                format!("bwd[{}]({})", seg_name(meta, *seg), meta.name)
            }
            ModuleSpec::Logits { meta } => format!("logits({})", meta.name),
            ModuleSpec::TrainStep { meta } => format!("train_step({})", meta.name),
            ModuleSpec::LossGrad { meta } => format!("loss_grad({})", meta.name),
            ModuleSpec::Fimd { .. } => "fimd".to_string(),
            ModuleSpec::Dampen { .. } => "dampen".to_string(),
            ModuleSpec::Gemm { .. } => "gemm".to_string(),
        }
    }
}

/// A backend-built module body: positional tensors in, tensors out.
///
/// `Send + Sync` is part of the contract: compiled module bodies are
/// immutable programs shared across fleet workers behind
/// `Arc<Executable>`, so per-call mutable state (scratch arenas) must
/// live outside the module (see `cpu::scratch`).
pub trait ModuleImpl: Send + Sync {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Mixed-precision entry: like [`ModuleImpl::run`] but arguments may
    /// be quantized int8 weights. The default accepts all-f32 argument
    /// lists only — backends that execute true int8 kernels (the
    /// CpuBackend forward modules) override it.
    fn run_mixed(&self, args: &[ArgRef]) -> Result<Vec<Tensor>> {
        match args.iter().map(|a| a.f32()).collect::<Option<Vec<_>>>() {
            Some(f32_args) => self.run(&f32_args),
            None => bail!("this module does not accept int8 arguments"),
        }
    }
}

/// An execution backend: builds module bodies from specs.
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;
    fn compile(&self, spec: &ModuleSpec) -> Result<Box<dyn ModuleImpl>>;
}

/// A compiled/interpreted module with per-module run statistics — the
/// backend-agnostic handle the model graph and engines hold.
pub struct Executable {
    pub name: String,
    imp: Box<dyn ModuleImpl>,
    stats: Mutex<ExecStats>,
}

impl Executable {
    pub(crate) fn new(name: String, imp: Box<dyn ModuleImpl>) -> Executable {
        Executable { name, imp, stats: Mutex::new(ExecStats::default()) }
    }

    /// Execute with host tensors; returns the output tuple as tensors.
    pub fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let out = self
            .imp
            .run(args)
            .with_context(|| format!("executing {}", self.name))?;
        let mut st = self.stats.lock().unwrap();
        st.runs += 1;
        st.run_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(out)
    }

    /// Execute with mixed f32 / int8-weight arguments (the true-int8
    /// forward path). Backends without int8 kernels reject quantized
    /// arguments cleanly.
    pub fn run_mixed(&self, args: &[ArgRef]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let out = self
            .imp
            .run_mixed(args)
            .with_context(|| format!("executing {}", self.name))?;
        let mut st = self.stats.lock().unwrap();
        st.runs += 1;
        st.run_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(out)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

/// A backend plus an executable cache.
///
/// `Send + Sync`: compiled modules are immutable programs behind
/// `Arc<Executable>`, so one runtime (and its cache) is shared by every
/// fleet worker and by the model registry — a worker that warms a model
/// pays module construction once per *process*, not once per replica.
/// The cache lock is held only around lookup/insert, never across a
/// backend compile's execution of user code paths (`load` re-checks
/// after compiling, so two racing compilers converge on one entry).
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    stats: Mutex<ExecStats>,
}

impl Runtime {
    /// The default pure-Rust interpreter backend.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(cpu::CpuBackend::new())))
    }

    /// The PJRT/HLO backend (requires `make artifacts` + real bindings).
    #[cfg(feature = "backend-xla")]
    pub fn xla() -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(xla::XlaBackend::new()?)))
    }

    /// Select the backend via `FICABU_BACKEND` (`cpu` default, `xla` with
    /// the `backend-xla` feature).
    pub fn from_env() -> Result<Runtime> {
        match std::env::var("FICABU_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("cpu") => Runtime::cpu(),
            #[cfg(feature = "backend-xla")]
            Ok("xla") => Runtime::xla(),
            #[cfg(not(feature = "backend-xla"))]
            Ok("xla") => {
                bail!("FICABU_BACKEND=xla requires building with --features backend-xla")
            }
            Ok(other) => bail!("unknown FICABU_BACKEND `{other}` (cpu | xla)"),
        }
    }

    pub fn with_backend(backend: Box<dyn Backend>) -> Runtime {
        Runtime {
            backend,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(ExecStats::default()),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.name().to_string()
    }

    /// Build (or fetch from cache) the module for a spec.
    pub fn load(&self, spec: &ModuleSpec) -> Result<Arc<Executable>> {
        let key = spec.key();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        // Compile outside the cache lock so a slow build never blocks
        // cache hits on other modules; a concurrent compile of the same
        // spec loses the entry race below and its duplicate is dropped.
        let t0 = std::time::Instant::now();
        let imp = self
            .backend
            .compile(spec)
            .with_context(|| format!("compiling {}", spec.label()))?;
        {
            let mut st = self.stats.lock().unwrap();
            st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
            st.compiles += 1;
        }
        let exe = Arc::new(Executable::new(spec.label(), imp));
        Ok(self.cache.lock().unwrap().entry(key).or_insert(exe).clone())
    }

    pub fn cached_modules(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Aggregate runtime statistics (compile count/time plus run stats
    /// summed over every cached [`Executable`]).
    pub fn stats(&self) -> ExecStats {
        let mut s = self.stats.lock().unwrap().clone();
        for exe in self.cache.lock().unwrap().values() {
            let e = exe.stats();
            s.runs += e.runs;
            s.run_ms += e.run_ms;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharedMeta;

    fn shared() -> SharedMeta {
        SharedMeta::builtin()
    }

    #[test]
    fn fimd_module_semantics() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load(&ModuleSpec::Fimd { shared: shared() }).unwrap();
        let t = shared().tile;
        let grad = Tensor::vec1((0..t).map(|i| (i % 7) as f32 * 0.1).collect());
        let acc = Tensor::vec1(vec![1.0; t]);
        let scale = Tensor::vec1(vec![0.5]);
        let out = exe.run(&[&grad, &acc, &scale]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![t]);
        for i in (0..t).step_by(1717) {
            let g = grad.data[i];
            let want = 1.0 + 0.5 * g * g;
            assert!((out[0].data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn executable_cache_hits() {
        let rt = Runtime::cpu().unwrap();
        let spec = ModuleSpec::Dampen { shared: shared() };
        let a = rt.load(&spec).unwrap();
        let b = rt.load(&spec).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.cached_modules(), 1);
        assert_eq!(rt.stats().compiles, 1);
    }

    #[test]
    fn dampen_module_semantics() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load(&ModuleSpec::Dampen { shared: shared() }).unwrap();
        let t = shared().tile;
        // idf huge for even indices -> selected, dampened by beta = id/idf
        let theta = Tensor::vec1(vec![2.0; t]);
        let idf = Tensor::vec1(
            (0..t).map(|i| if i % 2 == 0 { 10.0 } else { 0.1 }).collect(),
        );
        let idd = Tensor::vec1(vec![1.0; t]);
        let alpha = Tensor::vec1(vec![5.0]);
        let lam = Tensor::vec1(vec![1.0]);
        let out = exe.run(&[&theta, &idf, &idd, &alpha, &lam]).unwrap();
        assert_eq!(out.len(), 2);
        // even: selected (10 > 5*1), beta = min(1*1/10, 1) = 0.1 -> 0.2
        assert!((out[0].data[0] - 0.2).abs() < 1e-6);
        assert_eq!(out[1].data[0], 1.0);
        // odd: not selected
        assert_eq!(out[0].data[1], 2.0);
        assert_eq!(out[1].data[1], 0.0);
    }

    #[test]
    fn unsupported_segment_kind_errors() {
        let rt = Runtime::cpu().unwrap();
        let mut meta = crate::config::ModelMeta::builtin("rn18slim").unwrap();
        meta.segments[0].kind = "alien".to_string();
        assert!(rt.load(&ModuleSpec::SegmentFwd { meta, seg: 0 }).is_err());
    }

    #[test]
    fn from_env_rejects_unknown_backend() {
        std::env::set_var("FICABU_BACKEND", "npu");
        assert!(Runtime::from_env().is_err());
        std::env::set_var("FICABU_BACKEND", "cpu");
        assert!(Runtime::from_env().is_ok());
        std::env::remove_var("FICABU_BACKEND");
        assert!(Runtime::from_env().is_ok());
    }
}
