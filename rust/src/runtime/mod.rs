//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute many.
//!
//! This is the only module that touches the `xla` crate. The rest of the
//! coordinator deals in [`crate::tensor::Tensor`]s; conversion happens at
//! the execute boundary. Executables are cached by path, so the per-layer
//! unlearning loop pays compilation once per module per process.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

mod exec;
pub use exec::{ExecStats, Executable};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

/// A PJRT CPU client plus an executable cache.
///
/// Deliberately `!Sync`: PJRT client handles are owned by the coordinator
/// thread, matching the single Unlearning Engine of the processor; the
/// request-facing threads talk to it via channels (`coordinator`).
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Executable>>>,
    stats: RefCell<ExecStats>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text module, memoized by canonical path.
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Rc<Executable>> {
        let path = path.as_ref();
        let key = path
            .canonicalize()
            .with_context(|| format!("module not found: {}", path.display()))?;
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {}", key.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", key.display()))?;
        {
            let mut st = self.stats.borrow_mut();
            st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
            st.compiles += 1;
        }
        let exe = Rc::new(Executable::new(
            key.file_name().unwrap().to_string_lossy().to_string(),
            exe,
        ));
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn cached_modules(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Aggregate runtime statistics (compile count/time plus run stats
    /// summed over every cached [`Executable`]).
    pub fn stats(&self) -> ExecStats {
        let mut s = self.stats.borrow().clone();
        for exe in self.cache.borrow().values() {
            let e = exe.stats();
            s.runs += e.runs;
            s.run_ms += e.run_ms;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SharedMeta;
    use crate::tensor::Tensor;
    use std::path::Path;

    fn art() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts")
    }

    #[test]
    fn load_and_run_fimd_module() {
        let rt = Runtime::cpu().unwrap();
        let shared = SharedMeta::load(art().join("shared")).unwrap();
        let exe = rt.load(shared.module_path(&shared.fimd)).unwrap();
        let t = shared.tile;
        let grad = Tensor::vec1((0..t).map(|i| (i % 7) as f32 * 0.1).collect());
        let acc = Tensor::vec1(vec![1.0; t]);
        let scale = Tensor::vec1(vec![0.5]);
        let out = exe.run(&[&grad, &acc, &scale]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![t]);
        for i in (0..t).step_by(1717) {
            let g = grad.data[i];
            let want = 1.0 + 0.5 * g * g;
            assert!((out[0].data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn executable_cache_hits() {
        let rt = Runtime::cpu().unwrap();
        let shared = SharedMeta::load(art().join("shared")).unwrap();
        let p = shared.module_path(&shared.dampen);
        let a = rt.load(&p).unwrap();
        let b = rt.load(&p).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(rt.cached_modules(), 1);
        assert_eq!(rt.stats().compiles, 1);
    }

    #[test]
    fn dampen_module_semantics() {
        let rt = Runtime::cpu().unwrap();
        let shared = SharedMeta::load(art().join("shared")).unwrap();
        let exe = rt.load(shared.module_path(&shared.dampen)).unwrap();
        let t = shared.tile;
        // idf huge for even indices -> selected, dampened by beta = id/idf
        let theta = Tensor::vec1(vec![2.0; t]);
        let idf = Tensor::vec1(
            (0..t).map(|i| if i % 2 == 0 { 10.0 } else { 0.1 }).collect(),
        );
        let idd = Tensor::vec1(vec![1.0; t]);
        let alpha = Tensor::vec1(vec![5.0]);
        let lam = Tensor::vec1(vec![1.0]);
        let out = exe.run(&[&theta, &idf, &idd, &alpha, &lam]).unwrap();
        assert_eq!(out.len(), 2);
        // even: selected (10 > 5*1), beta = min(1*1/10, 1) = 0.1 -> 0.2
        assert!((out[0].data[0] - 0.2).abs() < 1e-6);
        assert_eq!(out[1].data[0], 1.0);
        // odd: not selected
        assert_eq!(out[0].data[1], 2.0);
        assert_eq!(out[1].data[1], 0.0);
    }

    #[test]
    fn missing_module_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.load("/nonexistent/x.hlo.txt").is_err());
    }
}
