//! XlaBackend — the PJRT/HLO artifact path (`backend-xla` feature).
//!
//! Maps every [`ModuleSpec`] onto the HLO-text file `make artifacts`
//! exported for it, compiles once through the PJRT client, and converts
//! tensors at the execute boundary. This is the only module that touches
//! the `xla` crate; by default the workspace links the vendored API stub
//! (`vendor/xla-stub`) so the feature *compiles* everywhere — real
//! execution requires swapping in the actual `xla` bindings.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::{Backend, ModuleImpl, ModuleSpec};

/// PJRT CPU client shared by every compiled module.
pub struct XlaBackend {
    client: std::rc::Rc<xla::PjRtClient>,
}

impl XlaBackend {
    pub fn new() -> Result<XlaBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaBackend { client: std::rc::Rc::new(client) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

/// The artifact file a spec maps to.
fn module_path(spec: &ModuleSpec) -> Result<PathBuf> {
    Ok(match spec {
        ModuleSpec::SegmentFwd { meta, seg } => meta.module_path(&meta.segments[*seg].fwd),
        ModuleSpec::SegmentBwd { meta, seg } => meta.module_path(&meta.segments[*seg].bwd),
        ModuleSpec::Logits { meta } => meta.module_path(&meta.logits_module),
        ModuleSpec::TrainStep { meta } => meta.module_path(&meta.train_step_module),
        ModuleSpec::LossGrad { meta } => meta.module_path(&meta.loss_grad_module),
        ModuleSpec::Fimd { shared } => shared.module_path(&shared.fimd),
        ModuleSpec::Dampen { shared } => shared.module_path(&shared.dampen),
        ModuleSpec::Gemm { shared } => shared.module_path(&shared.gemm),
    })
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn compile(&self, spec: &ModuleSpec) -> Result<Box<dyn ModuleImpl>> {
        let path = module_path(spec)?;
        let key = path.canonicalize().with_context(|| {
            format!("module not found: {} (run `make artifacts`)", path.display())
        })?;
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {}", key.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", key.display()))?;
        Ok(Box::new(XlaModule { name: spec.label(), exe }))
    }
}

/// A compiled PJRT executable with positional-argument semantics matching
/// the AOT export (params..., x[, gy]); outputs are the flattened ROOT
/// tuple in export order.
struct XlaModule {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl ModuleImpl for XlaModule {
    fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        if outs.is_empty() || outs[0].is_empty() {
            bail!("{}: empty execution result", self.name);
        }
        let lit = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // AOT lowers with return_tuple=True, so the result is always a tuple.
        let parts = lit
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))?;
        parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // rank-0: reshape [1] -> []
        return lit.reshape(&[]).context("reshaping scalar literal");
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping literal")
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let ty = shape.ty();
    if !matches!(ty, xla::ElementType::F32) {
        bail!("expected f32 output, got {ty:?}");
    }
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("reading literal data")?;
    Tensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_backend_fails_gracefully() {
        // with the vendored stub linked, client creation is a clean error,
        // not a crash — the real bindings swap in via the path dependency
        match XlaBackend::new() {
            Ok(_) => (), // real bindings present
            Err(e) => assert!(format!("{e:#}").contains("PJRT")),
        }
    }
}
