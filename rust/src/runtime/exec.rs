//! Executable wrapper: Tensor <-> Literal conversion + per-exe run stats.

use std::cell::RefCell;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub compiles: u64,
    pub compile_ms: f64,
    pub runs: u64,
    pub run_ms: f64,
}

/// A compiled PJRT executable with positional-argument semantics matching
/// the AOT export (params..., x[, gy]); outputs are the flattened ROOT
/// tuple in export order.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    stats: RefCell<ExecStats>,
}

impl Executable {
    pub(crate) fn new(name: String, exe: xla::PjRtLoadedExecutable) -> Executable {
        Executable { name, exe, stats: RefCell::new(ExecStats::default()) }
    }

    /// Execute with host tensors; returns the output tuple as tensors.
    pub fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let outs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // AOT lowers with return_tuple=True, so the result is always a tuple.
        let parts = lit
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))?;
        let result = parts
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<Vec<_>>>()?;
        let mut st = self.stats.borrow_mut();
        st.runs += 1;
        st.run_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(result)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.borrow().clone()
    }
}

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // rank-0: reshape [1] -> []
        return lit.reshape(&[]).context("reshaping scalar literal");
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).context("reshaping literal")
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let ty = shape.ty();
    if !matches!(ty, xla::ElementType::F32) {
        bail!("expected f32 output, got {:?}", ty);
    }
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("reading literal data")?;
    Tensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.25);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back.shape, Vec::<usize>::new());
        assert_eq!(back.data, vec![3.25]);
    }
}
