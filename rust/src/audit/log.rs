//! Durable per-model audit chains: the `audit.log` file beside the WAL.
//!
//! # On-disk layout
//!
//! ```text
//! header:  "FICABUA1"
//! record:  len u32 LE | crc32(payload) u32 LE | payload (len bytes)
//! payload: the record's full canonical JSON ([`AuditRecord::to_json`])
//! ```
//!
//! Framing and torn-write semantics are exactly the WAL's
//! ([`wal`](crate::coordinator::wal)): appends are sequential
//! `write_all` + fsync, a crash can tear at most the tail, and a scan
//! stops at the first frame that is short, implausibly sized, fails its
//! CRC32, or does not decode to a schema-valid record. Unlike the WAL
//! there is no generation word — the chain deliberately survives ledger
//! generations (recovery re-enters it instead of rewriting it).
//!
//! # Taint semantics
//!
//! An append that cannot reach disk (I/O error, `audit_append` fault)
//! must not block the reply path and must not silently drop the link:
//! the record enters the *in-memory* chain with `tainted: true`, later
//! links chain their `prev_hash` over it, and the on-disk chain keeps a
//! permanent, detectable hole at that position — `audit verify` fails
//! loudly there, which is the flag. Checkpoint [`ChainHead`]s are
//! computed from persisted links only.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::wal::crc32;
use crate::coordinator::ModelId;
use crate::testkit::faults;

use super::{AuditRecord, ChainHead};

/// Audit chain file name inside the durable directory.
pub const AUDIT_FILE: &str = "audit.log";

const MAGIC: &[u8; 8] = b"FICABUA1";
/// Upper bound on one framed record — larger is treated as corruption.
const MAX_RECORD: u32 = 16 << 20;

/// Result of scanning an `audit.log` under the torn-write rules: the
/// valid record prefix plus where it ends.
#[derive(Debug)]
pub struct AuditScan {
    /// Schema-valid records in file order.
    pub records: Vec<AuditRecord>,
    /// Byte length of the valid prefix (header included).
    pub valid_len: u64,
    /// Whether bytes past `valid_len` were found (torn tail/corruption).
    pub truncated: bool,
}

/// Scan `path` front to back, stopping at the first torn or corrupt
/// frame. A missing or wrong header is a loud error: appends never
/// touch the header after creation, so a bad one is disk corruption of
/// the proof record, not a crash artifact — it must not read as an
/// empty chain.
pub fn read_log(path: &Path) -> Result<AuditScan> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading audit log {}", path.display()))?;
    if bytes.len() < 8 || &bytes[..8] != MAGIC {
        bail!("audit log {} has a corrupt or missing FICABUA1 header", path.display());
    }
    let mut records = Vec::new();
    let mut pos = 8usize;
    loop {
        if pos + 8 > bytes.len() {
            break; // clean end or short frame header
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if len == 0 || len > MAX_RECORD {
            break;
        }
        let end = pos + 8 + len as usize;
        if end > bytes.len() {
            break; // torn payload
        }
        let payload = &bytes[pos + 8..end];
        if crc32(payload) != crc {
            break;
        }
        let Ok(text) = std::str::from_utf8(payload) else { break };
        let Ok(json) = crate::util::json::Json::parse(text) else { break };
        let Ok(rec) = AuditRecord::from_json(&json) else {
            break; // checksummed but schema-invalid: stop, same as torn
        };
        records.push(rec);
        pos = end;
    }
    Ok(AuditScan { records, valid_len: pos as u64, truncated: pos < bytes.len() })
}

/// Atomically replace the log at `path` with exactly `records` (tmp +
/// fsync + rename + dir fsync) — recovery's orphan truncation.
pub fn write_replacing(path: &Path, records: &[AuditRecord]) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    for rec in records {
        frame_into(&mut buf, rec);
    }
    let tmp = path.with_extension("log.tmp");
    {
        let mut f = File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    crate::coordinator::wal::sync_dir(path.parent().unwrap_or(Path::new(".")));
    Ok(())
}

fn frame_into(buf: &mut Vec<u8>, rec: &AuditRecord) {
    let payload = rec.to_json().to_string().into_bytes();
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
}

#[derive(Default)]
struct ModelChain {
    /// The full in-memory chain, tainted links included — this is what
    /// later links' `prev_hash` covers and what the fleet serves over
    /// `GET /models/{id}/audit`.
    records: Vec<AuditRecord>,
    /// `(chain_seq, core_hash)` of the newest *persisted* link — the
    /// checkpoint anchor.
    persisted: Option<(u64, u64)>,
}

/// Append handle over one `audit.log` plus the in-memory per-model
/// chains. Not internally locked: the owner
/// ([`Durability`](crate::coordinator::Durability)) serializes access,
/// and the same lock pairs each audit append with its WAL `Completed`
/// append so a crash leaves at most one trailing orphan record.
pub struct AuditLog {
    path: PathBuf,
    file: File,
    chains: BTreeMap<String, ModelChain>,
}

impl AuditLog {
    /// Open (or create) the log for appending: scan it, physically
    /// truncate any torn tail, and seed the in-memory chains from the
    /// persisted records.
    pub fn open_append(path: impl AsRef<Path>) -> Result<AuditLog> {
        let path = path.as_ref().to_path_buf();
        if !path.exists() {
            let mut f =
                File::create(&path).with_context(|| format!("creating {}", path.display()))?;
            f.write_all(MAGIC)?;
            f.sync_all()?;
            crate::coordinator::wal::sync_dir(path.parent().unwrap_or(Path::new(".")));
        }
        let scan = read_log(&path)?;
        let file = OpenOptions::new().append(true).open(&path)?;
        if scan.truncated {
            file.set_len(scan.valid_len)?;
            file.sync_all()?;
        }
        let mut chains: BTreeMap<String, ModelChain> = BTreeMap::new();
        for rec in scan.records {
            let chain = chains.entry(rec.model.as_str().to_string()).or_default();
            chain.persisted = Some((rec.chain_seq, rec.core_hash()));
            chain.records.push(rec);
        }
        Ok(AuditLog { path, file, chains })
    }

    /// Path of the underlying file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record: stamp `chain_seq`/`prev_hash` from the
    /// in-memory chain, then persist (framed + fsync'd). A persist
    /// failure taints the record instead of erroring — the link stays
    /// in the chain, flagged, and the caller's reply path continues.
    /// Returns the stamped record.
    pub fn append(&mut self, mut rec: AuditRecord) -> AuditRecord {
        let (chain_seq, prev_hash) = match self.chains.get(rec.model.as_str()) {
            Some(c) => match c.records.last() {
                Some(last) => (last.chain_seq + 1, last.core_hash()),
                None => (1, AuditRecord::genesis_hash(&rec.model)),
            },
            None => (1, AuditRecord::genesis_hash(&rec.model)),
        };
        rec.chain_seq = chain_seq;
        rec.prev_hash = prev_hash;
        rec.tainted = false;
        if let Err(e) = self.persist(&rec) {
            rec.tainted = true;
            eprintln!(
                "ficabu: audit append failed for model {} chain seq {chain_seq} \
                 (link tainted, serving continues): {e:#}",
                rec.model
            );
        }
        let chain = self.chains.entry(rec.model.as_str().to_string()).or_default();
        if !rec.tainted {
            chain.persisted = Some((rec.chain_seq, rec.core_hash()));
        }
        chain.records.push(rec.clone());
        rec
    }

    fn persist(&mut self, rec: &AuditRecord) -> Result<()> {
        faults::hit("audit_append")?;
        let mut frame = Vec::new();
        frame_into(&mut frame, rec);
        self.file.write_all(&frame)?;
        self.file.sync_data()?;
        Ok(())
    }

    /// The in-memory chain of one model (tainted links included);
    /// empty when the model has no links.
    pub fn chain(&self, model: &ModelId) -> Vec<AuditRecord> {
        self.chains.get(model.as_str()).map(|c| c.records.clone()).unwrap_or_default()
    }

    /// Ids of every model with at least one link, in sorted order.
    pub fn models(&self) -> Vec<ModelId> {
        self.chains
            .keys()
            .filter_map(|id| ModelId::new(id.as_str()).ok())
            .collect()
    }

    /// Per-model heads over *persisted* links only — what checkpoints
    /// embed. Models whose every link is tainted have no head yet.
    pub fn heads(&self) -> Vec<ChainHead> {
        self.chains
            .iter()
            .filter_map(|(id, c)| {
                let (chain_len, head_hash) = c.persisted?;
                Some(ChainHead { model: ModelId::new(id.as_str()).ok()?, chain_len, head_hash })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::test_record;
    use std::sync::{Mutex, MutexGuard, PoisonError};

    // `faults` plans are process-global; serialize the arming tests.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficabu_audit_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_reopen_roundtrip_chains_per_model() {
        let dir = tmpdir("roundtrip");
        let path = dir.join(AUDIT_FILE);
        let mut log = AuditLog::open_append(&path).unwrap();
        let a1 = log.append(test_record("tenant-a", 0, 0));
        let b1 = log.append(test_record("tenant-b", 0, 0));
        let a2 = log.append(test_record("tenant-a", 0, 0));
        assert_eq!((a1.chain_seq, b1.chain_seq, a2.chain_seq), (1, 1, 2));
        assert_eq!(a1.prev_hash, AuditRecord::genesis_hash(&a1.model));
        assert_eq!(a2.prev_hash, a1.core_hash());
        assert_eq!(b1.prev_hash, AuditRecord::genesis_hash(&b1.model));
        drop(log);

        let log = AuditLog::open_append(&path).unwrap();
        let a = ModelId::new("tenant-a").unwrap();
        let chain = log.chain(&a);
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[1].prev_hash, chain[0].core_hash());
        assert_eq!(log.models().len(), 2);
        let heads = log.heads();
        let ha = heads.iter().find(|h| h.model == a).unwrap();
        assert_eq!((ha.chain_len, ha.head_hash), (2, a2.core_hash()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_truncated_on_reopen() {
        let dir = tmpdir("torn");
        let path = dir.join(AUDIT_FILE);
        let mut log = AuditLog::open_append(&path).unwrap();
        log.append(test_record("default", 0, 0));
        log.append(test_record("default", 0, 0));
        drop(log);
        let whole = std::fs::read(&path).unwrap();
        let mut torn = whole.clone();
        torn.extend_from_slice(&[64, 0, 0, 0, 0xde, 0xad, 0xbe, 0xef, 1, 2, 3]);
        std::fs::write(&path, &torn).unwrap();
        let scan = read_log(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert!(scan.truncated);
        let log = AuditLog::open_append(&path).unwrap();
        assert_eq!(log.chain(&ModelId::default()).len(), 2);
        drop(log);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), whole.len() as u64, "tail cut");
        // corrupt header refuses loudly — proof files never read empty
        let mut bad = whole;
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(read_log(&path).is_err());
        assert!(AuditLog::open_append(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_append_taints_the_link_and_the_chain_continues() {
        let _g = serial();
        let dir = tmpdir("taint");
        let path = dir.join(AUDIT_FILE);
        let mut log = AuditLog::open_append(&path).unwrap();
        let r1 = log.append(test_record("default", 0, 0));
        faults::arm("audit_append:1:error").unwrap();
        let r2 = log.append(test_record("default", 0, 0));
        faults::clear();
        let r3 = log.append(test_record("default", 0, 0));
        assert!(!r1.tainted && r2.tainted && !r3.tainted);
        // the tainted link is flagged, never dropped: it sits in the
        // in-memory chain and r3 chains over it
        let chain = log.chain(&ModelId::default());
        assert_eq!(chain.len(), 3);
        assert!(chain[1].tainted);
        assert_eq!(r3.prev_hash, r2.core_hash());
        assert_eq!(r3.chain_seq, 3);
        // heads anchor on persisted links only
        let heads = log.heads();
        assert_eq!(heads[0].chain_len, 3, "r3 is persisted");
        drop(log);
        // on disk: links 1 and 3 — a permanent, detectable hole at 2
        let scan = read_log(&path).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[1].chain_seq, 3);
        assert_ne!(scan.records[1].prev_hash, scan.records[0].core_hash());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_replacing_rewrites_exactly() {
        let dir = tmpdir("replace");
        let path = dir.join(AUDIT_FILE);
        let mut log = AuditLog::open_append(&path).unwrap();
        let r1 = log.append(test_record("default", 0, 0));
        log.append(test_record("default", 0, 0));
        drop(log);
        write_replacing(&path, &[r1.clone()]).unwrap();
        let scan = read_log(&path).unwrap();
        assert_eq!(scan.records, vec![r1]);
        assert!(!scan.truncated);
        std::fs::remove_dir_all(&dir).ok();
    }
}
