//! Offline chain verification and proof extraction.
//!
//! [`verify_dir`] re-validates a durable directory's audit state with
//! no server running: every `audit.log` frame (CRC + schema), every
//! hash link per model chain, and — when a checkpoint exists — that the
//! checkpoint's embedded [`ChainHead`]s anchor to links the log
//! actually contains. Failures name the first broken record by its
//! position so an operator can jump straight to the forged, reordered,
//! or damaged link. [`prove`] answers "prove spec X was forgotten on
//! model M" by returning the verified links that executed X.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint;
use crate::coordinator::ModelId;
use crate::testkit::faults;
use crate::unlearn::ForgetSpec;

use super::log::{read_log, AUDIT_FILE};
use super::{AuditRecord, ChainHead};

/// Outcome of [`verify_dir`]: the verified records plus per-model heads.
#[derive(Debug)]
pub struct VerifyReport {
    /// Every verified record, in file order.
    pub records: Vec<AuditRecord>,
    /// Verified head of each model's chain.
    pub heads: Vec<ChainHead>,
    /// Whether a checkpoint was present and its embedded heads anchored.
    pub checkpoint_checked: bool,
}

/// Verify the hash links of `records` (file order). Per model, the
/// first link's `prev_hash` must equal the genesis hash, `chain_seq`
/// must run 1, 2, 3, ... with no gap or repeat, and every later link's
/// `prev_hash` must equal the previous link's core hash. Errors name
/// the first broken record by its 1-based file position and chain seq.
pub fn verify_records(records: &[AuditRecord]) -> Result<Vec<ChainHead>> {
    let mut state: HashMap<String, (u64, u64)> = HashMap::new(); // id -> (next seq, expected prev)
    for (idx, rec) in records.iter().enumerate() {
        let pos = idx + 1;
        let (want_seq, want_prev) = state
            .get(rec.model.as_str())
            .copied()
            .unwrap_or((1, AuditRecord::genesis_hash(&rec.model)));
        if rec.chain_seq != want_seq {
            bail!(
                "audit chain broken at record {pos} (model {}): chain seq {} where {want_seq} \
                 expected — link {want_seq} is missing, duplicated, or out of order",
                rec.model,
                rec.chain_seq
            );
        }
        if rec.prev_hash != want_prev {
            bail!(
                "audit chain broken at record {pos} (model {}, chain seq {}): prev_hash \
                 {:016x} does not match the previous link's hash {want_prev:016x} — forged or \
                 tampered link",
                rec.model,
                rec.chain_seq,
                rec.prev_hash
            );
        }
        state.insert(rec.model.as_str().to_string(), (want_seq + 1, rec.core_hash()));
    }
    let mut heads: Vec<ChainHead> = state
        .into_iter()
        .map(|(id, (next_seq, head_hash))| {
            Ok(ChainHead {
                model: ModelId::new(id)?,
                chain_len: next_seq - 1,
                head_hash,
            })
        })
        .collect::<Result<_>>()?;
    heads.sort_by(|a, b| a.model.as_str().cmp(b.model.as_str()));
    Ok(heads)
}

/// Verify a durable directory offline: frame-scan `audit.log` (a torn
/// or bit-flipped frame fails, naming the first bad record), check
/// every hash link ([`verify_records`]), and anchor the newest
/// checkpoint's embedded heads against the log.
pub fn verify_dir(dir: &Path) -> Result<VerifyReport> {
    faults::hit("audit_verify")?;
    let path = dir.join(AUDIT_FILE);
    if !path.exists() {
        bail!("no {AUDIT_FILE} in {} — nothing to verify", dir.display());
    }
    let scan = read_log(&path)?;
    if scan.truncated {
        bail!(
            "audit log {}: record {} is torn or corrupt (CRC/schema failure); the valid \
             chain ends after record {}",
            path.display(),
            scan.records.len() + 1,
            scan.records.len()
        );
    }
    let heads = verify_records(&scan.records)?;
    let mut checkpoint_checked = false;
    if let Some(ckpt) = checkpoint::load_latest(dir)? {
        for anchor in &ckpt.audit {
            let found = scan.records.iter().any(|r| {
                r.model == anchor.model
                    && r.chain_seq == anchor.chain_len
                    && r.core_hash() == anchor.head_hash
            });
            if !found {
                bail!(
                    "checkpoint anchors model {} at chain seq {} (hash {:016x}) but the audit \
                     log contains no such link — log and checkpoint diverged",
                    anchor.model,
                    anchor.chain_len,
                    anchor.head_hash
                );
            }
        }
        checkpoint_checked = true;
    }
    Ok(VerifyReport { records: scan.records, heads, checkpoint_checked })
}

/// Prove `spec` was forgotten: verify the directory, then return the
/// chain links that executed the spec's canonical key (optionally
/// restricted to one model), newest last. Rolled-back executions are
/// not proof and are excluded. Errors when the chain holds no such
/// link.
pub fn prove(
    dir: &Path,
    model: Option<&ModelId>,
    spec: &ForgetSpec,
) -> Result<Vec<AuditRecord>> {
    let report = verify_dir(dir).context("cannot prove against an unverifiable chain")?;
    let key = spec.canonical().key().hash64();
    let links: Vec<AuditRecord> = report
        .records
        .into_iter()
        .filter(|r| {
            r.spec.key().hash64() == key
                && !r.rolled_back
                && model.map(|m| r.model == *m).unwrap_or(true)
        })
        .collect();
    if links.is_empty() {
        bail!(
            "no verified audit link proves `{}`{} — the chain does not record that forget",
            spec.canonical(),
            model.map(|m| format!(" on model {m}")).unwrap_or_default()
        );
    }
    Ok(links)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::log::{write_replacing, AuditLog};
    use crate::audit::test_record;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ficabu_verify_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// A valid three-link chain (specs class:1..class:3) in a fresh dir.
    fn chained_dir(tag: &str) -> (PathBuf, Vec<AuditRecord>) {
        let dir = tmpdir(tag);
        let mut log = AuditLog::open_append(dir.join(AUDIT_FILE)).unwrap();
        let recs: Vec<AuditRecord> =
            (1..=3).map(|i| log.append(test_record("default", i, 0))).collect();
        (dir, recs)
    }

    #[test]
    fn valid_chain_verifies_with_heads() {
        let (dir, recs) = chained_dir("ok");
        let report = verify_dir(&dir).unwrap();
        assert_eq!(report.records.len(), 3);
        assert!(!report.checkpoint_checked, "no checkpoint in this dir");
        assert_eq!(report.heads.len(), 1);
        assert_eq!(report.heads[0].chain_len, 3);
        assert_eq!(report.heads[0].head_hash, recs[2].core_hash());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_tail_record_is_named() {
        let (dir, _) = chained_dir("torn");
        let path = dir.join(AUDIT_FILE);
        let len = std::fs::metadata(&path).unwrap().len();
        // chop into the last frame's payload
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(len - 7)
            .unwrap();
        let err = verify_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 3"), "must name the torn record: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bit_flipped_body_is_named() {
        let (dir, _) = chained_dir("flip");
        let path = dir.join(AUDIT_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one byte in the second frame's payload: locate it by
        // walking the frames
        let mut pos = 8usize;
        let len1 = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 8 + len1; // start of frame 2
        bytes[pos + 8 + 10] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = verify_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 2"), "must name the flipped record: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reordered_records_are_named() {
        let (dir, recs) = chained_dir("reorder");
        let path = dir.join(AUDIT_FILE);
        let swapped = vec![recs[0].clone(), recs[2].clone(), recs[1].clone()];
        write_replacing(&path, &swapped).unwrap();
        let err = verify_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 2"), "first out-of-order link is record 2: {msg}");
        assert!(msg.contains("chain seq 3"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn forged_record_with_stale_prev_hash_is_named() {
        let (dir, recs) = chained_dir("forge");
        let path = dir.join(AUDIT_FILE);
        // forge link 3: right chain_seq, but prev_hash skips link 2
        // (points at link 1, as if link 2 were quietly replaced)
        let mut forged = recs.clone();
        forged[2].prev_hash = recs[0].core_hash();
        forged[2].forget_acc = 0.0; // the doctored claim
        write_replacing(&path, &forged).unwrap();
        let err = verify_dir(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 3"), "must name the forged link: {msg}");
        assert!(msg.contains("forged or tampered"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prove_returns_matching_links_and_rejects_unknown_specs() {
        let (dir, recs) = chained_dir("prove");
        // test_record specs are class:(chain_seq % 7) = 1, 2, 3
        let got = prove(&dir, None, &ForgetSpec::Class(2)).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].chain_seq, recs[1].chain_seq);
        let model = ModelId::default();
        assert!(prove(&dir, Some(&model), &ForgetSpec::Class(2)).is_ok());
        let err = prove(&dir, None, &ForgetSpec::Class(6)).unwrap_err();
        assert!(format!("{err:#}").contains("class:6"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
