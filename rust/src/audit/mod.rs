//! Verifiable unlearning: hash-chained audit records with MIA attestation.
//!
//! Privacy regulation is the paper's motive, and a deployed right-to-be-
//! forgotten endpoint must *prove* forgetting, not merely perform it.
//! This module is the "prove" pillar next to serve (the fleet) and
//! survive (the WAL): every completed forget emits an [`AuditRecord`] —
//! the canonical spec, tenancy (model id + config fingerprint), build
//! identity (git rev), executed precision, seed, before/after quality,
//! and a membership-inference [`Attestation`]
//! ([`ThresholdAttack`](crate::metrics::ThresholdAttack) member-rate on
//! the forget set before vs after the edit) — serialized as canonical
//! JSON and hash-chained per model:
//!
//! ```text
//! record 1            record 2            record 3
//! prev = fnv64(model) prev = H(record 1)  prev = H(record 2)   ...
//! ```
//!
//! where `H` is FNV-1a 64 over the record's canonical *core* JSON (the
//! record minus its durability coordinates `wal_seq`/`wal_gen`/`tainted` —
//! recovery rewrites the ledger with fresh sequence numbers, so those
//! coordinates are generation-local while the chain must hash
//! identically across a crash; CRC framing in the log still detects any
//! on-disk byte damage, see [`log`]).
//!
//! The chain lives in three places:
//!
//! * `audit.log` beside the WAL ([`log::AuditLog`], CRC-framed like
//!   `wal.rs`), appended *before* the WAL `Completed` record under one
//!   lock so a crash leaves at most one trailing orphan;
//! * every durability checkpoint (`FICABUC3`) embeds the per-model
//!   [`ChainHead`]s at checkpoint time;
//! * [`ParamStore::save_with_provenance`](crate::model::ParamStore::save_with_provenance)
//!   embeds the head record in shipped parameter files.
//!
//! [`verify`] re-validates all of it offline (`ficabu audit
//! list|verify|prove`); the fleet surfaces chains live over
//! `GET /models/{id}/audit`.

pub mod log;
pub mod verify;

pub use log::{AuditLog, AuditScan, AUDIT_FILE};
pub use verify::{prove, verify_dir, verify_records, VerifyReport};

use anyhow::{bail, Context, Result};

use crate::coordinator::ModelId;
use crate::unlearn::ForgetSpec;
use crate::util::json::Json;

/// FNV-1a 64 — the crate's fingerprint hash (same parameters as the
/// dispatcher's config fingerprint), here over canonical record bytes.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Build identity stamped into every record: `FICABU_GIT_REV` when set
/// (hermetic builds, tests), else `git rev-parse --short=12 HEAD`, else
/// `"unknown"`. Resolved once per process.
pub fn git_rev() -> &'static str {
    use std::sync::OnceLock;
    static REV: OnceLock<String> = OnceLock::new();
    REV.get_or_init(|| {
        if let Ok(v) = std::env::var("FICABU_GIT_REV") {
            let v = v.trim().to_string();
            if !v.is_empty() {
                return v;
            }
        }
        std::process::Command::new("git")
            .args(["rev-parse", "--short=12", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .unwrap_or_else(|| "unknown".to_string())
    })
}

/// Membership-inference attestation of one forget: the threshold attack
/// is calibrated *after* the edit (members = retain losses, non-members
/// = forget losses) and probes the forget set's pre- and post-edit
/// losses. Successful unlearning drives `mia_after` below `mia_before`
/// — the drop is the evidence an auditor checks per link.
#[derive(Debug, Clone, PartialEq)]
pub struct Attestation {
    /// Strategy name that executed the forget (e.g. `"FiCABU"`).
    pub strategy: String,
    /// Executed numeric precision (`"f32"` / `"int8"`).
    pub precision: String,
    /// The worker's sampling seed (with the spec key, it pins the batch).
    pub seed: u64,
    /// Forget-set accuracy before the edit.
    pub forget_acc_before: f64,
    /// Retain-subsample accuracy before the edit.
    pub retain_acc_before: f64,
    /// Member-rate of the forget set's pre-edit losses.
    pub mia_before: f64,
    /// Member-rate of the forget set's post-edit losses.
    pub mia_after: f64,
}

impl Attestation {
    /// Canonical JSON (fixed key order — the hashed wire form).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("strategy", Json::string(self.strategy.clone())),
            ("precision", Json::string(self.precision.clone())),
            ("seed", Json::string(format!("{:016x}", self.seed))),
            ("forget_acc_before", Json::from(self.forget_acc_before)),
            ("retain_acc_before", Json::from(self.retain_acc_before)),
            ("mia_before", Json::from(self.mia_before)),
            ("mia_after", Json::from(self.mia_after)),
        ])
    }

    /// Schema-checked decode of [`Attestation::to_json`].
    pub fn from_json(j: &Json) -> Result<Attestation> {
        let str_field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("attestation: missing string `{k}`"))
        };
        let num = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("attestation: missing number `{k}`"))
        };
        Ok(Attestation {
            strategy: str_field("strategy")?,
            precision: str_field("precision")?,
            seed: hex64(&str_field("seed")?).context("attestation: bad seed")?,
            forget_acc_before: num("forget_acc_before")?,
            retain_acc_before: num("retain_acc_before")?,
            mia_before: num("mia_before")?,
            mia_after: num("mia_after")?,
        })
    }
}

/// One link of a model's audit chain: everything an auditor needs to
/// re-derive "what was forgotten, by which build, with what evidence".
///
/// `chain_seq`/`prev_hash` are stamped by [`AuditLog::append`];
/// `wal_seq`/`wal_gen`/`tainted` are durability coordinates excluded
/// from the chain hash (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct AuditRecord {
    /// The model this forget ran against.
    pub model: ModelId,
    /// 1-based position in this model's chain.
    pub chain_seq: u64,
    /// Core hash of the previous link; `fnv64(model id)` for link 1.
    pub prev_hash: u64,
    /// The canonical request that was executed.
    pub spec: ForgetSpec,
    /// FNV-1a fingerprint of the serving `UnlearnConfig`.
    pub config_hash: u64,
    /// Build identity at record time ([`git_rev`]).
    pub git_rev: String,
    /// Whether the engine rolled the edit back.
    pub rolled_back: bool,
    /// Ledger sequence of the completing WAL record (generation-local;
    /// `None` for records produced outside a durable fleet).
    pub wal_seq: Option<u64>,
    /// Ledger generation `wal_seq` belongs to (0 outside a durable
    /// fleet). Recovery uses it to tell which trailing records were
    /// written against the ledger being recovered; like `wal_seq` it is
    /// excluded from the chain hash.
    pub wal_gen: u64,
    /// `true` when the durable append of this record failed: the link
    /// exists in memory and in later records' `prev_hash` but not on
    /// disk — flagged, never silently dropped.
    pub tainted: bool,
    /// Forget-set accuracy after the edit.
    pub forget_acc: f64,
    /// Retain-subsample accuracy after the edit.
    pub retain_acc: f64,
    /// Membership-inference evidence; `None` when the serving core
    /// could not probe (e.g. a mock service).
    pub attest: Option<Attestation>,
}

impl AuditRecord {
    /// Genesis `prev_hash` of a model's chain (link 1 points here).
    pub fn genesis_hash(model: &ModelId) -> u64 {
        fnv64(model.as_str().as_bytes())
    }

    /// Full canonical JSON — the framed wire form in `audit.log`.
    pub fn to_json(&self) -> Json {
        let mut pairs = self.core_pairs();
        pairs.push((
            "wal_seq",
            self.wal_seq.map(|s| Json::from(s as usize)).unwrap_or(Json::Null),
        ));
        pairs.push(("wal_gen", Json::from(self.wal_gen as usize)));
        pairs.push(("tainted", Json::from(self.tainted)));
        Json::obj(pairs)
    }

    fn core_pairs(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("model", Json::string(self.model.to_string())),
            ("chain_seq", Json::from(self.chain_seq as usize)),
            ("prev_hash", Json::string(format!("{:016x}", self.prev_hash))),
            ("spec", Json::string(self.spec.to_string())),
            ("config_hash", Json::string(format!("{:016x}", self.config_hash))),
            ("git_rev", Json::string(self.git_rev.clone())),
            ("rolled_back", Json::from(self.rolled_back)),
            ("forget_acc", Json::from(self.forget_acc)),
            ("retain_acc", Json::from(self.retain_acc)),
            ("attest", self.attest.as_ref().map(Attestation::to_json).unwrap_or(Json::Null)),
        ]
    }

    /// The hashed core: the record minus `wal_seq`/`wal_gen`/`tainted`
    /// (see the module docs for why durability coordinates stay out of
    /// the chain).
    pub fn core_json(&self) -> Json {
        Json::obj(self.core_pairs())
    }

    /// FNV-1a 64 over the canonical core JSON — what the next link's
    /// `prev_hash` must equal.
    pub fn core_hash(&self) -> u64 {
        fnv64(self.core_json().to_string().as_bytes())
    }

    /// Schema-checked decode of [`AuditRecord::to_json`]. Every field is
    /// required (`wal_seq`/`attest` may be `null`); unknown specs, bad
    /// hex, or missing keys are loud errors — this *is* the offline
    /// schema check `audit verify` applies per record.
    pub fn from_json(j: &Json) -> Result<AuditRecord> {
        let str_field = |k: &str| -> Result<&str> {
            j.get(k).and_then(Json::as_str).with_context(|| format!("audit record: missing string `{k}`"))
        };
        let num = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).with_context(|| format!("audit record: missing number `{k}`"))
        };
        let boolean = |k: &str| -> Result<bool> {
            j.get(k).and_then(Json::as_bool).with_context(|| format!("audit record: missing bool `{k}`"))
        };
        let chain_seq = num("chain_seq")?;
        if chain_seq < 1.0 || chain_seq.fract() != 0.0 {
            bail!("audit record: chain_seq must be a positive integer, got {chain_seq}");
        }
        let wal_seq = match j.get("wal_seq") {
            Some(Json::Null) => None,
            Some(v) => Some(
                v.as_i64()
                    .filter(|&s| s >= 0)
                    .context("audit record: wal_seq must be a non-negative integer or null")?
                    as u64,
            ),
            None => bail!("audit record: missing `wal_seq`"),
        };
        let wal_gen = num("wal_gen")?;
        if wal_gen < 0.0 || wal_gen.fract() != 0.0 {
            bail!("audit record: wal_gen must be a non-negative integer, got {wal_gen}");
        }
        let attest = match j.get("attest") {
            Some(Json::Null) => None,
            Some(v) => Some(Attestation::from_json(v)?),
            None => bail!("audit record: missing `attest`"),
        };
        Ok(AuditRecord {
            model: ModelId::new(str_field("model")?).context("audit record: bad model id")?,
            chain_seq: chain_seq as u64,
            prev_hash: hex64(str_field("prev_hash")?).context("audit record: bad prev_hash")?,
            spec: ForgetSpec::parse(str_field("spec")?).context("audit record: bad spec")?,
            config_hash: hex64(str_field("config_hash")?).context("audit record: bad config_hash")?,
            git_rev: str_field("git_rev")?.to_string(),
            rolled_back: boolean("rolled_back")?,
            wal_seq,
            wal_gen: wal_gen as u64,
            tainted: boolean("tainted")?,
            forget_acc: num("forget_acc")?,
            retain_acc: num("retain_acc")?,
            attest,
        })
    }
}

/// Head of one model's chain at a point in time — what checkpoints
/// embed: re-anchoring recovery can check the log still contains this
/// exact link.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainHead {
    /// The model whose chain this head summarizes.
    pub model: ModelId,
    /// `chain_seq` of the newest durably-persisted link.
    pub chain_len: u64,
    /// [`AuditRecord::core_hash`] of that link.
    pub head_hash: u64,
}

/// 16-hex-digit string → u64 (the record wire form of 64-bit hashes).
fn hex64(s: &str) -> Result<u64> {
    if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        bail!("expected 16 hex digits, got `{s}`");
    }
    u64::from_str_radix(s, 16).map_err(|e| anyhow::anyhow!("bad hex `{s}`: {e}"))
}

/// Shared test fixture for the audit submodules' unit tests.
#[cfg(test)]
pub(crate) fn test_record(model: &str, chain_seq: u64, prev_hash: u64) -> AuditRecord {
    AuditRecord {
        model: ModelId::new(model).unwrap(),
        chain_seq,
        prev_hash,
        spec: ForgetSpec::Class(chain_seq as usize % 7),
        config_hash: 0xdead_beef_0042_0007,
        git_rev: "abc123def456".to_string(),
        rolled_back: false,
        wal_seq: Some(chain_seq),
        wal_gen: 1,
        tainted: false,
        forget_acc: 0.05,
        retain_acc: 0.9,
        attest: Some(Attestation {
            strategy: "FiCABU".to_string(),
            precision: "f32".to_string(),
            seed: 0xedbe,
            forget_acc_before: 0.88,
            retain_acc_before: 0.91,
            mia_before: 0.75,
            mia_after: 0.1,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(model: &str, chain_seq: u64, prev_hash: u64) -> AuditRecord {
        test_record(model, chain_seq, prev_hash)
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let r = record("default", 3, 0x0123_4567_89ab_cdef);
        let j = r.to_json().to_string();
        let back = AuditRecord::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, r);
        // canonical: re-render of the decoded record is byte-identical
        assert_eq!(back.to_json().to_string(), j);
        assert_eq!(back.core_hash(), r.core_hash());
    }

    #[test]
    fn core_hash_ignores_durability_coordinates() {
        let r = record("default", 1, AuditRecord::genesis_hash(&ModelId::default()));
        let mut replayed = r.clone();
        replayed.wal_seq = Some(99);
        replayed.wal_gen = 12;
        assert_eq!(r.core_hash(), replayed.core_hash(), "fresh ledger seqs must not fork the chain");
        let mut t = r.clone();
        t.tainted = true;
        assert_eq!(r.core_hash(), t.core_hash());
        // ... but every core field is covered
        let mut forged = r.clone();
        forged.forget_acc += 1e-9;
        assert_ne!(r.core_hash(), forged.core_hash());
        let mut forged = r;
        forged.git_rev = "ffffffffffff".to_string();
        assert_ne!(forged.core_hash(), record("default", 1, forged.prev_hash).core_hash());
    }

    #[test]
    fn schema_check_rejects_missing_and_malformed_fields() {
        let good = record("default", 1, 7).to_json().to_string();
        let j = Json::parse(&good).unwrap();
        assert!(AuditRecord::from_json(&j).is_ok());
        for broken in [
            good.replace("\"chain_seq\":1", "\"chain_seq\":0"),
            good.replace("\"chain_seq\":1", "\"chain_seq\":1.5"),
            good.replace("prev_hash", "prev_hsah"),
            good.replace("\"spec\":\"class:1\"", "\"spec\":\"klass:1\""),
            good.replace("\"tainted\":false", "\"tainted\":0"),
            good.replace("\"wal_seq\":1", "\"wal_seq\":-4"),
        ] {
            let parsed = Json::parse(&broken).unwrap();
            assert!(AuditRecord::from_json(&parsed).is_err(), "should reject: {broken}");
        }
    }

    #[test]
    fn hex64_is_strict() {
        assert_eq!(hex64("00000000000000ff").unwrap(), 0xff);
        assert!(hex64("ff").is_err());
        assert!(hex64("00000000000000zz").is_err());
        assert!(hex64("00000000000000ff0").is_err());
    }

    #[test]
    fn git_rev_env_override() {
        // process-global OnceLock: only assert the shape, not the source
        let rev = git_rev();
        assert!(!rev.is_empty());
    }
}
