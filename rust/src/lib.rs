//! # FiCABU — Fisher-based Context-Adaptive Balanced Unlearning
//!
//! Reproduction of "FiCABU: A Fisher-Based, Context-Adaptive Machine
//! Unlearning Processor for Edge AI" (DATE 2026) as a self-contained Rust
//! crate: the unlearning coordinator — back-end-first Context-Adaptive
//! Unlearning with checkpointed early stop, Balanced Dampening depth
//! schedule, SSD baseline, INT8 store, the FiCABU processor cycle/energy
//! simulator, and a multi-worker serving fleet (bounded queue,
//! spec-key request coalescing, deadline shedding — see
//! [`coordinator`]).
//!
//! ## Unlearning API
//!
//! Requests and methods are decoupled:
//!
//! * **What** to forget is a typed [`unlearn::ForgetSpec`] — one class,
//!   several classes in one event, or specific training samples — with
//!   a canonical [`unlearn::SpecKey`] the serving fleet coalesces and
//!   routes on.
//! * **How** to forget is an [`unlearn::Strategy`] — the engine's loop
//!   is decomposed into forget-Fisher / dampening / early-stop stages
//!   with the paper's operating points ([`unlearn::Ssd`],
//!   [`unlearn::Cau`], [`unlearn::Bd`], [`unlearn::Ficabu`]) provided;
//!   a custom method overrides single stages.
//! * **Where** it runs is an [`coordinator::UnlearnSession`] — a
//!   builder-style facade owning model, parameter store, stored
//!   importance, and engines, exposing `session.forget(&spec)`; the
//!   [`coordinator::Fleet`] runs one session replica per worker thread.
//!
//! See the runnable example on [`coordinator::UnlearnSession`] and the
//! README's "Unlearning API" section.
//!
//! ## Execution backends
//!
//! All compute flows through the [`runtime::Backend`] seam:
//!
//! * **CpuBackend (default).** A pure-Rust interpreter whose GEMM /
//!   conv / FIMD / dampening kernels match `python/compile/kernels/ref.py`
//!   and run on a tiled, panel-packed, multi-threaded GEMM core
//!   (`FICABU_THREADS`, see README §Performance) with a zero-alloc
//!   scratch arena, driving model inventories built in Rust
//!   ([`config::builtin`]). `cargo build && cargo test` works on a
//!   stock stable toolchain with **no Python artifacts and no XLA** —
//!   `make artifacts` is *not* required.
//! * **XlaBackend (`backend-xla` feature, optional).** The original
//!   PJRT path executing the HLO-text artifacts of the Python AOT export
//!   (L1 Pallas kernels, L2 JAX graphs — see `python/compile/`). Only
//!   this feature consumes `make artifacts`; the workspace compiles it
//!   against a vendored API stub, real execution needs the actual `xla`
//!   bindings. Select at runtime with `FICABU_BACKEND=xla`.
//!
//! Python never runs at request time on either path: after an optional
//! one-shot `make artifacts`, the `ficabu` binary is self-contained.

pub mod audit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod fisher;
pub mod hwsim;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod testkit;
pub mod unlearn;
pub mod util;
