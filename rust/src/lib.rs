//! # FiCABU — Fisher-based Context-Adaptive Balanced Unlearning
//!
//! Reproduction of "FiCABU: A Fisher-Based, Context-Adaptive Machine
//! Unlearning Processor for Edge AI" (DATE 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (build-time Python): Pallas kernels for the processor's
//!   datapath engines — patch GEMM (VTA backbone), FIMD (diagonal Fisher),
//!   Dampening — in `python/compile/kernels/`.
//! * **L2** (build-time Python): per-segment JAX model graphs (ResNet-18
//!   and ViT topologies), AOT-lowered to HLO text under `artifacts/`.
//! * **L3** (this crate): the unlearning coordinator — back-end-first
//!   Context-Adaptive Unlearning with checkpointed early stop, Balanced
//!   Dampening depth schedule, SSD baseline, INT8 store, the FiCABU
//!   processor cycle/energy simulator, and an edge request loop.
//!
//! Python never runs at request time: `make artifacts` is the only Python
//! step; afterwards the `ficabu` binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod exp;
pub mod data;
pub mod fisher;
pub mod hwsim;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod testkit;
pub mod unlearn;
pub mod util;
