//! INT8 quantization: the true int8 weight representation plus the
//! legacy fake-quant oracle.
//!
//! The FiCABU prototype targets INT8 models (paper §IV-A: "Unless noted
//! otherwise, we target INT8 quantized models"). Since PR 3 the
//! CpuBackend *executes* that operating point: [`QTensor`] stores a
//! GEMM/conv weight as per-output-channel symmetric int8 + scales,
//! activations are quantized per tensor during GEMM panel packing, and
//! the i8 x i8 -> i32 micro-kernel in `runtime::cpu::gemm` requantizes
//! once at the store (`acc * a_scale * w_scale[col]`). The f32 master
//! copy in the `ParamStore` is snapped to the dequantized grid so the
//! gradient chain differentiates exactly the weights the int8 forward
//! executes.
//!
//! [`fake_quant`] (per-tensor quantize→dequantize in f32) is retained as
//! a *test oracle* and for the legacy deployment-assumption mode — it is
//! no longer the execution story.

use super::Tensor;

/// Per-tensor symmetric scale for the int8 range [-127, 127].
pub fn scale_for(data: &[f32]) -> f32 {
    let amax = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        1.0
    } else {
        amax / 127.0
    }
}

/// Quantize one value given a precomputed reciprocal scale. This is THE
/// rounding used by the int8 execution path (packing, oracles, weight
/// stores): multiply by `1/scale`, round half away from zero, saturate
/// to the symmetric [-127, 127] grid. Tiled kernels and scalar oracles
/// must share it bit-for-bit.
#[inline]
pub fn q8(v: f32, inv_scale: f32) -> i8 {
    (v * inv_scale).round().clamp(-127.0, 127.0) as i8
}

/// A weight tensor quantized per *output channel* (the trailing axis:
/// `n` of a dense `[k, n]`, `cout` of an HWIO conv `[kh, kw, cin,
/// cout]`), symmetric int8. The layout of `data` matches the f32
/// source, so the same strided views drive the int8 pack seams.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub shape: Vec<usize>,
    /// Row-major i8 values (same element order as the f32 source).
    pub data: Vec<i8>,
    /// One scale per output channel (trailing-dim column).
    pub scales: Vec<f32>,
}

impl QTensor {
    /// Number of output channels (trailing dimension).
    pub fn cols(&self) -> usize {
        self.scales.len()
    }

    /// Quantize a rank >= 2 weight tensor per trailing-dim channel.
    pub fn from_weight(t: &Tensor) -> QTensor {
        assert!(
            t.shape.len() >= 2,
            "per-channel quantization needs rank >= 2, got {:?}",
            t.shape
        );
        let cols = *t.shape.last().unwrap();
        let rows = t.data.len() / cols;
        let mut scales = vec![0.0f32; cols];
        for r in 0..rows {
            let row = &t.data[r * cols..(r + 1) * cols];
            for (s, v) in scales.iter_mut().zip(row) {
                *s = s.max(v.abs());
            }
        }
        for s in scales.iter_mut() {
            *s = if *s == 0.0 { 1.0 } else { *s / 127.0 };
        }
        let inv: Vec<f32> = scales.iter().map(|s| 1.0 / s).collect();
        let mut data = vec![0i8; t.data.len()];
        for r in 0..rows {
            let src = &t.data[r * cols..(r + 1) * cols];
            let dst = &mut data[r * cols..(r + 1) * cols];
            for c in 0..cols {
                dst[c] = q8(src[c], inv[c]);
            }
        }
        QTensor { shape: t.shape.clone(), data, scales }
    }

    /// Write the dequantized (f32-grid) values into `out` — the master
    /// weight view the f32 gradient chain consumes.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        let cols = self.cols();
        debug_assert_eq!(out.len(), self.data.len());
        for (drow, qrow) in out.chunks_exact_mut(cols).zip(self.data.chunks_exact(cols)) {
            for ((d, &q), &s) in drow.iter_mut().zip(qrow).zip(&self.scales) {
                *d = q as f32 * s;
            }
        }
    }

    /// Dequantized copy (allocating convenience).
    pub fn dequantize(&self) -> Tensor {
        let mut data = vec![0.0f32; self.data.len()];
        self.dequantize_into(&mut data);
        Tensor { shape: self.shape.clone(), data }
    }
}

/// Quantize to int8 with round-to-nearest-even-ish (f32 round).
pub fn quantize(data: &[f32], scale: f32) -> Vec<i8> {
    data.iter()
        .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Snap a tensor onto its per-tensor int8 grid in place; returns the
/// scale. **Test oracle / legacy mode only** — the execution path
/// quantizes per channel through [`QTensor`] and runs integer GEMM.
pub fn fake_quant(t: &mut Tensor) -> f32 {
    let s = scale_for(&t.data);
    for v in t.data.iter_mut() {
        *v = (*v / s).round().clamp(-127.0, 127.0) * s;
    }
    s
}

/// Quantization SNR in dB — used by the INT8 ablation bench.
pub fn quant_snr_db(orig: &[f32], quant: &[f32]) -> f32 {
    let sig: f32 = orig.iter().map(|v| v * v).sum();
    let err: f32 = orig
        .iter()
        .zip(quant)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    if err == 0.0 {
        f32::INFINITY
    } else {
        10.0 * (sig / err).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn roundtrip_error_bounded() {
        let mut r = Pcg32::seeded(3);
        let data = r.normal_vec(4096, 0.5);
        let s = scale_for(&data);
        let deq = dequantize(&quantize(&data, s), s);
        for (a, b) in data.iter().zip(&deq) {
            assert!((a - b).abs() <= s * 0.5 + 1e-7, "{a} vs {b} (s={s})");
        }
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut r = Pcg32::seeded(4);
        let mut t = Tensor::vec1(r.normal_vec(1024, 1.0));
        fake_quant(&mut t);
        let once = t.clone();
        fake_quant(&mut t);
        for (a, b) in once.data.iter().zip(&t.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zeros_stay_zero() {
        let mut t = Tensor::zeros(vec![16]);
        let s = fake_quant(&mut t);
        assert_eq!(s, 1.0);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn qtensor_per_channel_scales_and_roundtrip() {
        // column 1 is 100x larger than column 0: per-channel scales keep
        // the small column's resolution
        let t = Tensor::new(vec![3, 2], vec![0.01, 1.0, -0.02, -2.0, 0.015, 1.5]).unwrap();
        let q = QTensor::from_weight(&t);
        assert_eq!(q.cols(), 2);
        assert!((q.scales[0] - 0.02 / 127.0).abs() < 1e-9);
        assert!((q.scales[1] - 2.0 / 127.0).abs() < 1e-9);
        let d = q.dequantize();
        for (i, (a, b)) in t.data.iter().zip(&d.data).enumerate() {
            let s = q.scales[i % 2];
            assert!((a - b).abs() <= s * 0.5 + 1e-7, "{a} vs {b}");
        }
        // amax columns hit the grid exactly
        assert_eq!(q.data[3], -127);
    }

    #[test]
    fn qtensor_quantize_is_idempotent_on_grid() {
        let mut r = Pcg32::seeded(11);
        let t = Tensor::new(vec![8, 5], r.normal_vec(40, 1.0)).unwrap();
        let q1 = QTensor::from_weight(&t);
        let q2 = QTensor::from_weight(&q1.dequantize());
        assert_eq!(q1.data, q2.data);
        for (a, b) in q1.scales.iter().zip(&q2.scales) {
            assert!((a - b).abs() <= 1e-6 * a.abs(), "{a} vs {b}");
        }
    }

    #[test]
    fn qtensor_zero_column_uses_unit_scale() {
        let t = Tensor::new(vec![2, 2], vec![0.0, 3.0, 0.0, -1.0]).unwrap();
        let q = QTensor::from_weight(&t);
        assert_eq!(q.scales[0], 1.0);
        assert_eq!(q.data[0], 0);
        assert_eq!(q.data[2], 0);
    }

    #[test]
    fn snr_reasonable() {
        let mut r = Pcg32::seeded(5);
        let data = r.normal_vec(8192, 1.0);
        let mut t = Tensor::vec1(data.clone());
        fake_quant(&mut t);
        let snr = quant_snr_db(&data, &t.data);
        // int8 on gaussian data: expect > 30 dB
        assert!(snr > 30.0, "snr {snr}");
    }
}
