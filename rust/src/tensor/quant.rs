//! INT8 per-tensor symmetric fake-quantization.
//!
//! The FiCABU prototype targets INT8 models (paper §IV-A: "Unless noted
//! otherwise, we target INT8 quantized models"). The compiled XLA modules
//! are f32, so we reproduce the INT8 operating point by quantize→dequantize
//! of weights (and optionally activations): values are snapped onto the
//! 256-level grid the hardware would see, and the hwsim charges INT8 MAC
//! energy. DESIGN.md §2 records this substitution.

use super::Tensor;

/// Per-tensor symmetric scale for the int8 range [-127, 127].
pub fn scale_for(data: &[f32]) -> f32 {
    let amax = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        1.0
    } else {
        amax / 127.0
    }
}

/// Quantize to int8 with round-to-nearest-even-ish (f32 round).
pub fn quantize(data: &[f32], scale: f32) -> Vec<i8> {
    data.iter()
        .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Snap a tensor onto its int8 grid in place; returns the scale.
pub fn fake_quant(t: &mut Tensor) -> f32 {
    let s = scale_for(&t.data);
    for v in t.data.iter_mut() {
        *v = (*v / s).round().clamp(-127.0, 127.0) * s;
    }
    s
}

/// Quantization SNR in dB — used by the INT8 ablation bench.
pub fn quant_snr_db(orig: &[f32], quant: &[f32]) -> f32 {
    let sig: f32 = orig.iter().map(|v| v * v).sum();
    let err: f32 = orig
        .iter()
        .zip(quant)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    if err == 0.0 {
        f32::INFINITY
    } else {
        10.0 * (sig / err).log10()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn roundtrip_error_bounded() {
        let mut r = Pcg32::seeded(3);
        let data = r.normal_vec(4096, 0.5);
        let s = scale_for(&data);
        let deq = dequantize(&quantize(&data, s), s);
        for (a, b) in data.iter().zip(&deq) {
            assert!((a - b).abs() <= s * 0.5 + 1e-7, "{a} vs {b} (s={s})");
        }
    }

    #[test]
    fn fake_quant_idempotent() {
        let mut r = Pcg32::seeded(4);
        let mut t = Tensor::vec1(r.normal_vec(1024, 1.0));
        fake_quant(&mut t);
        let once = t.clone();
        fake_quant(&mut t);
        for (a, b) in once.data.iter().zip(&t.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zeros_stay_zero() {
        let mut t = Tensor::zeros(vec![16]);
        let s = fake_quant(&mut t);
        assert_eq!(s, 1.0);
        assert!(t.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn snr_reasonable() {
        let mut r = Pcg32::seeded(5);
        let data = r.normal_vec(8192, 1.0);
        let mut t = Tensor::vec1(data.clone());
        fake_quant(&mut t);
        let snr = quant_snr_db(&data, &t.data);
        // int8 on gaussian data: expect > 30 dB
        assert!(snr > 30.0, "snr {snr}");
    }
}
