//! Dense row-major f32 tensor — the host-side value type of the coordinator.
//!
//! Device math happens inside compiled XLA executables; this type only
//! needs construction, batch slicing/padding (for the micro-batched FIMD
//! stream), flattening into the tile bursts the engine modules consume, and
//! the small readout ops the metrics use (argmax, softmax rows).

pub mod quant;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn vec1(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Leading (batch) dimension, or 1 for scalars.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(1)
    }

    /// Elements per sample (product of non-batch dims).
    pub fn sample_len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[1..].iter().product()
        }
    }

    /// Slice `[start, start+count)` along the batch dimension (contiguous
    /// in row-major, so this is a memcpy).
    pub fn slice_batch(&self, start: usize, count: usize) -> Result<Tensor> {
        let b = self.batch();
        if start + count > b {
            bail!("batch slice {}..{} out of {}", start, start + count, b);
        }
        let s = self.sample_len();
        let mut shape = self.shape.clone();
        shape[0] = count;
        Ok(Tensor {
            shape,
            data: self.data[start * s..(start + count) * s].to_vec(),
        })
    }

    /// Stack sample-tensors along a new batch dim, padding with repeats of
    /// the final sample if fewer than `batch` are given (XLA modules have a
    /// static batch; metrics mask the padding back out).
    pub fn stack_pad(samples: &[&[f32]], sample_shape: &[usize], batch: usize) -> Result<Tensor> {
        if samples.is_empty() || samples.len() > batch {
            bail!("stack_pad: {} samples for batch {}", samples.len(), batch);
        }
        let s: usize = sample_shape.iter().product();
        let mut data = Vec::with_capacity(batch * s);
        for x in samples {
            if x.len() != s {
                bail!("stack_pad: sample len {} != {}", x.len(), s);
            }
            data.extend_from_slice(x);
        }
        let last = samples[samples.len() - 1];
        for _ in samples.len()..batch {
            data.extend_from_slice(last);
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(sample_shape);
        Tensor::new(shape, data)
    }

    /// View row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.sample_len();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.batch())
            .map(|i| {
                let r = self.row(i);
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Row-wise softmax (used by the MIA / loss metrics on logits).
    pub fn softmax_rows(&self) -> Tensor {
        let c = self.sample_len();
        let mut out = self.clone();
        for i in 0..self.batch() {
            let r = &mut out.data[i * c..(i + 1) * c];
            let m = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0;
            for v in r.iter_mut() {
                *v = (*v - m).exp();
                z += *v;
            }
            for v in r.iter_mut() {
                *v /= z;
            }
        }
        out
    }

    pub fn l2(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn batch_slice() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect()).unwrap();
        let s = t.slice_batch(1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
        assert!(t.slice_batch(3, 2).is_err());
    }

    #[test]
    fn stack_pad_repeats_last() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let t = Tensor::stack_pad(&[&a, &b], &[2], 4).unwrap();
        assert_eq!(t.shape, vec![4, 2]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0, 3.0, 4.0, 3.0, 4.0]);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        let s = t.softmax_rows();
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        assert!(s.row(0)[2] > s.row(0)[1]);
    }
}
