//! Diagonal-Fisher importance: storage + the FIMD engine stream.
//!
//! Importance is stored per segment as one flat f32 buffer covering the
//! segment's parameters in meta order (the same contiguous layout the
//! hardware IP sees as DMA bursts). `FimdEngine` streams gradient bursts
//! through the compiled Pallas FIMD module tile by tile — eq. (2):
//! `I_i = E[(d ln p(D_f|theta) / d theta_i)^2]`, accumulated as
//! `acc += scale * g^2` per microbatch.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{ModelMeta, SharedMeta};
use crate::model::{Model, ParamStore};
use crate::runtime::{Executable, ModuleSpec, Runtime};
use crate::tensor::Tensor;

/// Per-segment flat importance buffers (`I_D` or `I_Df`).
#[derive(Clone, Debug)]
pub struct Importance {
    pub per_seg: Vec<Vec<f32>>,
}

impl Importance {
    pub fn zeros_like(meta: &ModelMeta) -> Importance {
        Importance {
            per_seg: meta
                .segments
                .iter()
                .map(|s| vec![0.0; s.param_count()])
                .collect(),
        }
    }

    pub fn total_len(&self) -> usize {
        self.per_seg.iter().map(|v| v.len()).sum()
    }

    /// Elementwise max with a floor — used to keep stored global
    /// importance strictly positive (a zero `I_D` would make the
    /// selection threshold trivially satisfiable).
    pub fn floor(&mut self, eps: f32) {
        for seg in self.per_seg.iter_mut() {
            for v in seg.iter_mut() {
                if *v < eps {
                    *v = eps;
                }
            }
        }
    }

    // --- persistence (same container format idea as ParamStore) ---------

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"FICABIM1");
        buf.extend_from_slice(&(self.per_seg.len() as u32).to_le_bytes());
        for seg in &self.per_seg {
            buf.extend_from_slice(&(seg.len() as u32).to_le_bytes());
            for v in seg {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        if let Some(p) = path.as_ref().parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, buf)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Importance> {
        let b = std::fs::read(path)?;
        if b.len() < 12 || &b[..8] != b"FICABIM1" {
            bail!("bad importance file");
        }
        let mut pos = 8;
        let mut rd_u32 = |pos: &mut usize| -> Result<u32> {
            if *pos + 4 > b.len() {
                bail!("truncated importance file");
            }
            let v = u32::from_le_bytes([b[*pos], b[*pos + 1], b[*pos + 2], b[*pos + 3]]);
            *pos += 4;
            Ok(v)
        };
        let nseg = rd_u32(&mut pos)? as usize;
        let mut per_seg = Vec::with_capacity(nseg);
        for _ in 0..nseg {
            let n = rd_u32(&mut pos)? as usize;
            if pos + 4 * n > b.len() {
                bail!("truncated importance data");
            }
            let seg = b[pos..pos + 4 * n]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            pos += 4 * n;
            per_seg.push(seg);
        }
        Ok(Importance { per_seg })
    }
}

/// Concatenate a segment's gradient tensors into a caller-owned burst
/// buffer (meta parameter order — must mirror the dampening
/// write-back). The buffer is cleared and refilled, so one allocation
/// serves every microbatch of every segment in the hot loop.
pub fn concat_seg_into(tensors: &[Tensor], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(tensors.iter().map(|t| t.len()).sum());
    for t in tensors {
        out.extend_from_slice(&t.data);
    }
}

/// Concatenate into a fresh buffer (allocating convenience).
pub fn concat_seg(tensors: &[Tensor]) -> Vec<f32> {
    let mut out = Vec::new();
    concat_seg_into(tensors, &mut out);
    out
}

/// The FIMD IP: streams (grad, acc) tile pairs through the compiled Pallas
/// module. Tiles are fixed-size bursts; the tail is zero-padded (padding
/// squares to zero, so accumulation is exact).
pub struct FimdEngine {
    exe: Arc<Executable>,
    pub tile: usize,
    /// *Real* elements streamed (feeds the hwsim cycle/traffic model).
    pub elems_streamed: std::cell::Cell<u64>,
    /// Zero-pad lanes of tail bursts, counted separately: they occupy
    /// IP cycles but never move over DDR (and previously inflated
    /// `elems_streamed` by a full tile per non-divisible segment).
    pub pad_elems: std::cell::Cell<u64>,
}

impl FimdEngine {
    pub fn new(rt: &Runtime, shared: &SharedMeta) -> Result<FimdEngine> {
        Ok(FimdEngine {
            exe: rt.load(&ModuleSpec::Fimd { shared: shared.clone() })?,
            tile: shared.tile,
            elems_streamed: std::cell::Cell::new(0),
            pad_elems: std::cell::Cell::new(0),
        })
    }

    /// `acc[i] += scale * grads[i]^2` for a whole segment buffer.
    /// The two tile buffers are hoisted out of the tile loop — one
    /// allocation pair per call, not per tile (and the module reuses
    /// them across every full tile; only the tail rewrites its padding).
    pub fn accumulate(&self, acc: &mut [f32], grads: &[f32], scale: f32) -> Result<()> {
        if acc.len() != grads.len() {
            bail!("fimd: acc {} vs grads {}", acc.len(), grads.len());
        }
        let t = self.tile;
        let scale_t = Tensor::vec1(vec![scale]);
        let mut gbuf = Tensor::vec1(vec![0.0f32; t]);
        let mut abuf = Tensor::vec1(vec![0.0f32; t]);
        let mut off = 0;
        while off < acc.len() {
            let n = t.min(acc.len() - off);
            gbuf.data[..n].copy_from_slice(&grads[off..off + n]);
            abuf.data[..n].copy_from_slice(&acc[off..off + n]);
            if n < t {
                gbuf.data[n..].fill(0.0);
                abuf.data[n..].fill(0.0);
            }
            let out = self.exe.run(&[&gbuf, &abuf, &scale_t])?;
            acc[off..off + n].copy_from_slice(&out[0].data[..n]);
            self.elems_streamed.set(self.elems_streamed.get() + n as u64);
            self.pad_elems.set(self.pad_elems.get() + (t - n) as u64);
            off += n;
        }
        Ok(())
    }
}

/// Compute the stored global importance `I_D` (paper §II): full
/// backward-stream over `batches` of representative data, squared-grad
/// accumulated over every microbatch of every batch. Computed once after
/// training and persisted alongside the checkpoint.
pub fn compute_global_importance(
    model: &Model,
    params: &ParamStore,
    engine: &FimdEngine,
    batches: &[(Tensor, Tensor)], // (x [B,...], onehot [B,C])
) -> Result<Importance> {
    let meta = &model.meta;
    let mb_size = meta.microbatch;
    let num_mb = meta.batch / mb_size;
    let mut imp = Importance::zeros_like(meta);
    let scale = 1.0 / (batches.len() * num_mb) as f32;

    let mut burst: Vec<f32> = Vec::new();
    for (x, onehot) in batches {
        let cache = model.forward_cached(params, x)?;
        for mb in 0..num_mb {
            let logits_mb = cache.microbatch_logits(mb, mb_size)?;
            let onehot_mb = onehot.slice_batch(mb * mb_size, mb_size)?;
            let mut gy = model.loss_grad(&logits_mb, &onehot_mb)?;
            // back-end-first segment stream (same direction as hardware)
            for k in (0..meta.num_segments()).rev() {
                let x_mb = cache.microbatch_input(k, mb, mb_size)?;
                let (grads, gx) = model.segment_bwd(k, params, &x_mb, &gy)?;
                concat_seg_into(&grads, &mut burst);
                engine.accumulate(&mut imp.per_seg[k], &burst, scale)?;
                gy = gx;
            }
        }
    }
    Ok(imp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fimd_engine_matches_scalar_math() {
        let rt = Runtime::cpu().unwrap();
        let shared = SharedMeta::builtin();
        let eng = FimdEngine::new(&rt, &shared).unwrap();
        // odd length exercises tail padding
        let n = shared.tile + 1234;
        let grads: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut acc = vec![0.5f32; n];
        eng.accumulate(&mut acc, &grads, 0.25).unwrap();
        for i in (0..n).step_by(997) {
            let want = 0.5 + 0.25 * grads[i] * grads[i];
            assert!((acc[i] - want).abs() < 1e-6, "{i}");
        }
        // real/pad split: the tail tile must charge only its real lanes
        // as streamed elements, the zero filler as pad cycles — and the
        // two must add up to the burst train the IP actually clocked.
        assert_eq!(eng.elems_streamed.get(), n as u64);
        assert_eq!(eng.pad_elems.get(), (shared.tile - 1234) as u64);
        assert_eq!(
            eng.elems_streamed.get() + eng.pad_elems.get(),
            2 * shared.tile as u64
        );
    }

    #[test]
    fn importance_roundtrip() {
        let imp = Importance { per_seg: vec![vec![1.0, 2.0], vec![3.0]] };
        let dir = std::env::temp_dir().join("ficabu_imp_test");
        let p = dir.join("i.bin");
        imp.save(&p).unwrap();
        let back = Importance::load(&p).unwrap();
        assert_eq!(back.per_seg, imp.per_seg);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn floor_applies() {
        let mut imp = Importance { per_seg: vec![vec![0.0, 5.0]] };
        imp.floor(1e-8);
        assert_eq!(imp.per_seg[0], vec![1e-8, 5.0]);
    }

    #[test]
    fn concat_order() {
        let a = Tensor::vec1(vec![1.0, 2.0]);
        let b = Tensor::vec1(vec![3.0]);
        assert_eq!(concat_seg(&[a, b]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn concat_into_reuses_buffer() {
        let a = Tensor::vec1(vec![1.0, 2.0, 3.0]);
        let b = Tensor::vec1(vec![4.0]);
        let mut buf = Vec::new();
        concat_seg_into(&[a.clone(), b], &mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0, 4.0]);
        let cap = buf.capacity();
        concat_seg_into(&[a], &mut buf);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        assert_eq!(buf.capacity(), cap, "refill must not reallocate");
    }
}
