//! Artifact metadata: parse `artifacts/<model>/meta.json` and
//! `artifacts/shared/shared.json` (written once by `python -m compile.aot`)
//! into the typed inventory the coordinator drives the compiled modules
//! with. Argument *order* is the contract: module args are
//! `(params in listed order, x[, gy])` and outputs mirror the meta.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub name: String,
    pub kind: String,
    pub params: Vec<ParamMeta>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub macs_fwd_per_sample: u64,
    pub fwd: String,
    pub bwd: String,
}

impl SegmentMeta {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub name: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub batch: usize,
    pub microbatch: usize,
    pub tile: usize,
    pub segments: Vec<SegmentMeta>,
    pub logits_module: String,
    pub train_step_module: String,
    pub loss_grad_module: String,
}

impl ModelMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelMeta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut segments = Vec::new();
        for s in j.req("segments")?.as_arr().context("segments not array")? {
            let params = s
                .req("params")?
                .as_arr()
                .context("params not array")?
                .iter()
                .map(|p| {
                    Ok(ParamMeta {
                        name: p.req("name")?.as_str().context("param name")?.to_string(),
                        shape: p.req("shape")?.usize_list()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            segments.push(SegmentMeta {
                name: s.req("name")?.as_str().context("name")?.to_string(),
                kind: s.req("kind")?.as_str().context("kind")?.to_string(),
                params,
                in_shape: s.req("in_shape")?.usize_list()?,
                out_shape: s.req("out_shape")?.usize_list()?,
                macs_fwd_per_sample: s
                    .req("macs_fwd_per_sample")?
                    .as_f64()
                    .context("macs")? as u64,
                fwd: s.req("fwd")?.as_str().context("fwd")?.to_string(),
                bwd: s.req("bwd")?.as_str().context("bwd")?.to_string(),
            });
        }
        let modules = j.req("modules")?;
        Ok(ModelMeta {
            dir,
            name: j.req("name")?.as_str().context("name")?.to_string(),
            num_classes: j.req("num_classes")?.as_usize().context("num_classes")?,
            input_shape: j.req("input_shape")?.usize_list()?,
            batch: j.req("batch")?.as_usize().context("batch")?,
            microbatch: j.req("microbatch")?.as_usize().context("microbatch")?,
            tile: j.req("tile")?.as_usize().context("tile")?,
            segments,
            logits_module: modules.req("logits")?.as_str().context("logits")?.to_string(),
            train_step_module: modules
                .req("train_step")?
                .as_str()
                .context("train_step")?
                .to_string(),
            loss_grad_module: modules
                .req("loss_grad")?
                .as_str()
                .context("loss_grad")?
                .to_string(),
        })
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Paper depth index: last segment (head) -> l = 1; first -> l = L.
    pub fn depth_l(&self, seg_index: usize) -> usize {
        self.num_segments() - seg_index
    }

    /// Segment index for a given depth l (inverse of `depth_l`).
    pub fn seg_index(&self, l: usize) -> usize {
        self.num_segments() - l
    }

    pub fn total_params(&self) -> usize {
        self.segments.iter().map(|s| s.param_count()).sum()
    }

    pub fn module_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[derive(Debug, Clone)]
pub struct SharedMeta {
    pub dir: PathBuf,
    pub tile: usize,
    pub fimd: String,
    pub dampen: String,
    pub gemm: String,
    pub gemm_demo: usize,
}

impl SharedMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<SharedMeta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("shared.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)?;
        let m = j.req("modules")?;
        Ok(SharedMeta {
            dir,
            tile: j.req("tile")?.as_usize().context("tile")?,
            fimd: m.req("fimd")?.as_str().context("fimd")?.to_string(),
            dampen: m.req("dampen")?.as_str().context("dampen")?.to_string(),
            gemm: m.req("gemm")?.as_str().context("gemm")?.to_string(),
            gemm_demo: j.req("gemm_demo")?.as_usize().context("gemm_demo")?,
        })
    }

    pub fn module_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// Locate the artifacts root: $FICABU_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("FICABU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> PathBuf {
        // tests run from rust/; artifacts live at the workspace root
        let ws = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        ws.join("artifacts")
    }

    #[test]
    fn load_rn18slim_meta() {
        let m = ModelMeta::load(art().join("rn18slim")).unwrap();
        assert_eq!(m.name, "rn18slim");
        assert_eq!(m.num_classes, 20);
        assert_eq!(m.num_segments(), 10);
        assert_eq!(m.segments[0].kind, "stem");
        assert_eq!(m.segments[9].kind, "head");
        assert_eq!(m.input_shape, vec![32, 32, 3]);
        // depth indexing: head is l=1, stem is l=L
        assert_eq!(m.depth_l(9), 1);
        assert_eq!(m.depth_l(0), 10);
        assert_eq!(m.seg_index(1), 9);
        assert!(m.total_params() > 100_000);
    }

    #[test]
    fn load_vitslim_meta() {
        let m = ModelMeta::load(art().join("vitslim")).unwrap();
        assert_eq!(m.num_segments(), 14);
        assert_eq!(
            m.segments.iter().filter(|s| s.kind == "encoder").count(),
            12
        );
    }

    #[test]
    fn load_shared_meta() {
        let s = SharedMeta::load(art().join("shared")).unwrap();
        assert_eq!(s.tile % 1024, 0);
        assert!(s.module_path(&s.fimd).exists());
        assert!(s.module_path(&s.dampen).exists());
    }

    #[test]
    fn segment_shapes_chain() {
        for name in ["rn18slim", "vitslim"] {
            let m = ModelMeta::load(art().join(name)).unwrap();
            for w in m.segments.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape);
            }
            assert_eq!(
                m.segments.last().unwrap().out_shape,
                vec![m.num_classes]
            );
        }
    }
}
