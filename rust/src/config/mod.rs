//! Model/engine inventories the coordinator drives the modules with.
//!
//! Two sources, one type: [`ModelMeta::resolve`] loads
//! `artifacts/<model>/meta.json` when the Python AOT export has been run
//! (`make artifacts`, needed only for the `backend-xla` feature) and
//! falls back to the [`builtin`] pure-Rust inventories otherwise, so the
//! default CpuBackend needs no Python artifacts at all. Argument *order*
//! is the contract: module args are `(params in listed order, x[, gy])`
//! and outputs mirror the meta.

pub mod builtin;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct SegmentMeta {
    pub name: String,
    pub kind: String,
    pub params: Vec<ParamMeta>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub macs_fwd_per_sample: u64,
    pub fwd: String,
    pub bwd: String,
}

impl SegmentMeta {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dir: PathBuf,
    pub name: String,
    pub num_classes: usize,
    pub input_shape: Vec<usize>,
    pub batch: usize,
    pub microbatch: usize,
    pub tile: usize,
    /// Attention heads of encoder segments (4 for vitslim; unused by
    /// convolutional models). Absent from older meta.json files.
    pub heads: usize,
    pub segments: Vec<SegmentMeta>,
    pub logits_module: String,
    pub train_step_module: String,
    pub loss_grad_module: String,
}

impl ModelMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelMeta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let mut segments = Vec::new();
        for s in j.req("segments")?.as_arr().context("segments not array")? {
            let params = s
                .req("params")?
                .as_arr()
                .context("params not array")?
                .iter()
                .map(|p| {
                    Ok(ParamMeta {
                        name: p.req("name")?.as_str().context("param name")?.to_string(),
                        shape: p.req("shape")?.usize_list()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            segments.push(SegmentMeta {
                name: s.req("name")?.as_str().context("name")?.to_string(),
                kind: s.req("kind")?.as_str().context("kind")?.to_string(),
                params,
                in_shape: s.req("in_shape")?.usize_list()?,
                out_shape: s.req("out_shape")?.usize_list()?,
                macs_fwd_per_sample: s
                    .req("macs_fwd_per_sample")?
                    .as_f64()
                    .context("macs")? as u64,
                fwd: s.req("fwd")?.as_str().context("fwd")?.to_string(),
                bwd: s.req("bwd")?.as_str().context("bwd")?.to_string(),
            });
        }
        let modules = j.req("modules")?;
        // `heads` is semantically load-bearing for encoder segments (the
        // CPU interpreter rebuilds the attention head split from it), so
        // a meta that ships encoders must state it explicitly; for conv
        // inventories the value is unused.
        let heads = match j.get("heads").and_then(|v| v.as_usize()) {
            Some(h) => h,
            None if segments.iter().any(|s| s.kind == "encoder") => {
                anyhow::bail!(
                    "meta.json has encoder segments but no `heads` key \
                     (re-export artifacts with the current compile.aot)"
                )
            }
            None => builtin::VIT_HEADS,
        };
        Ok(ModelMeta {
            dir,
            name: j.req("name")?.as_str().context("name")?.to_string(),
            num_classes: j.req("num_classes")?.as_usize().context("num_classes")?,
            input_shape: j.req("input_shape")?.usize_list()?,
            batch: j.req("batch")?.as_usize().context("batch")?,
            microbatch: j.req("microbatch")?.as_usize().context("microbatch")?,
            tile: j.req("tile")?.as_usize().context("tile")?,
            heads,
            segments,
            logits_module: modules.req("logits")?.as_str().context("logits")?.to_string(),
            train_step_module: modules
                .req("train_step")?
                .as_str()
                .context("train_step")?
                .to_string(),
            loss_grad_module: modules
                .req("loss_grad")?
                .as_str()
                .context("loss_grad")?
                .to_string(),
        })
    }

    /// The built-in (pure Rust) inventory for a known model name.
    pub fn builtin(name: &str) -> Result<ModelMeta> {
        builtin::model(name)
    }

    /// Artifacts if exported, builtin otherwise — the default entry point.
    pub fn resolve(name: &str) -> Result<ModelMeta> {
        let dir = artifacts_root().join(name);
        if dir.join("meta.json").exists() {
            ModelMeta::load(dir)
        } else {
            ModelMeta::builtin(name)
        }
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// Paper depth index: last segment (head) -> l = 1; first -> l = L.
    pub fn depth_l(&self, seg_index: usize) -> usize {
        self.num_segments() - seg_index
    }

    /// Segment index for a given depth l (inverse of `depth_l`).
    pub fn seg_index(&self, l: usize) -> usize {
        self.num_segments() - l
    }

    pub fn total_params(&self) -> usize {
        self.segments.iter().map(|s| s.param_count()).sum()
    }

    pub fn module_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[derive(Debug, Clone)]
pub struct SharedMeta {
    pub dir: PathBuf,
    pub tile: usize,
    pub fimd: String,
    pub dampen: String,
    pub gemm: String,
    pub gemm_demo: usize,
}

impl SharedMeta {
    pub fn load(dir: impl AsRef<Path>) -> Result<SharedMeta> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("shared.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)?;
        let m = j.req("modules")?;
        Ok(SharedMeta {
            dir,
            tile: j.req("tile")?.as_usize().context("tile")?,
            fimd: m.req("fimd")?.as_str().context("fimd")?.to_string(),
            dampen: m.req("dampen")?.as_str().context("dampen")?.to_string(),
            gemm: m.req("gemm")?.as_str().context("gemm")?.to_string(),
            gemm_demo: j.req("gemm_demo")?.as_usize().context("gemm_demo")?,
        })
    }

    /// The built-in shared-engine inventory.
    pub fn builtin() -> SharedMeta {
        builtin::shared()
    }

    /// Artifacts if exported, builtin otherwise.
    pub fn resolve() -> Result<SharedMeta> {
        let dir = artifacts_root().join("shared");
        if dir.join("shared.json").exists() {
            SharedMeta::load(dir)
        } else {
            Ok(SharedMeta::builtin())
        }
    }

    pub fn module_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// Locate the artifacts root: $FICABU_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("FICABU_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_falls_back_to_builtin() {
        // no artifacts in the test environment -> builtin inventory
        let m = ModelMeta::resolve("rn18slim").unwrap();
        assert_eq!(m.name, "rn18slim");
        assert_eq!(m.num_classes, 20);
        assert_eq!(m.num_segments(), 10);
        // depth indexing: head is l=1, stem is l=L
        assert_eq!(m.depth_l(9), 1);
        assert_eq!(m.depth_l(0), 10);
        assert_eq!(m.seg_index(1), 9);
        assert!(ModelMeta::resolve("nope").is_err());
    }

    #[test]
    fn meta_json_roundtrip_shapes() {
        // a hand-rolled meta.json exercising the artifact parse path
        let dir = std::env::temp_dir().join("ficabu_cfg_meta");
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
 "name": "toy", "num_classes": 2, "input_shape": [4, 4, 3],
 "batch": 8, "microbatch": 2, "tile": 1024,
 "segments": [
  {"name": "stem", "kind": "stem",
   "params": [{"name": "w", "shape": [3, 3, 3, 4]},
              {"name": "gamma", "shape": [4]},
              {"name": "beta", "shape": [4]}],
   "in_shape": [4, 4, 3], "out_shape": [4, 4, 4],
   "macs_fwd_per_sample": 1728,
   "fwd": "fwd_00.hlo.txt", "bwd": "bwd_00.hlo.txt"}
 ],
 "modules": {"logits": "logits.hlo.txt",
             "train_step": "train_step.hlo.txt",
             "loss_grad": "loss_grad.hlo.txt"}
}"#;
        std::fs::write(dir.join("meta.json"), text).unwrap();
        let m = ModelMeta::load(&dir).unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.segments[0].params[0].shape, vec![3, 3, 3, 4]);
        assert_eq!(m.segments[0].param_count(), 108 + 4 + 4);
        // `heads` absent -> default
        assert_eq!(m.heads, builtin::VIT_HEADS);
        assert_eq!(m.module_path("x.hlo.txt"), dir.join("x.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_resolve_builtin() {
        let s = SharedMeta::resolve().unwrap();
        assert_eq!(s.tile % 1024, 0);
        assert_eq!(s.tile, builtin::TILE);
    }

    #[test]
    fn segment_shapes_chain() {
        for name in ["rn18slim", "vitslim"] {
            let m = ModelMeta::builtin(name).unwrap();
            for w in m.segments.windows(2) {
                assert_eq!(w[0].out_shape, w[1].in_shape);
            }
            assert_eq!(
                m.segments.last().unwrap().out_shape,
                vec![m.num_classes]
            );
        }
    }
}
