//! Built-in model inventories — the Rust port of `python/compile/model.py`.
//!
//! The seed pipeline obtained `meta.json` from the Python AOT export
//! (`make artifacts`). That made the whole coordinator unusable without a
//! JAX toolchain. The topology is static, so the same inventories (segment
//! names/kinds, parameter shapes, activation shapes, analytic MAC counts)
//! are constructed here in pure Rust; `ModelMeta::resolve` prefers an
//! on-disk `meta.json` when one exists (so `make artifacts` keeps working
//! for the XLA path) and falls back to these builtins otherwise.
//!
//! Keep the numbers in lockstep with `python/compile/model.py` and
//! `python/compile/aot.py`: the AOT export writes the same inventory to
//! `meta.json`, and the golden tests compare the two worlds.

use anyhow::{bail, Result};

use super::{artifacts_root, ModelMeta, ParamMeta, SegmentMeta, SharedMeta};

/// Forget-batch / eval batch size N (aot.py BATCH).
pub const BATCH: usize = 64;
/// Fisher micro-batch size (aot.py MICROBATCH).
pub const MICROBATCH: usize = 8;
/// Engine burst tile, elements (kernels/fimd.py TILE).
pub const TILE: usize = 8192;
/// Shared GEMM demo module dimension (aot.py GEMM_DEMO).
pub const GEMM_DEMO: usize = 256;
/// Attention heads of the vitslim encoder (model.py build_vitslim).
pub const VIT_HEADS: usize = 4;

/// GroupNorm group count (model.py GN_GROUPS).
pub const GN_GROUPS: usize = 4;
/// GroupNorm / LayerNorm epsilon (model.py GN_EPS / LN_EPS).
pub const NORM_EPS: f32 = 1e-5;

fn p(name: &str, shape: &[usize]) -> ParamMeta {
    ParamMeta { name: name.to_string(), shape: shape.to_vec() }
}

fn conv_macs(hw_out: usize, cin: usize, cout: usize, k: usize) -> u64 {
    (hw_out * hw_out * cout * cin * k * k) as u64
}

fn seg(
    index: usize,
    name: &str,
    kind: &str,
    params: Vec<ParamMeta>,
    in_shape: Vec<usize>,
    out_shape: Vec<usize>,
    macs: u64,
) -> SegmentMeta {
    SegmentMeta {
        name: name.to_string(),
        kind: kind.to_string(),
        params,
        in_shape,
        out_shape,
        macs_fwd_per_sample: macs,
        fwd: format!("fwd_{index:02}.hlo.txt"),
        bwd: format!("bwd_{index:02}.hlo.txt"),
    }
}

/// ResNet-18 topology at reduced width (stage widths w, 2w, 4w, 8w).
fn rn18slim(num_classes: usize, width: usize, img: usize) -> ModelMeta {
    let mut segments = Vec::new();
    let w0 = width;

    segments.push(seg(
        0,
        "stem",
        "stem",
        vec![p("w", &[3, 3, 3, w0]), p("gamma", &[w0]), p("beta", &[w0])],
        vec![img, img, 3],
        vec![img, img, w0],
        conv_macs(img, 3, w0, 3),
    ));

    let stage_widths = [w0, 2 * w0, 4 * w0, 8 * w0];
    let mut hw = img;
    let mut cin = w0;
    for (s, &cout) in stage_widths.iter().enumerate() {
        for b in 0..2 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let down = stride != 1 || cin != cout;
            let hw_out = hw / stride;
            let mut params = vec![
                p("w1", &[3, 3, cin, cout]),
                p("g1", &[cout]),
                p("b1", &[cout]),
                p("w2", &[3, 3, cout, cout]),
                p("g2", &[cout]),
                p("b2", &[cout]),
            ];
            if down {
                params.push(p("wd", &[1, 1, cin, cout]));
                params.push(p("gd", &[cout]));
                params.push(p("bd", &[cout]));
            }
            let macs = conv_macs(hw_out, cin, cout, 3)
                + conv_macs(hw_out, cout, cout, 3)
                + if down { conv_macs(hw_out, cin, cout, 1) } else { 0 };
            segments.push(seg(
                segments.len(),
                &format!("s{}b{}", s + 1, b + 1),
                "block",
                params,
                vec![hw, hw, cin],
                vec![hw_out, hw_out, cout],
                macs,
            ));
            hw = hw_out;
            cin = cout;
        }
    }

    let cfin = stage_widths[3];
    segments.push(seg(
        segments.len(),
        "head",
        "head",
        vec![p("w", &[cfin, num_classes]), p("b", &[num_classes])],
        vec![hw, hw, cfin],
        vec![num_classes],
        (cfin * num_classes) as u64,
    ));

    finish("rn18slim", num_classes, vec![img, img, 3], segments)
}

/// ViT topology: patch embed + 12 pre-LN encoders + mean-pool head.
fn vitslim(
    num_classes: usize,
    dim: usize,
    depth: usize,
    heads: usize,
    mlp_ratio: usize,
    patch: usize,
    img: usize,
) -> ModelMeta {
    let tokens = (img / patch) * (img / patch);
    let hdim = dim / heads;
    let mlp = dim * mlp_ratio;
    let mut segments = Vec::new();

    segments.push(seg(
        0,
        "embed",
        "embed",
        vec![
            p("w", &[patch * patch * 3, dim]),
            p("b", &[dim]),
            p("pos", &[tokens, dim]),
        ],
        vec![img, img, 3],
        vec![tokens, dim],
        (tokens * patch * patch * 3 * dim) as u64,
    ));

    let enc_macs = (tokens * dim * 3 * dim
        + 2 * heads * tokens * tokens * hdim
        + tokens * dim * dim
        + 2 * tokens * dim * mlp) as u64;
    for i in 0..depth {
        segments.push(seg(
            segments.len(),
            &format!("enc{}", i + 1),
            "encoder",
            vec![
                p("ln1g", &[dim]),
                p("ln1b", &[dim]),
                p("wqkv", &[dim, 3 * dim]),
                p("bqkv", &[3 * dim]),
                p("wproj", &[dim, dim]),
                p("bproj", &[dim]),
                p("ln2g", &[dim]),
                p("ln2b", &[dim]),
                p("w1", &[dim, mlp]),
                p("b1", &[mlp]),
                p("w2", &[mlp, dim]),
                p("b2", &[dim]),
            ],
            vec![tokens, dim],
            vec![tokens, dim],
            enc_macs,
        ));
    }

    segments.push(seg(
        segments.len(),
        "head",
        "head",
        vec![
            p("lng", &[dim]),
            p("lnb", &[dim]),
            p("w", &[dim, num_classes]),
            p("b", &[num_classes]),
        ],
        vec![tokens, dim],
        vec![num_classes],
        (dim * num_classes) as u64,
    ));

    finish("vitslim", num_classes, vec![img, img, 3], segments)
}

fn finish(
    name: &str,
    num_classes: usize,
    input_shape: Vec<usize>,
    segments: Vec<SegmentMeta>,
) -> ModelMeta {
    ModelMeta {
        dir: artifacts_root().join(name),
        name: name.to_string(),
        num_classes,
        input_shape,
        batch: BATCH,
        microbatch: MICROBATCH,
        tile: TILE,
        heads: VIT_HEADS,
        segments,
        logits_module: "logits.hlo.txt".to_string(),
        train_step_module: "train_step.hlo.txt".to_string(),
        loss_grad_module: "loss_grad.hlo.txt".to_string(),
    }
}

/// The built-in inventory for a known model name.
pub fn model(name: &str) -> Result<ModelMeta> {
    match name {
        "rn18slim" => Ok(rn18slim(20, 8, 32)),
        "vitslim" => Ok(vitslim(20, 32, 12, VIT_HEADS, 2, 4, 32)),
        _ => bail!("unknown builtin model `{name}` (rn18slim | vitslim)"),
    }
}

/// The built-in shared-engine inventory (burst geometry + module names).
pub fn shared() -> SharedMeta {
    SharedMeta {
        dir: artifacts_root().join("shared"),
        tile: TILE,
        fimd: "fimd.hlo.txt".to_string(),
        dampen: "dampen.hlo.txt".to_string(),
        gemm: "gemm.hlo.txt".to_string(),
        gemm_demo: GEMM_DEMO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rn18slim_matches_python_inventory() {
        let m = model("rn18slim").unwrap();
        assert_eq!(m.num_segments(), 10);
        assert_eq!(m.segments[0].kind, "stem");
        assert_eq!(m.segments[9].kind, "head");
        assert_eq!(m.input_shape, vec![32, 32, 3]);
        assert_eq!(m.batch, BATCH);
        assert_eq!(m.microbatch, MICROBATCH);
        // stem MACs: 32*32*8*3*9
        assert_eq!(m.segments[0].macs_fwd_per_sample, 221_184);
        // shape chain is consistent and ends at the classifier
        for w in m.segments.windows(2) {
            assert_eq!(w[0].out_shape, w[1].in_shape);
        }
        assert_eq!(m.segments[9].out_shape, vec![20]);
        // downsampling blocks carry 9 params, identity blocks 6
        assert_eq!(m.segments[1].params.len(), 6); // s1b1: stride 1, 8->8
        assert_eq!(m.segments[3].params.len(), 9); // s2b1: stride 2
        assert!(m.total_params() > 100_000);
    }

    #[test]
    fn vitslim_matches_python_inventory() {
        let m = model("vitslim").unwrap();
        assert_eq!(m.num_segments(), 14);
        assert_eq!(
            m.segments.iter().filter(|s| s.kind == "encoder").count(),
            12
        );
        assert_eq!(m.segments[0].out_shape, vec![64, 32]); // tokens x dim
        assert_eq!(m.segments[1].params.len(), 12);
        assert_eq!(m.heads, 4);
        for w in m.segments.windows(2) {
            assert_eq!(w[0].out_shape, w[1].in_shape);
        }
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(model("resnet152").is_err());
    }

    #[test]
    fn shared_geometry() {
        let s = shared();
        assert_eq!(s.tile % 1024, 0);
        assert_eq!(s.gemm_demo, GEMM_DEMO);
    }
}
