//! Evaluation metrics: retain/forget accuracy, membership inference
//! attack (MIA), and the Retain Preservation Rate (RPR, eq. 7).

pub mod accuracy;
pub mod mia;
pub mod rpr;

pub use accuracy::{eval_accuracy, per_sample_losses};
pub use mia::{mia_accuracy, ThresholdAttack};
pub use rpr::rpr;
