//! Membership Inference Attack — the unlearning-quality probe of Table I.
//!
//! Loss-threshold attack (Yeom-style): calibrate a threshold on known
//! member losses (retain-set training samples) vs non-member losses (test
//! samples) by maximizing balanced accuracy, then report the fraction of
//! *forget* samples still classified as members. Successful unlearning
//! drives this toward 0 (paper reports e.g. 82.0 -> 5.4 on Rocket/RN).

/// Calibrated loss threshold: predict "member" when loss < threshold.
#[derive(Debug, Clone, Copy)]
pub struct ThresholdAttack {
    pub threshold: f32,
    /// Balanced accuracy achieved on the calibration split.
    pub calibration_acc: f64,
}

impl ThresholdAttack {
    /// Fit by sweeping candidate thresholds over the pooled losses.
    pub fn fit(member_losses: &[f32], nonmember_losses: &[f32]) -> ThresholdAttack {
        let mut candidates: Vec<f32> = member_losses
            .iter()
            .chain(nonmember_losses)
            .cloned()
            .collect();
        candidates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        candidates.dedup();
        let mut best = ThresholdAttack { threshold: 0.0, calibration_acc: 0.0 };
        for &t in &candidates {
            let tpr = member_losses.iter().filter(|&&l| l < t).count() as f64
                / member_losses.len().max(1) as f64;
            let tnr = nonmember_losses.iter().filter(|&&l| l >= t).count() as f64
                / nonmember_losses.len().max(1) as f64;
            let bal = (tpr + tnr) / 2.0;
            if bal > best.calibration_acc {
                best = ThresholdAttack { threshold: t, calibration_acc: bal };
            }
        }
        best
    }

    /// Fraction of the probe set predicted "member".
    pub fn member_rate(&self, losses: &[f32]) -> f64 {
        if losses.is_empty() {
            return 0.0;
        }
        losses.iter().filter(|&&l| l < self.threshold).count() as f64 / losses.len() as f64
    }
}

/// End-to-end MIA score on the forget set: calibrate on member (retain
/// train) vs non-member (test) losses, probe the forget losses.
pub fn mia_accuracy(member: &[f32], nonmember: &[f32], forget: &[f32]) -> f64 {
    ThresholdAttack::fit(member, nonmember).member_rate(forget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::util::prng::Pcg32;

    #[test]
    fn separable_calibration() {
        let members = vec![0.1, 0.2, 0.15, 0.05];
        let nonmembers = vec![2.0, 2.5, 1.8, 3.0];
        let atk = ThresholdAttack::fit(&members, &nonmembers);
        assert!(atk.calibration_acc > 0.99);
        // member-like probes flagged, nonmember-like not
        assert_eq!(atk.member_rate(&[0.12, 0.08]), 1.0);
        assert_eq!(atk.member_rate(&[2.2, 4.0]), 0.0);
    }

    #[test]
    fn unlearned_forget_set_scores_low() {
        // forget samples with losses like non-members -> MIA ~ 0
        let members = vec![0.1; 20];
        let nonmembers = vec![2.0; 20];
        let forget_after_unlearn = vec![2.5; 10];
        assert_eq!(mia_accuracy(&members, &nonmembers, &forget_after_unlearn), 0.0);
        let forget_before = vec![0.05; 10];
        assert_eq!(mia_accuracy(&members, &nonmembers, &forget_before), 1.0);
    }

    #[test]
    fn calibration_acc_bounded_property() {
        prop::check(
            "balanced accuracy in [0.5, 1] for nonempty splits",
            60,
            |rng: &mut Pcg32, size| {
                let n = 2 + size / 2;
                let m: Vec<f32> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
                let o: Vec<f32> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
                (m, o)
            },
            |(m, o)| {
                let atk = ThresholdAttack::fit(m, o);
                if atk.calibration_acc < 0.5 - 1e-9 || atk.calibration_acc > 1.0 + 1e-9 {
                    return Err(format!("bal acc {}", atk.calibration_acc));
                }
                Ok(())
            },
        );
    }
}
