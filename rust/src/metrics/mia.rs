//! Membership Inference Attack — the unlearning-quality probe of Table I.
//!
//! Loss-threshold attack (Yeom-style): calibrate a threshold on known
//! member losses (retain-set training samples) vs non-member losses (test
//! samples) by maximizing balanced accuracy, then report the fraction of
//! *forget* samples still classified as members. Successful unlearning
//! drives this toward 0 (paper reports e.g. 82.0 -> 5.4 on Rocket/RN).

/// Calibrated loss threshold: predict "member" when loss < threshold.
#[derive(Debug, Clone, Copy)]
pub struct ThresholdAttack {
    pub threshold: f32,
    /// Balanced accuracy achieved on the calibration split.
    pub calibration_acc: f64,
}

impl ThresholdAttack {
    /// Fit by sweeping candidate thresholds over the pooled losses.
    ///
    /// O(n log n): sort each split once (`total_cmp`, so degenerate NaN
    /// losses cannot panic the calibration), then read every candidate's
    /// TPR/TNR as a prefix count via binary search. NaN losses never win
    /// a `< t` / `>= t` comparison, so they are dropped from the sorted
    /// arrays and candidate set while the denominators keep the raw
    /// input lengths — identical scores to the quadratic filter-count
    /// sweep on finite data.
    pub fn fit(member_losses: &[f32], nonmember_losses: &[f32]) -> ThresholdAttack {
        let sorted = |losses: &[f32]| {
            let mut v: Vec<f32> = losses.iter().copied().filter(|l| !l.is_nan()).collect();
            v.sort_by(f32::total_cmp);
            v
        };
        let members = sorted(member_losses);
        let nonmembers = sorted(nonmember_losses);
        let mut candidates: Vec<f32> = members.iter().chain(&nonmembers).copied().collect();
        candidates.sort_by(f32::total_cmp);
        candidates.dedup();
        let m = member_losses.len().max(1) as f64;
        let n = nonmember_losses.len().max(1) as f64;
        let mut best = ThresholdAttack { threshold: 0.0, calibration_acc: 0.0 };
        for &t in &candidates {
            // prefix length = |{l : l < t}| — the arrays hold no NaN, so
            // `l < t` partitions them and `partition_point` is exact
            let tpr = members.partition_point(|&l| l < t) as f64 / m;
            let tnr = (nonmembers.len() - nonmembers.partition_point(|&l| l < t)) as f64 / n;
            let bal = (tpr + tnr) / 2.0;
            if bal > best.calibration_acc {
                best = ThresholdAttack { threshold: t, calibration_acc: bal };
            }
        }
        best
    }

    /// Fraction of the probe set predicted "member".
    pub fn member_rate(&self, losses: &[f32]) -> f64 {
        if losses.is_empty() {
            return 0.0;
        }
        losses.iter().filter(|&&l| l < self.threshold).count() as f64 / losses.len() as f64
    }
}

/// End-to-end MIA score on the forget set: calibrate on member (retain
/// train) vs non-member (test) losses, probe the forget losses.
pub fn mia_accuracy(member: &[f32], nonmember: &[f32], forget: &[f32]) -> f64 {
    ThresholdAttack::fit(member, nonmember).member_rate(forget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::util::prng::Pcg32;

    #[test]
    fn separable_calibration() {
        let members = vec![0.1, 0.2, 0.15, 0.05];
        let nonmembers = vec![2.0, 2.5, 1.8, 3.0];
        let atk = ThresholdAttack::fit(&members, &nonmembers);
        assert!(atk.calibration_acc > 0.99);
        // member-like probes flagged, nonmember-like not
        assert_eq!(atk.member_rate(&[0.12, 0.08]), 1.0);
        assert_eq!(atk.member_rate(&[2.2, 4.0]), 0.0);
    }

    #[test]
    fn unlearned_forget_set_scores_low() {
        // forget samples with losses like non-members -> MIA ~ 0
        let members = vec![0.1; 20];
        let nonmembers = vec![2.0; 20];
        let forget_after_unlearn = vec![2.5; 10];
        assert_eq!(mia_accuracy(&members, &nonmembers, &forget_after_unlearn), 0.0);
        let forget_before = vec![0.05; 10];
        assert_eq!(mia_accuracy(&members, &nonmembers, &forget_before), 1.0);
    }

    #[test]
    fn nan_losses_do_not_panic_and_dilute_the_rates() {
        // a degenerate loss (NaN from an all-zero logit row) used to
        // panic partial_cmp().unwrap(); now it simply never counts as a
        // member or non-member hit while staying in the denominator
        let members = vec![0.1, 0.2, f32::NAN, 0.15];
        let nonmembers = vec![2.0, f32::NAN, 2.5];
        let atk = ThresholdAttack::fit(&members, &nonmembers);
        assert!(atk.threshold.is_finite());
        // tpr = 3/4 (NaN member never < t), tnr = 2/3 at the best split
        assert!((atk.calibration_acc - (3.0 / 4.0 + 2.0 / 3.0) / 2.0).abs() < 1e-9);
        assert!(atk.calibration_acc <= 1.0);
    }

    #[test]
    fn quadratic_oracle_agreement() {
        // the prefix-count sweep must score exactly like the original
        // O(n^2) filter-count sweep on finite data
        let mut rng = Pcg32::seeded(7);
        for _ in 0..25 {
            let m: Vec<f32> = (0..17).map(|_| rng.range(0.0, 3.0)).collect();
            let o: Vec<f32> = (0..13).map(|_| rng.range(0.0, 3.0)).collect();
            let atk = ThresholdAttack::fit(&m, &o);
            let mut cand: Vec<f32> = m.iter().chain(&o).copied().collect();
            cand.sort_by(f32::total_cmp);
            cand.dedup();
            let mut best = ThresholdAttack { threshold: 0.0, calibration_acc: 0.0 };
            for &t in &cand {
                let tpr = m.iter().filter(|&&l| l < t).count() as f64 / m.len() as f64;
                let tnr = o.iter().filter(|&&l| l >= t).count() as f64 / o.len() as f64;
                let bal = (tpr + tnr) / 2.0;
                if bal > best.calibration_acc {
                    best = ThresholdAttack { threshold: t, calibration_acc: bal };
                }
            }
            assert_eq!(atk.threshold, best.threshold);
            assert_eq!(atk.calibration_acc, best.calibration_acc);
        }
    }

    #[test]
    fn calibration_acc_bounded_property() {
        prop::check(
            "balanced accuracy in [0.5, 1] for nonempty splits",
            60,
            |rng: &mut Pcg32, size| {
                let n = 2 + size / 2;
                let m: Vec<f32> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
                let o: Vec<f32> = (0..n).map(|_| rng.range(0.0, 3.0)).collect();
                (m, o)
            },
            |(m, o)| {
                let atk = ThresholdAttack::fit(m, o);
                if atk.calibration_acc < 0.5 - 1e-9 || atk.calibration_acc > 1.0 + 1e-9 {
                    return Err(format!("bal acc {}", atk.calibration_acc));
                }
                Ok(())
            },
        );
    }
}
