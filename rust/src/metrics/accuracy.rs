//! Dataset accuracy / per-sample loss evaluation through the fused
//! `logits` module (static batch; tail batches padded and masked).

use anyhow::Result;

use crate::data::Dataset;
use crate::model::{Model, ParamAccess};

/// Top-1 accuracy over the given sample indices.
pub fn eval_accuracy(
    model: &Model,
    params: &dyn ParamAccess,
    ds: &Dataset,
    idx: &[usize],
) -> Result<f64> {
    if idx.is_empty() {
        return Ok(0.0);
    }
    let b = model.meta.batch;
    let mut hits = 0usize;
    for chunk in idx.chunks(b) {
        let (x, labels) = ds.batch(chunk, b);
        let logits = model.logits(params, &x)?;
        let preds = logits.argmax_rows();
        hits += preds
            .iter()
            .take(labels.len())
            .zip(&labels)
            .filter(|(p, l)| p == l)
            .count();
    }
    Ok(hits as f64 / idx.len() as f64)
}

/// Per-sample cross-entropy losses (softmax readout on host — the same
/// quantity the MIA thresholds).
pub fn per_sample_losses(
    model: &Model,
    params: &dyn ParamAccess,
    ds: &Dataset,
    idx: &[usize],
) -> Result<Vec<f32>> {
    let b = model.meta.batch;
    let mut out = Vec::with_capacity(idx.len());
    for chunk in idx.chunks(b) {
        let (x, labels) = ds.batch(chunk, b);
        let logits = model.logits(params, &x)?;
        let probs = logits.softmax_rows();
        for (i, &l) in labels.iter().enumerate() {
            let p = probs.row(i)[l].max(1e-12);
            out.push(-p.ln());
        }
    }
    Ok(out)
}
