//! Retain Preservation Rate — eq. (7):
//! `RPR = (1 - dDr_ours / dDr_ssd) * 100`, where `dDr` is the retain
//! accuracy drop vs the pre-unlearning baseline. Positive RPR means the
//! method preserves retain accuracy better than SSD.

/// All accuracies as fractions in [0, 1].
pub fn rpr(baseline_dr: f64, ssd_dr: f64, ours_dr: f64) -> f64 {
    let d_ssd = baseline_dr - ssd_dr;
    let d_ours = baseline_dr - ours_dr;
    if d_ssd.abs() < 1e-12 {
        // SSD lost nothing; any loss by ours is infinitely worse — report 0
        // when both are lossless.
        return if d_ours.abs() < 1e-12 { 0.0 } else { f64::NEG_INFINITY };
    }
    (1.0 - d_ours / d_ssd) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positive_when_ours_preserves_more() {
        // baseline 96.95, SSD 96.14, ours 96.25 (Table II Rocket/RN)
        let v = rpr(0.9695, 0.9614, 0.9625);
        assert!((v - 13.58).abs() < 0.2, "{v}");
    }

    #[test]
    fn zero_when_equal() {
        assert_eq!(rpr(0.97, 0.95, 0.95), 0.0);
    }

    #[test]
    fn hundred_when_no_drop() {
        assert!((rpr(0.97, 0.90, 0.97) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn negative_when_worse_than_ssd() {
        assert!(rpr(0.97, 0.96, 0.94) < 0.0);
    }

    #[test]
    fn degenerate_ssd_lossless() {
        assert_eq!(rpr(0.97, 0.97, 0.97), 0.0);
        assert_eq!(rpr(0.97, 0.97, 0.96), f64::NEG_INFINITY);
    }
}
