//! The unlearning engine — Algorithm 1 decomposed into its stages.
//!
//! The paper's loop walks segments back-end-first (depth l = 1 at the
//! head). For each segment it streams the per-microbatch gradient chain
//! through the FIMD module (Fisher of the *original* parameters — the gy
//! chain for segment l is computed before segment l is dampened, so the
//! whole chain sees pre-edit weights, exactly like SSD's single-pass
//! formulation), dampens the segment through the Dampening module with
//! `S(l)`-scaled `(alpha, lambda)`, and at checkpoints resumes partial
//! inference from the cached activations to decide early stop.
//!
//! That loop body is split into three stage functions ([`stages`]) —
//! forget-Fisher estimation, dampening pass, early-stop controller —
//! which [`run_strategy`] drives through the
//! [`Strategy`](crate::unlearn::Strategy) trait. The paper's four
//! operating points (SSD / CAU / BD / FiCABU) are provided strategies
//! differing only in the [`UnlearnConfig`] bag they consume; a custom
//! strategy can override any single stage and inherit the rest.
//!
//! An unlearning event is **transactional**: [`stages::dampen`] journals
//! each segment's pre-image ([`Pass::snapshot_segment`]) before writing
//! it, and [`run_strategy`] restores the journal on any error *or panic*
//! between begin and finish — so a replica whose request fails is
//! bitwise back to its pre-request parameters (f32 masters and int8
//! copies alike), never left half-dampened.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

use anyhow::{bail, Result};

use crate::fisher::{concat_seg_into, FimdEngine, Importance};
use crate::model::macs::{self, MacLedger};
use crate::model::params::{ParamAccess, SegmentSnapshot};
use crate::model::{ActivationCache, Model};
use crate::runtime::Precision;
use crate::tensor::Tensor;
use crate::testkit::faults;
use crate::unlearn::damp::{DampEngine, DampStats};
use crate::unlearn::schedule::Schedule;
use crate::unlearn::strategy::Strategy;

/// Operating-point parameter bag for one unlearning engine.
///
/// The config is plain `Send + Clone` data that a
/// [`Strategy`](crate::unlearn::Strategy) consumes; all mutable pass
/// state lives in [`Pass`] — so one config can be cloned into any
/// number of serving replicas (`coordinator::WorkerSpec`) and executed
/// re-entrantly, one event per replica at a time, with no shared state
/// between workers. Batch compatibility in the fleet is keyed by the
/// config's *fingerprint* (`coordinator::wal::config_fingerprint`) as
/// part of the `(model, config_hash, spec)` batch key — a claimed batch
/// may mix configs and tenants freely; `PartialEq` remains derived for
/// tests but carries no dispatch semantics.
///
/// Build configs through the strategy constructors
/// ([`Ssd::new`](crate::unlearn::Ssd), [`Cau::new`](crate::unlearn::Cau),
/// [`Bd::new`](crate::unlearn::Bd),
/// [`Ficabu::new`](crate::unlearn::Ficabu)) rather than by hand — they
/// encode which knobs each operating point actually uses.
#[derive(Debug, Clone, PartialEq)]
pub struct UnlearnConfig {
    pub alpha: f64,
    pub lambda: f64,
    pub schedule: Schedule,
    /// Depths l at which to run checkpoint partial inference; empty
    /// disables early stop (SSD/BD).
    pub checkpoints: Vec<usize>,
    /// Target forget accuracy (fraction): random-guess level for the task.
    pub tau: f64,
    /// Forward/eval precision: `Int8` serves the paper's deployment
    /// mode (int8 GEMM streaming for Step-0 forward and checkpoint
    /// partial inference) while the gradient chain (segment VJPs, FIMD)
    /// stays f32 over the dequantized masters. Requires a store
    /// prepared with [`ParamStore::quantize_int8`].
    pub precision: Precision,
}

impl Default for UnlearnConfig {
    /// SSD-shaped defaults: uniform schedule, no checkpoints, f32.
    fn default() -> UnlearnConfig {
        UnlearnConfig {
            alpha: 10.0,
            lambda: 1.0,
            schedule: Schedule::Uniform,
            checkpoints: vec![],
            tau: 0.0,
            precision: Precision::F32,
        }
    }
}

impl UnlearnConfig {
    /// Builder: serve forward/eval at the given precision.
    pub fn with_precision(mut self, precision: Precision) -> UnlearnConfig {
        self.precision = precision;
        self
    }
}

/// The paper's checkpoint grid: first and last depth, plus every
/// `stride` interior segments (every 4 of 16 convs = every 2 of 8 blocks
/// for ResNet-18; every 3 of 12 encoders for ViT).
pub fn default_checkpoints(num_segments: usize, stride: usize) -> Vec<usize> {
    let big_l = num_segments;
    let mut cps = vec![1];
    let mut l = 1 + stride;
    while l < big_l {
        cps.push(l);
        l += stride;
    }
    cps.push(big_l);
    cps.dedup();
    cps
}

#[derive(Debug, Clone, Default)]
pub struct UnlearnReport {
    pub ledger: MacLedger,
    /// Depth at which early stop fired (None = ran to the front-end).
    pub stop_depth: Option<usize>,
    pub segments_edited: usize,
    /// Selected-parameter count per depth l (index l-1) — Fig. 3 data.
    pub selected_per_depth: Vec<u64>,
    /// (depth, measured forget accuracy) at every evaluated checkpoint.
    pub checkpoint_trace: Vec<(usize, f64)>,
    /// *Real* elements streamed through each IP (feeds the hwsim
    /// cycle/traffic model).
    pub fimd_elems: u64,
    pub damp_elems: u64,
    /// Zero-pad elements the fixed-size IP bursts carried beyond the
    /// real streams (tail tiles) — pad lanes cost IP cycles but never
    /// move over DDR.
    pub fimd_pad_elems: u64,
    pub damp_pad_elems: u64,
    /// Bytes of activation cache held for checkpoint reuse.
    pub act_cache_bytes: usize,
    /// Precision the forward/eval GEMM stream actually executed in —
    /// the hwsim charges int8 MAC energy and 1-byte traffic from this,
    /// not from a deployment assumption.
    pub precision: Precision,
    /// Whether the event failed mid-pass and the engine restored every
    /// journaled segment to its pre-request state. Always `false` on a
    /// successful pass; carried on the error path via the wire-facing
    /// `Summary` contract.
    pub rolled_back: bool,
}

/// One-hot targets for a label batch; rejects out-of-range labels
/// instead of writing past the row (the old implementation panicked).
pub fn make_onehot(labels: &[usize], classes: usize) -> Result<Tensor> {
    let mut t = Tensor::zeros(vec![labels.len(), classes]);
    for (i, &c) in labels.iter().enumerate() {
        if c >= classes {
            bail!("label {c} at row {i} out of range ({classes} classes)");
        }
        t.data[i * classes + c] = 1.0;
    }
    Ok(t)
}

/// Mutable state of one unlearning pass, threaded through the
/// [`Strategy`](crate::unlearn::Strategy) stage hooks. Built by
/// [`run_strategy`]; custom strategies read the public fields and
/// advance the gradient chain via [`Pass::backprop_microbatch`] (the
/// chain state itself is private so a stage cannot desynchronize it by
/// accident — see the stage-1 contract on
/// [`Strategy::forget_fisher`](crate::unlearn::Strategy::forget_fisher)).
pub struct Pass<'a> {
    pub model: &'a Model,
    /// The parameter view this pass edits: an owned drifting
    /// [`ParamStore`](crate::model::ParamStore) for the legacy session
    /// path, or a per-request [`CowParams`](crate::model::CowParams)
    /// overlay in the registry fleet.
    pub params: &'a mut dyn ParamAccess,
    pub global: &'a Importance,
    pub fimd: &'a FimdEngine,
    pub damp: &'a DampEngine,
    /// Per-sample forget labels (one per batch row; classes may mix —
    /// multi-class and sample-level specs land here unchanged).
    pub labels: &'a [usize],
    /// Step-0 activation cache: segment inputs + logits, pre-edit.
    pub cache: ActivationCache,
    pub report: UnlearnReport,
    /// Transaction journal: pre-images of every segment written this
    /// pass, captured by [`Pass::snapshot_segment`] before the first
    /// write and replayed by [`run_strategy`] on error/panic.
    journal: Vec<(usize, SegmentSnapshot)>,
    /// Per-microbatch gy chain, advanced by the forget-Fisher stage.
    gy_state: Vec<Tensor>,
    /// Hoisted burst buffers reused across microbatches and segments.
    burst: Vec<f32>,
    theta: Vec<f32>,
    fimd_start: (u64, u64),
    damp_start: (u64, u64),
}

impl<'a> Pass<'a> {
    /// Validate the event and run Algorithm 1 Step 0: one cached
    /// forward pass plus the per-microbatch loss-gradient seeds.
    #[allow(clippy::too_many_arguments)]
    fn begin(
        model: &'a Model,
        params: &'a mut dyn ParamAccess,
        forget_x: &Tensor,
        forget_labels: &'a [usize],
        global: &'a Importance,
        fimd: &'a FimdEngine,
        damp: &'a DampEngine,
        cfg: &UnlearnConfig,
    ) -> Result<Pass<'a>> {
        let meta = &model.meta;
        let big_l = meta.num_segments();
        let mb_size = meta.microbatch;
        if forget_x.batch() != meta.batch {
            bail!("forget batch {} != model batch {}", forget_x.batch(), meta.batch);
        }
        if forget_labels.len() != meta.batch {
            bail!("labels len {} != batch {}", forget_labels.len(), meta.batch);
        }
        if cfg.precision == Precision::Int8 && !params.is_quantized() {
            bail!("int8 unlearning requested on an unquantized store (ParamStore::quantize_int8)");
        }
        let num_mb = meta.batch / mb_size;

        let mut report = UnlearnReport {
            selected_per_depth: vec![0; big_l],
            precision: cfg.precision,
            ..Default::default()
        };

        // --- Step 0: one forward pass, cache every segment input ---------
        // (int8-served: the forward streams int8 GEMM over the quantized
        // weights; the cached activations feed the f32 gradient chain)
        let cache = model.forward_cached_prec(&*params, forget_x, cfg.precision)?;
        report.ledger.forward = macs::full_forward_macs(meta, meta.batch);
        report.act_cache_bytes = cache.bytes();

        // Per-microbatch gradient chain state, seeded at the logits.
        let onehot = make_onehot(forget_labels, meta.num_classes)?;
        let mut gy_state: Vec<Tensor> = Vec::with_capacity(num_mb);
        for mb in 0..num_mb {
            let logits_mb = cache.microbatch_logits(mb, mb_size)?;
            let onehot_mb = onehot.slice_batch(mb * mb_size, mb_size)?;
            gy_state.push(model.loss_grad(&logits_mb, &onehot_mb)?);
        }

        Ok(Pass {
            model,
            params,
            global,
            fimd,
            damp,
            labels: forget_labels,
            cache,
            report,
            journal: Vec::new(),
            gy_state,
            burst: Vec::new(),
            theta: Vec::new(),
            fimd_start: (fimd.elems_streamed.get(), fimd.pad_elems.get()),
            damp_start: (damp.elems_streamed.get(), damp.pad_elems.get()),
        })
    }

    /// Backpropagate microbatch `mb` through segment `k` and advance
    /// its gy chain entry, returning the segment's parameter gradients
    /// (the VJP the default Fisher stage streams).
    ///
    /// This is the only way to move the gradient chain, and a stage-1
    /// override that does not delegate to
    /// [`stages::forget_fisher`] MUST drive it once per microbatch at
    /// every depth — otherwise deeper segments would silently see a
    /// stale chain.
    pub fn backprop_microbatch(&mut self, k: usize, mb: usize) -> Result<Vec<Tensor>> {
        let mb_size = self.model.meta.microbatch;
        let x_mb = self.cache.microbatch_input(k, mb, mb_size)?;
        let (grads, gx) = self.model.segment_bwd(k, &*self.params, &x_mb, &self.gy_state[mb])?;
        self.gy_state[mb] = gx;
        Ok(grads)
    }

    /// Journal segment `k`'s pre-image before writing it (idempotent
    /// per pass: only the first call for a segment captures). A custom
    /// stage-2 override that edits `params` directly MUST call this
    /// before its first write to keep the engine's rollback guarantee.
    pub fn snapshot_segment(&mut self, k: usize) {
        if self.journal.iter().any(|(j, _)| *j == k) {
            return;
        }
        self.journal.push((k, self.params.snapshot_segment(k)));
    }

    /// Restore every journaled segment (newest first) to its pre-pass
    /// state and mark the report rolled back.
    fn rollback(&mut self) {
        for (k, snap) in self.journal.drain(..).rev() {
            self.params.restore_segment(k, snap);
        }
        self.report.rolled_back = true;
    }

    fn finish(mut self) -> UnlearnReport {
        self.report.fimd_elems = self.fimd.elems_streamed.get() - self.fimd_start.0;
        self.report.fimd_pad_elems = self.fimd.pad_elems.get() - self.fimd_start.1;
        self.report.damp_elems = self.damp.elems_streamed.get() - self.damp_start.0;
        self.report.damp_pad_elems = self.damp.pad_elems.get() - self.damp_start.1;
        self.report
    }
}

/// Early-stop controller verdict for one depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopVerdict {
    /// Keep editing toward the front-end.
    Continue,
    /// Target reached: leave layers l+1..L untouched.
    Stop,
}

/// The paper's default stage implementations — the bodies of the
/// [`Strategy`](crate::unlearn::Strategy) trait's provided methods.
/// Custom strategies can call these directly for the stages they do
/// *not* override.
pub mod stages {
    use super::*;

    /// Stage 1 — forget-Fisher estimation for depth `l`: stream every
    /// microbatch's VJP for this segment through the FIMD IP (gradients
    /// of the *original* parameters — the segment is dampened only
    /// after its bwd has produced gx) and advance the gy chain.
    pub fn forget_fisher(pass: &mut Pass<'_>, l: usize) -> Result<Vec<f32>> {
        faults::hit("forget_fisher")?;
        let meta = &pass.model.meta;
        let k = meta.seg_index(l);
        let num_mb = meta.batch / meta.microbatch;
        let mut i_df = vec![0.0f32; meta.segments[k].param_count()];
        let scale = 1.0 / num_mb as f32;
        for mb in 0..num_mb {
            let grads = pass.backprop_microbatch(k, mb)?;
            concat_seg_into(&grads, &mut pass.burst);
            pass.fimd.accumulate(&mut i_df, &pass.burst, scale)?;
        }
        pass.report.ledger.backward += macs::bwd_macs(meta, k, meta.batch);
        pass.report.ledger.fisher += macs::fisher_macs(meta, k, num_mb);
        Ok(i_df)
    }

    /// Stage 2 — Balanced Dampening for depth `l`: scale
    /// `(alpha, lambda)` by `S(l)`, stream the segment burst through the
    /// Dampening IP, scatter the edit back, and keep any int8 copies in
    /// lockstep.
    pub fn dampen(
        pass: &mut Pass<'_>,
        cfg: &UnlearnConfig,
        l: usize,
        i_df: &[f32],
    ) -> Result<DampStats> {
        faults::hit("dampen")?;
        let meta = &pass.model.meta;
        let big_l = meta.num_segments();
        let k = meta.seg_index(l);
        let s = cfg.schedule.s(l, big_l);
        let alpha_l = (cfg.alpha * s) as f32;
        let lambda_l = (cfg.lambda * s) as f32;
        concat_seg_into(pass.params.seg(k), &mut pass.theta);
        let stats =
            pass.damp.dampen(&mut pass.theta, i_df, &pass.global.per_seg[k], alpha_l, lambda_l)?;
        // journal the pre-image before the first write to this segment,
        // so a later failure anywhere in the pass can roll it back
        pass.snapshot_segment(k);
        scatter_seg(&pass.theta, pass.params.seg_mut(k))?;
        // Keep the int8 copies in lockstep with the edited masters —
        // only the segment the dampening write-back touched. Gated on
        // the *store* (not cfg.precision) deliberately: an f32-precision
        // run over an int8-deployed store must still leave the int8
        // copies valid (evals auto-detect them), at the cost of
        // re-snapping edits to the grid. For a pure-f32 ablation arm,
        // run on an unquantized clone of the store.
        if pass.params.is_quantized() {
            pass.params.requantize_segment(k);
        }
        pass.report.ledger.dampen += macs::dampen_macs(meta, k);
        pass.report.selected_per_depth[l - 1] = stats.selected;
        pass.report.segments_edited = l;
        Ok(stats)
    }

    /// Stage 3 — Context-Adaptive early stop: at configured checkpoint
    /// depths, resume partial inference from the cached input of this
    /// segment through the (now partially dampened) back-end and stop
    /// once the batch forget accuracy reaches `tau`.
    pub fn early_stop(pass: &mut Pass<'_>, cfg: &UnlearnConfig, l: usize) -> Result<StopVerdict> {
        // seam fires at every depth, before the checkpoint-grid gate, so
        // a fault plan can target the n-th stop *check* on any strategy
        faults::hit("early_stop")?;
        if !cfg.checkpoints.contains(&l) {
            return Ok(StopVerdict::Continue);
        }
        let meta = &pass.model.meta;
        let k = meta.seg_index(l);
        let logits = pass.model.partial_forward_prec(
            &*pass.params,
            k,
            &pass.cache.inputs[k],
            cfg.precision,
        )?;
        pass.report.ledger.checkpoint += macs::partial_inference_macs(meta, k, meta.batch);
        let acc = forget_accuracy(&logits, pass.labels)?;
        pass.report.checkpoint_trace.push((l, acc));
        if acc <= cfg.tau {
            pass.report.stop_depth = Some(l);
            return Ok(StopVerdict::Stop);
        }
        Ok(StopVerdict::Continue)
    }
}

/// Run one unlearning event over a forget batch, driving the given
/// [`Strategy`](crate::unlearn::Strategy) through the stage loop.
///
/// `forget_x` is `[N, ...]` with N = meta.batch; `forget_labels[n]` the
/// per-sample label to forget (classes may mix within the batch).
/// `global` is the stored `I_D`.
#[allow(clippy::too_many_arguments)]
pub fn run_strategy(
    model: &Model,
    params: &mut dyn ParamAccess,
    forget_x: &Tensor,
    forget_labels: &[usize],
    global: &Importance,
    fimd: &FimdEngine,
    damp: &DampEngine,
    strategy: &dyn Strategy,
) -> Result<UnlearnReport> {
    let cfg = strategy.config();
    let mut pass =
        Pass::begin(model, params, forget_x, forget_labels, global, fimd, damp, cfg)?;
    let big_l = model.meta.num_segments();
    // --- back-end-first layer loop, run as a transaction ------------------
    // Any error or panic after begin rolls the journaled segments back
    // before propagating, so the caller's ParamStore is bitwise its
    // pre-request self. AssertUnwindSafe: on unwind the pass state is
    // only ever touched by `rollback`, which replays whole pre-images.
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        for l in 1..=big_l {
            let i_df = strategy.forget_fisher(&mut pass, l)?;
            strategy.dampen(&mut pass, l, &i_df)?;
            if strategy.early_stop(&mut pass, l)? == StopVerdict::Stop {
                break;
            }
        }
        anyhow::Ok(())
    }));
    match outcome {
        Ok(Ok(())) => Ok(pass.finish()),
        Ok(Err(e)) => {
            pass.rollback();
            Err(e.context("unlearning event failed; replica rolled back to pre-request params"))
        }
        Err(payload) => {
            pass.rollback();
            resume_unwind(payload)
        }
    }
}

/// Run one unlearning event with the paper's default stages driven
/// straight from a config bag (the serving replicas' path: a
/// [`UnlearnConfig`] travels in a `WorkerSpec`, the strategy is
/// reconstructed in-thread).
#[allow(clippy::too_many_arguments)]
pub fn run_unlearning(
    model: &Model,
    params: &mut dyn ParamAccess,
    forget_x: &Tensor,
    forget_labels: &[usize],
    global: &Importance,
    fimd: &FimdEngine,
    damp: &DampEngine,
    cfg: &UnlearnConfig,
) -> Result<UnlearnReport> {
    let strategy = crate::unlearn::Ficabu::from_config(cfg.clone());
    run_strategy(model, params, forget_x, forget_labels, global, fimd, damp, &strategy)
}

/// Scatter a segment burst back into its parameter tensors (inverse of
/// `fisher::concat_seg`). Rejects a length mismatch instead of silently
/// truncating (the old implementation only `debug_assert`ed, so a
/// release build with a short burst would leave the segment tail
/// stale).
pub fn scatter_seg(burst: &[f32], tensors: &mut [Tensor]) -> Result<()> {
    let want: usize = tensors.iter().map(|t| t.len()).sum();
    if want != burst.len() {
        bail!("scatter_seg: burst {} != segment params {}", burst.len(), want);
    }
    let mut off = 0;
    for t in tensors.iter_mut() {
        let n = t.len();
        t.data.copy_from_slice(&burst[off..off + n]);
        off += n;
    }
    Ok(())
}

/// Batch-mean forget accuracy (Algorithm 1's `partial_inference`
/// readout). Errors on an empty or mismatched label set instead of
/// returning NaN (an empty batch would otherwise poison every
/// downstream `acc <= tau` comparison as silently-false).
pub fn forget_accuracy(logits: &Tensor, labels: &[usize]) -> Result<f64> {
    if labels.is_empty() {
        bail!("forget_accuracy: empty label set");
    }
    let preds = logits.argmax_rows();
    if preds.len() != labels.len() {
        bail!("forget_accuracy: {} logit rows vs {} labels", preds.len(), labels.len());
    }
    let hits = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    Ok(hits as f64 / labels.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::unlearn::{Ficabu, Ssd};

    #[test]
    fn default_checkpoint_grids_match_paper() {
        // RN: 10 segments, every 2 blocks -> {1,3,5,7,9,10}
        assert_eq!(default_checkpoints(10, 2), vec![1, 3, 5, 7, 9, 10]);
        // ViT: 14 segments, every 3 encoders -> {1,4,7,10,13,14}
        assert_eq!(default_checkpoints(14, 3), vec![1, 4, 7, 10, 13, 14]);
    }

    #[test]
    fn onehot_layout() {
        let t = make_onehot(&[2, 0], 3).unwrap();
        assert_eq!(t.data, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn onehot_rejects_out_of_range_label() {
        let err = make_onehot(&[0, 3], 3).unwrap_err().to_string();
        assert!(err.contains("label 3"), "got: {err}");
        // boundary: the last valid label is classes - 1
        assert!(make_onehot(&[2], 3).is_ok());
    }

    #[test]
    fn scatter_roundtrip() {
        let mut ts = vec![Tensor::vec1(vec![0.0; 3]), Tensor::vec1(vec![0.0; 2])];
        scatter_seg(&[1.0, 2.0, 3.0, 4.0, 5.0], &mut ts).unwrap();
        assert_eq!(ts[0].data, vec![1.0, 2.0, 3.0]);
        assert_eq!(ts[1].data, vec![4.0, 5.0]);
    }

    #[test]
    fn scatter_rejects_length_mismatch() {
        let mut ts = vec![Tensor::vec1(vec![9.0; 3]), Tensor::vec1(vec![9.0; 2])];
        // short burst: must error and leave the tensors untouched
        assert!(scatter_seg(&[1.0, 2.0], &mut ts).is_err());
        assert!(scatter_seg(&[1.0; 6], &mut ts).is_err());
        assert_eq!(ts[0].data, vec![9.0; 3]);
        assert_eq!(ts[1].data, vec![9.0; 2]);
    }

    #[test]
    fn forget_accuracy_counts() {
        let logits = Tensor::new(vec![2, 3], vec![0.0, 5.0, 0.0, 9.0, 0.0, 0.0]).unwrap();
        assert_eq!(forget_accuracy(&logits, &[1, 0]).unwrap(), 1.0);
        assert_eq!(forget_accuracy(&logits, &[1, 2]).unwrap(), 0.5);
    }

    #[test]
    fn forget_accuracy_guards_degenerate_inputs() {
        let logits = Tensor::new(vec![2, 3], vec![0.0; 6]).unwrap();
        assert!(forget_accuracy(&logits, &[]).is_err(), "empty labels must not yield NaN");
        assert!(forget_accuracy(&logits, &[0]).is_err(), "row/label mismatch");
    }

    #[test]
    fn strategy_configs_replace_the_constructor_zoo() {
        let ssd = Ssd::new(10.0, 1.0);
        assert!(ssd.config().checkpoints.is_empty());
        assert_eq!(ssd.config().schedule, Schedule::Uniform);
        let fic = Ficabu::new(
            10.0,
            1.0,
            Schedule::Sigmoid { cm: 5.0, br: 10.0 },
            vec![1, 3],
            0.05,
        );
        assert!(!fic.config().checkpoints.is_empty());
        assert_eq!(fic.config().tau, 0.05);
    }
}
