//! The unlearning engine — Algorithm 1 with the Balanced Dampening profile.
//!
//! One implementation covers all four operating points evaluated in the
//! paper; they differ only in configuration:
//!
//! | mode     | checkpoints | schedule  | paper artifact |
//! |----------|-------------|-----------|----------------|
//! | SSD      | none        | Uniform   | baseline, §II  |
//! | CAU      | paper grid  | Uniform   | Table I        |
//! | BD       | none        | Sigmoid   | Table II       |
//! | FiCABU   | paper grid  | Sigmoid   | Table IV       |
//!
//! The loop walks segments back-end-first (depth l = 1 at the head). For
//! each segment it streams the per-microbatch gradient chain through the
//! FIMD module (Fisher of the *original* parameters — the gy chain for
//! segment l is computed before segment l is dampened, so the whole chain
//! sees pre-edit weights, exactly like SSD's single-pass formulation),
//! dampens the segment through the Dampening module with `S(l)`-scaled
//! `(alpha, lambda)`, and at checkpoints resumes partial inference from the
//! cached activations to decide early stop.

use anyhow::{bail, Result};

use crate::fisher::{concat_seg_into, FimdEngine, Importance};
use crate::model::macs::{self, MacLedger};
use crate::model::{Model, ParamStore};
use crate::runtime::Precision;
use crate::tensor::Tensor;
use crate::unlearn::damp::DampEngine;
use crate::unlearn::schedule::Schedule;

/// Operating-point configuration for one unlearning engine.
///
/// The config is plain `Send + Clone` data, and `run_unlearning` keeps
/// all mutable state in its arguments — so one config can be cloned
/// into any number of serving replicas (`coordinator::WorkerSpec`) and
/// executed re-entrantly, one event per replica at a time, with no
/// shared state between workers. `PartialEq` is the dispatcher's
/// batch-compatibility check: requests are batchable into one worker
/// pass exactly when their configs compare equal.
#[derive(Debug, Clone, PartialEq)]
pub struct UnlearnConfig {
    pub alpha: f64,
    pub lambda: f64,
    pub schedule: Schedule,
    /// Depths l at which to run checkpoint partial inference; empty
    /// disables early stop (SSD/BD).
    pub checkpoints: Vec<usize>,
    /// Target forget accuracy (fraction): random-guess level for the task.
    pub tau: f64,
    /// Forward/eval precision: `Int8` serves the paper's deployment
    /// mode (int8 GEMM streaming for Step-0 forward and checkpoint
    /// partial inference) while the gradient chain (segment VJPs, FIMD)
    /// stays f32 over the dequantized masters. Requires a store
    /// prepared with [`ParamStore::quantize_int8`].
    pub precision: Precision,
}

impl UnlearnConfig {
    pub fn ssd(alpha: f64, lambda: f64) -> UnlearnConfig {
        UnlearnConfig {
            alpha,
            lambda,
            schedule: Schedule::Uniform,
            checkpoints: vec![],
            tau: 0.0,
            precision: Precision::F32,
        }
    }

    pub fn cau(alpha: f64, lambda: f64, checkpoints: Vec<usize>, tau: f64) -> UnlearnConfig {
        UnlearnConfig {
            alpha,
            lambda,
            schedule: Schedule::Uniform,
            checkpoints,
            tau,
            precision: Precision::F32,
        }
    }

    pub fn bd(alpha: f64, lambda: f64, schedule: Schedule) -> UnlearnConfig {
        UnlearnConfig {
            alpha,
            lambda,
            schedule,
            checkpoints: vec![],
            tau: 0.0,
            precision: Precision::F32,
        }
    }

    pub fn ficabu(
        alpha: f64,
        lambda: f64,
        schedule: Schedule,
        checkpoints: Vec<usize>,
        tau: f64,
    ) -> UnlearnConfig {
        UnlearnConfig {
            alpha,
            lambda,
            schedule,
            checkpoints,
            tau,
            precision: Precision::F32,
        }
    }

    /// Builder: serve forward/eval at the given precision.
    pub fn with_precision(mut self, precision: Precision) -> UnlearnConfig {
        self.precision = precision;
        self
    }
}

/// The paper's checkpoint grid: first and last depth, plus every
/// `stride` interior segments (every 4 of 16 convs = every 2 of 8 blocks
/// for ResNet-18; every 3 of 12 encoders for ViT).
pub fn default_checkpoints(num_segments: usize, stride: usize) -> Vec<usize> {
    let big_l = num_segments;
    let mut cps = vec![1];
    let mut l = 1 + stride;
    while l < big_l {
        cps.push(l);
        l += stride;
    }
    cps.push(big_l);
    cps.dedup();
    cps
}

#[derive(Debug, Clone, Default)]
pub struct UnlearnReport {
    pub ledger: MacLedger,
    /// Depth at which early stop fired (None = ran to the front-end).
    pub stop_depth: Option<usize>,
    pub segments_edited: usize,
    /// Selected-parameter count per depth l (index l-1) — Fig. 3 data.
    pub selected_per_depth: Vec<u64>,
    /// (depth, measured forget accuracy) at every evaluated checkpoint.
    pub checkpoint_trace: Vec<(usize, f64)>,
    /// *Real* elements streamed through each IP (feeds the hwsim
    /// cycle/traffic model).
    pub fimd_elems: u64,
    pub damp_elems: u64,
    /// Zero-pad elements the fixed-size IP bursts carried beyond the
    /// real streams (tail tiles) — pad lanes cost IP cycles but never
    /// move over DDR.
    pub fimd_pad_elems: u64,
    pub damp_pad_elems: u64,
    /// Bytes of activation cache held for checkpoint reuse.
    pub act_cache_bytes: usize,
    /// Precision the forward/eval GEMM stream actually executed in —
    /// the hwsim charges int8 MAC energy and 1-byte traffic from this,
    /// not from a deployment assumption.
    pub precision: Precision,
}

pub fn make_onehot(labels: &[usize], classes: usize) -> Tensor {
    let mut t = Tensor::zeros(vec![labels.len(), classes]);
    for (i, &c) in labels.iter().enumerate() {
        t.data[i * classes + c] = 1.0;
    }
    t
}

/// Run one unlearning event over a forget batch.
///
/// `forget_x` is `[N, ...]` with N = meta.batch; `forget_labels[n]` the
/// class to forget (per the paper a single class per event). `global` is
/// the stored `I_D`.
pub fn run_unlearning(
    model: &Model,
    params: &mut ParamStore,
    forget_x: &Tensor,
    forget_labels: &[usize],
    global: &Importance,
    fimd: &FimdEngine,
    damp: &DampEngine,
    cfg: &UnlearnConfig,
) -> Result<UnlearnReport> {
    let meta = &model.meta;
    let big_l = meta.num_segments();
    let mb_size = meta.microbatch;
    if forget_x.batch() != meta.batch {
        bail!("forget batch {} != model batch {}", forget_x.batch(), meta.batch);
    }
    if forget_labels.len() != meta.batch {
        bail!("labels len {} != batch {}", forget_labels.len(), meta.batch);
    }
    if cfg.precision == Precision::Int8 && !params.is_quantized() {
        bail!("int8 unlearning requested on an unquantized store (ParamStore::quantize_int8)");
    }
    let num_mb = meta.batch / mb_size;
    let fimd_start = fimd.elems_streamed.get();
    let damp_start = damp.elems_streamed.get();
    let fimd_pad_start = fimd.pad_elems.get();
    let damp_pad_start = damp.pad_elems.get();

    let mut report = UnlearnReport {
        selected_per_depth: vec![0; big_l],
        precision: cfg.precision,
        ..Default::default()
    };

    // --- Step 0: one forward pass, cache every segment input -------------
    // (int8-served: the forward streams int8 GEMM over the quantized
    // weights; the cached activations feed the f32 gradient chain)
    let cache = model.forward_cached_prec(params, forget_x, cfg.precision)?;
    report.ledger.forward = macs::full_forward_macs(meta, meta.batch);
    report.act_cache_bytes = cache.bytes();

    // Per-microbatch gradient chain state, seeded at the logits.
    let onehot = make_onehot(forget_labels, meta.num_classes);
    let mut gy_state: Vec<Tensor> = Vec::with_capacity(num_mb);
    for mb in 0..num_mb {
        let logits_mb = cache.microbatch_logits(mb, mb_size)?;
        let onehot_mb = onehot.slice_batch(mb * mb_size, mb_size)?;
        gy_state.push(model.loss_grad(&logits_mb, &onehot_mb)?);
    }

    // --- back-end-first layer loop ---------------------------------------
    // Burst buffers hoisted out of the loops: segment gradient bursts
    // and parameter bursts reuse one allocation across all microbatches
    // and segments.
    let mut burst: Vec<f32> = Vec::new();
    let mut theta: Vec<f32> = Vec::new();
    for l in 1..=big_l {
        let k = meta.seg_index(l);

        // Fisher on D_f for this segment (original-parameter gradients:
        // this segment is dampened only after its bwd has produced gx).
        let mut i_df = vec![0.0f32; meta.segments[k].param_count()];
        let scale = 1.0 / num_mb as f32;
        for mb in 0..num_mb {
            let x_mb = cache.microbatch_input(k, mb, mb_size)?;
            let (grads, gx) = model.segment_bwd(k, params, &x_mb, &gy_state[mb])?;
            concat_seg_into(&grads, &mut burst);
            fimd.accumulate(&mut i_df, &burst, scale)?;
            gy_state[mb] = gx;
        }
        report.ledger.backward += macs::bwd_macs(meta, k, meta.batch);
        report.ledger.fisher += macs::fisher_macs(meta, k, num_mb);

        // Balanced Dampening: scale (alpha, lambda) by S(l).
        let s = cfg.schedule.s(l, big_l);
        let alpha_l = (cfg.alpha * s) as f32;
        let lambda_l = (cfg.lambda * s) as f32;
        concat_seg_into(&params.seg[k], &mut theta);
        let stats = damp.dampen(&mut theta, &i_df, &global.per_seg[k], alpha_l, lambda_l)?;
        scatter_seg(&theta, &mut params.seg[k]);
        // Keep the int8 copies in lockstep with the edited masters —
        // only the segment the dampening write-back touched. Gated on
        // the *store* (not cfg.precision) deliberately: an f32-precision
        // run over an int8-deployed store must still leave the int8
        // copies valid (evals auto-detect them), at the cost of
        // re-snapping edits to the grid. For a pure-f32 ablation arm,
        // run on an unquantized clone of the store.
        if params.is_quantized() {
            params.requantize_segment(k);
        }
        report.ledger.dampen += macs::dampen_macs(meta, k);
        report.selected_per_depth[l - 1] = stats.selected;
        report.segments_edited = l;

        // Checkpoint: partial inference from the cached input of this
        // segment through the (now partially dampened) back-end.
        if cfg.checkpoints.contains(&l) {
            let logits = model.partial_forward_prec(params, k, &cache.inputs[k], cfg.precision)?;
            report.ledger.checkpoint += macs::partial_inference_macs(meta, k, meta.batch);
            let acc = forget_accuracy(&logits, forget_labels);
            report.checkpoint_trace.push((l, acc));
            if acc <= cfg.tau {
                report.stop_depth = Some(l);
                break; // layers l+1..L left untouched
            }
        }
    }

    report.fimd_elems = fimd.elems_streamed.get() - fimd_start;
    report.damp_elems = damp.elems_streamed.get() - damp_start;
    report.fimd_pad_elems = fimd.pad_elems.get() - fimd_pad_start;
    report.damp_pad_elems = damp.pad_elems.get() - damp_pad_start;
    Ok(report)
}

/// Scatter a segment burst back into its parameter tensors (inverse of
/// `fisher::concat_seg`).
pub fn scatter_seg(burst: &[f32], tensors: &mut [Tensor]) {
    let mut off = 0;
    for t in tensors.iter_mut() {
        let n = t.len();
        t.data.copy_from_slice(&burst[off..off + n]);
        off += n;
    }
    debug_assert_eq!(off, burst.len());
}

/// Batch-mean forget accuracy (Algorithm 1's `partial_inference` readout).
pub fn forget_accuracy(logits: &Tensor, labels: &[usize]) -> f64 {
    let preds = logits.argmax_rows();
    let hits = preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| p == l)
        .count();
    hits as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_checkpoint_grids_match_paper() {
        // RN: 10 segments, every 2 blocks -> {1,3,5,7,9,10}
        assert_eq!(default_checkpoints(10, 2), vec![1, 3, 5, 7, 9, 10]);
        // ViT: 14 segments, every 3 encoders -> {1,4,7,10,13,14}
        assert_eq!(default_checkpoints(14, 3), vec![1, 4, 7, 10, 13, 14]);
    }

    #[test]
    fn onehot_layout() {
        let t = make_onehot(&[2, 0], 3);
        assert_eq!(t.data, vec![0.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn scatter_roundtrip() {
        let mut ts = vec![Tensor::vec1(vec![0.0; 3]), Tensor::vec1(vec![0.0; 2])];
        scatter_seg(&[1.0, 2.0, 3.0, 4.0, 5.0], &mut ts);
        assert_eq!(ts[0].data, vec![1.0, 2.0, 3.0]);
        assert_eq!(ts[1].data, vec![4.0, 5.0]);
    }

    #[test]
    fn forget_accuracy_counts() {
        let logits = Tensor::new(vec![2, 3], vec![0.0, 5.0, 0.0, 9.0, 0.0, 0.0]).unwrap();
        assert_eq!(forget_accuracy(&logits, &[1, 0]), 1.0);
        assert_eq!(forget_accuracy(&logits, &[1, 2]), 0.5);
    }

    #[test]
    fn config_modes() {
        let ssd = UnlearnConfig::ssd(10.0, 1.0);
        assert!(ssd.checkpoints.is_empty());
        assert_eq!(ssd.schedule, Schedule::Uniform);
        let fic = UnlearnConfig::ficabu(
            10.0,
            1.0,
            Schedule::Sigmoid { cm: 5.0, br: 10.0 },
            vec![1, 3],
            0.05,
        );
        assert!(!fic.checkpoints.is_empty());
    }
}
