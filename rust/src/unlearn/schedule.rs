//! Balanced Dampening depth schedule — paper §III-B, eq. (5)/(6), Fig. 4.
//!
//! The scalars `(alpha, lambda)` become `S(l) * (alpha, lambda)` with
//!
//! ```text
//! S(l) = 1 + (b_r - 1) * (sigma(l) - sigma(1)) / (sigma(L) - sigma(1))
//! sigma(l) = 1 / (1 + exp(-(l - c_m)))
//! ```
//!
//! S(1) = 1 at the back-end (strongest edits, selection threshold and
//! dampening constant unscaled) rising monotonically to S(L) = b_r at the
//! front-end (weakest edits, protecting general features).

/// Depth profile applied to `(alpha, lambda)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    /// Layer-agnostic scalars — vanilla SSD.
    Uniform,
    /// Sigmoid profile of eq. (6).
    Sigmoid { cm: f64, br: f64 },
}

impl Schedule {
    /// S(l) for depth l in [1, L].
    pub fn s(&self, l: usize, big_l: usize) -> f64 {
        match self {
            Schedule::Uniform => 1.0,
            Schedule::Sigmoid { cm, br } => {
                let sig = |x: f64| 1.0 / (1.0 + (-(x - cm)).exp());
                let s1 = sig(1.0);
                let sl = sig(big_l as f64);
                if (sl - s1).abs() < 1e-12 {
                    return 1.0;
                }
                1.0 + (br - 1.0) * (sig(l as f64) - s1) / (sl - s1)
            }
        }
    }

    /// The paper's calibration (§III-B): smooth the layer-wise selected
    /// parameter distribution from an SSD pass, and center `c_m` at the
    /// mid-value between the smoothed extrema. `selected[i]` is indexed by
    /// depth l = i + 1; `b_r` defaults to 10 in the paper.
    pub fn from_selection_distribution(selected: &[u64], br: f64) -> Schedule {
        let l = selected.len();
        if l < 3 {
            return Schedule::Sigmoid { cm: (l as f64 + 1.0) / 2.0, br };
        }
        // 3-tap moving-average smoothing
        let sm: Vec<f64> = (0..l)
            .map(|i| {
                let lo = i.saturating_sub(1);
                let hi = (i + 1).min(l - 1);
                (lo..=hi).map(|j| selected[j] as f64).sum::<f64>() / (hi - lo + 1) as f64
            })
            .collect();
        let (mut lmax, mut lmin) = (0usize, 0usize);
        for i in 0..l {
            if sm[i] > sm[lmax] {
                lmax = i;
            }
            if sm[i] < sm[lmin] {
                lmin = i;
            }
        }
        // depths are 1-based
        let cm = ((lmax + 1) as f64 + (lmin + 1) as f64) / 2.0;
        Schedule::Sigmoid { cm, br }
    }

    /// The full profile, for Fig. 4 output.
    pub fn profile(&self, big_l: usize) -> Vec<f64> {
        (1..=big_l).map(|l| self.s(l, big_l)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop;
    use crate::util::prng::Pcg32;

    #[test]
    fn uniform_is_one() {
        for l in 1..=16 {
            assert_eq!(Schedule::Uniform.s(l, 16), 1.0);
        }
    }

    #[test]
    fn sigmoid_endpoints() {
        let s = Schedule::Sigmoid { cm: 8.0, br: 10.0 };
        assert!((s.s(1, 16) - 1.0).abs() < 1e-9, "S(1) must be 1");
        assert!((s.s(16, 16) - 10.0).abs() < 1e-9, "S(L) must be b_r");
    }

    #[test]
    fn sigmoid_monotone_property() {
        prop::check(
            "S(l) monotonically nondecreasing in l when b_r >= 1",
            100,
            |rng: &mut Pcg32, size| {
                let big_l = 3 + rng.below(size.max(3) + 13);
                let cm = rng.range(0.0, big_l as f32 + 2.0) as f64;
                let br = 1.0 + rng.range(0.0, 20.0) as f64;
                (big_l, cm, br)
            },
            |&(big_l, cm, br)| {
                let s = Schedule::Sigmoid { cm, br };
                let prof = s.profile(big_l);
                for w in prof.windows(2) {
                    if w[1] < w[0] - 1e-9 {
                        return Err(format!("decreasing: {w:?}"));
                    }
                }
                if (prof[0] - 1.0).abs() > 1e-9 {
                    return Err(format!("S(1)={}", prof[0]));
                }
                if (prof[big_l - 1] - br).abs() > 1e-9 {
                    return Err(format!("S(L)={} br={br}", prof[big_l - 1]));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn calibration_centers_between_extrema() {
        // back-end-heavy selection (depth 1 has most selections)
        let selected = [1000u64, 800, 400, 100, 50, 20, 10, 5];
        let s = Schedule::from_selection_distribution(&selected, 10.0);
        match s {
            Schedule::Sigmoid { cm, br } => {
                assert_eq!(br, 10.0);
                // max at l=1, min at l=8 -> cm = 4.5
                assert!((cm - 4.5).abs() < 1.0, "cm={cm}");
            }
            _ => panic!("expected sigmoid"),
        }
    }

    #[test]
    fn degenerate_lengths() {
        let s = Schedule::from_selection_distribution(&[5, 3], 10.0);
        assert!(matches!(s, Schedule::Sigmoid { .. }));
        // L = 1: S must not NaN
        assert!(Schedule::Sigmoid { cm: 1.0, br: 10.0 }.s(1, 1).is_finite());
    }

    #[test]
    fn empty_selection_still_yields_a_usable_schedule() {
        // a failed/skipped SSD calibration pass hands in no per-depth
        // counts — the fallback must be a finite sigmoid, not a panic
        let s = Schedule::from_selection_distribution(&[], 10.0);
        match s {
            Schedule::Sigmoid { cm, br } => {
                assert!(cm.is_finite());
                assert_eq!(br, 10.0);
            }
            _ => panic!("expected sigmoid"),
        }
        for (l, v) in s.profile(8).iter().enumerate() {
            assert!(v.is_finite() && *v >= 1.0 - 1e-9, "S({}) = {v}", l + 1);
        }
    }

    #[test]
    fn constant_selection_profile_is_finite() {
        // all depths equal: smoothed max == min, cm sits mid-array and
        // the sigmoid still interpolates 1 -> b_r without NaN
        let s = Schedule::from_selection_distribution(&[7; 10], 10.0);
        let prof = s.profile(10);
        assert!(prof.iter().all(|v| v.is_finite()));
        assert!((prof[0] - 1.0).abs() < 1e-9);
        assert!((prof[9] - 10.0).abs() < 1e-9);
    }

    #[test]
    fn extreme_br_values() {
        // b_r = 1: flat profile (BD degenerates to uniform strength)
        let flat = Schedule::Sigmoid { cm: 5.0, br: 1.0 };
        for v in flat.profile(12) {
            assert!((v - 1.0).abs() < 1e-9);
        }
        // b_r < 1: *stronger* front-end edits — monotone nonincreasing,
        // still finite and endpoint-exact
        let inv = Schedule::Sigmoid { cm: 5.0, br: 0.1 };
        let prof = inv.profile(12);
        assert!((prof[0] - 1.0).abs() < 1e-9);
        assert!((prof[11] - 0.1).abs() < 1e-9);
        for w in prof.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
        // huge b_r: no overflow / NaN
        let big = Schedule::Sigmoid { cm: 5.0, br: 1e12 };
        assert!(big.profile(12).iter().all(|v| v.is_finite()));
        assert!((big.s(12, 12) - 1e12).abs() < 1.0);
    }

    #[test]
    fn single_segment_model_profile() {
        // L = 1: sigma(L) == sigma(1) -> the guard returns S = 1
        let s = Schedule::Sigmoid { cm: 0.5, br: 10.0 };
        assert_eq!(s.profile(1), vec![1.0]);
        assert_eq!(Schedule::Uniform.profile(1), vec![1.0]);
        // calibration from a single-depth selection (< 3 taps branch)
        let cal = Schedule::from_selection_distribution(&[42], 10.0);
        match cal {
            Schedule::Sigmoid { cm, .. } => assert!((cm - 1.0).abs() < 1e-9),
            _ => panic!("expected sigmoid"),
        }
        assert_eq!(cal.profile(1), vec![1.0]);
    }

    #[test]
    fn profile_of_zero_segments_is_empty() {
        assert!(Schedule::Sigmoid { cm: 1.0, br: 10.0 }.profile(0).is_empty());
    }
}
