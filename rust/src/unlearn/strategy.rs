//! Pluggable unlearning methods over the decomposed engine stages.
//!
//! The paper frames FiCABU as one point in a method space: an SSD
//! dampening substrate, a Context-Adaptive early-stop controller, and
//! the Balanced Dampening depth schedule. [`Strategy`] is that space as
//! a trait — three stage hooks with the paper's defaults provided
//! ([`crate::unlearn::engine::stages`]) — so a new method overrides one
//! stage and inherits the rest, and the serving stack
//! ([`crate::coordinator::UnlearnSession`], the fleet, the CLI) never
//! changes when a method is added.
//!
//! | strategy   | checkpoints | schedule  | paper artifact |
//! |------------|-------------|-----------|----------------|
//! | [`Ssd`]    | none        | Uniform   | baseline, §II  |
//! | [`Cau`]    | paper grid  | Uniform   | Table I        |
//! | [`Bd`]     | none        | Sigmoid   | Table II       |
//! | [`Ficabu`] | paper grid  | Sigmoid   | Table IV       |
//!
//! All four consume the same serializable [`UnlearnConfig`] parameter
//! bag — the fleet coalesces on its fingerprint
//! (`coordinator::wal::config_fingerprint`) — so any of them travels to
//! worker replicas as plain data ([`Ficabu::from_config`] rebuilds the
//! strategy in-thread).

use anyhow::Result;

use crate::runtime::Precision;
use crate::unlearn::damp::DampStats;
use crate::unlearn::engine::{stages, Pass, StopVerdict, UnlearnConfig};
use crate::unlearn::schedule::Schedule;

/// One unlearning method: forget-Fisher estimation → dampening pass →
/// early-stop controller, with the paper's implementations provided.
///
/// Implementors supply the [`UnlearnConfig`] bag (and may override any
/// stage); [`run_strategy`](crate::unlearn::run_strategy) drives the
/// back-end-first depth loop.
pub trait Strategy {
    /// Human-readable method name (reports, logs).
    fn name(&self) -> &str;

    /// The serializable parameter bag this strategy consumes. The fleet
    /// fingerprints it into the request's batch key: two requests
    /// coalesce into one execution only when their config fingerprints
    /// (and model and spec) match.
    fn config(&self) -> &UnlearnConfig;

    /// Stage 1 — per-segment forget-Fisher estimate at depth `l`.
    /// Default: stream every microbatch VJP through the FIMD IP.
    ///
    /// Contract for overrides: this stage owns advancing the gradient
    /// chain. An implementation that does not delegate to
    /// [`stages::forget_fisher`] must call
    /// [`Pass::backprop_microbatch`] once per microbatch at this depth,
    /// or deeper segments will see a stale chain.
    fn forget_fisher(&self, pass: &mut Pass<'_>, l: usize) -> Result<Vec<f32>> {
        stages::forget_fisher(pass, l)
    }

    /// Stage 2 — dampening pass at depth `l` over the stage-1 estimate.
    /// Default: `S(l)`-scaled selective dampening through the IP.
    fn dampen(&self, pass: &mut Pass<'_>, l: usize, i_df: &[f32]) -> Result<DampStats> {
        stages::dampen(pass, self.config(), l, i_df)
    }

    /// Stage 3 — early-stop controller at depth `l`. Default:
    /// checkpoint partial inference against `tau`.
    fn early_stop(&self, pass: &mut Pass<'_>, l: usize) -> Result<StopVerdict> {
        stages::early_stop(pass, self.config(), l)
    }
}

macro_rules! provided_strategy {
    ($(#[$doc:meta])* $name:ident, $label:literal) => {
        $(#[$doc])*
        #[derive(Debug, Clone, PartialEq)]
        pub struct $name {
            cfg: UnlearnConfig,
        }

        impl $name {
            /// Builder: serve forward/eval at the given precision.
            pub fn with_precision(mut self, precision: Precision) -> $name {
                self.cfg.precision = precision;
                self
            }

            /// Unwrap the parameter bag (e.g. for a fleet `WorkerSpec`).
            pub fn into_config(self) -> UnlearnConfig {
                self.cfg
            }
        }

        impl Strategy for $name {
            fn name(&self) -> &str {
                $label
            }

            fn config(&self) -> &UnlearnConfig {
                &self.cfg
            }
        }
    };
}

provided_strategy!(
    /// Vanilla SSD: uniform schedule, no early stop — the dampening
    /// substrate and cost baseline (§II).
    Ssd,
    "SSD"
);

provided_strategy!(
    /// Context-Adaptive Unlearning: uniform schedule with checkpointed
    /// early stop (Table I).
    Cau,
    "CAU"
);

provided_strategy!(
    /// Balanced Dampening: sigmoid depth schedule, no early stop
    /// (Table II).
    Bd,
    "BD"
);

provided_strategy!(
    /// The full method: Balanced Dampening plus Context-Adaptive early
    /// stop (Table IV).
    Ficabu,
    "FiCABU"
);

impl Ssd {
    pub fn new(alpha: f64, lambda: f64) -> Ssd {
        Ssd { cfg: UnlearnConfig { alpha, lambda, ..Default::default() } }
    }
}

impl Cau {
    pub fn new(alpha: f64, lambda: f64, checkpoints: Vec<usize>, tau: f64) -> Cau {
        Cau { cfg: UnlearnConfig { alpha, lambda, checkpoints, tau, ..Default::default() } }
    }
}

impl Bd {
    pub fn new(alpha: f64, lambda: f64, schedule: Schedule) -> Bd {
        Bd { cfg: UnlearnConfig { alpha, lambda, schedule, ..Default::default() } }
    }
}

impl Ficabu {
    pub fn new(
        alpha: f64,
        lambda: f64,
        schedule: Schedule,
        checkpoints: Vec<usize>,
        tau: f64,
    ) -> Ficabu {
        Ficabu {
            cfg: UnlearnConfig {
                alpha,
                lambda,
                schedule,
                checkpoints,
                tau,
                precision: Precision::F32,
            },
        }
    }

    /// Rebuild a strategy from a travelled parameter bag — the general
    /// "run exactly what the bag says" constructor (SSD/CAU/BD are the
    /// restrictions of FiCABU expressible in the bag, so this one
    /// constructor serves every fleet replica).
    pub fn from_config(cfg: UnlearnConfig) -> Ficabu {
        Ficabu { cfg }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelMeta, SharedMeta};
    use crate::fisher::{FimdEngine, Importance};
    use crate::model::{Model, ParamStore};
    use crate::runtime::Runtime;
    use crate::unlearn::{run_strategy, DampEngine};
    use crate::util::prng::Pcg32;

    #[test]
    fn provided_strategies_encode_the_paper_grid() {
        let cps = vec![1, 3, 5];
        let sig = Schedule::Sigmoid { cm: 5.0, br: 10.0 };
        let ssd = Ssd::new(10.0, 1.0);
        let cau = Cau::new(10.0, 1.0, cps.clone(), 0.05);
        let bd = Bd::new(10.0, 1.0, sig.clone());
        let fic = Ficabu::new(10.0, 1.0, sig.clone(), cps.clone(), 0.05);
        assert_eq!(ssd.name(), "SSD");
        assert!(ssd.config().checkpoints.is_empty());
        assert_eq!(ssd.config().schedule, Schedule::Uniform);
        assert_eq!(cau.config().checkpoints, cps);
        assert_eq!(cau.config().schedule, Schedule::Uniform);
        assert!(bd.config().checkpoints.is_empty());
        assert_eq!(bd.config().schedule, sig);
        assert_eq!(fic.config().checkpoints, cps);
        assert_eq!(fic.config().schedule, sig);
        // the bag roundtrips through the fleet's travel format
        assert_eq!(Ficabu::from_config(fic.config().clone()), fic);
    }

    #[test]
    fn precision_builder_applies() {
        let s = Ssd::new(1.0, 1.0).with_precision(Precision::Int8);
        assert_eq!(s.config().precision, Precision::Int8);
        assert_eq!(s.clone().into_config(), *s.config());
    }

    /// A custom strategy overriding only the early-stop controller: the
    /// pluggability contract — one stage swapped, fisher/dampening
    /// inherited from the defaults.
    struct StopAtDepth {
        cfg: UnlearnConfig,
        depth: usize,
    }

    impl Strategy for StopAtDepth {
        fn name(&self) -> &str {
            "stop-at-depth"
        }
        fn config(&self) -> &UnlearnConfig {
            &self.cfg
        }
        fn early_stop(&self, pass: &mut Pass<'_>, l: usize) -> Result<StopVerdict> {
            if l >= self.depth {
                pass.report.stop_depth = Some(l);
                return Ok(StopVerdict::Stop);
            }
            Ok(StopVerdict::Continue)
        }
    }

    #[test]
    fn custom_strategy_overrides_one_stage() {
        let rt = Runtime::cpu().unwrap();
        let meta = ModelMeta::builtin("rn18slim").unwrap();
        let shared = SharedMeta::builtin();
        let model = Model::load(&rt, meta.clone()).unwrap();
        let mut params = ParamStore::init(&meta, 42);
        let before = params.clone();
        let fimd = FimdEngine::new(&rt, &shared).unwrap();
        let damp = DampEngine::new(&rt, &shared).unwrap();
        let mut global = Importance::zeros_like(&meta);
        global.floor(1e-6);

        let mut rng = Pcg32::seeded(7);
        let n: usize = meta.input_shape.iter().product::<usize>() * meta.batch;
        let mut shape = vec![meta.batch];
        shape.extend_from_slice(&meta.input_shape);
        let x = crate::tensor::Tensor::new(shape, rng.normal_vec(n, 1.0)).unwrap();
        let labels: Vec<usize> = (0..meta.batch).map(|i| i % meta.num_classes).collect();

        // alpha = 1 over the 1e-6 floor selects aggressively, so the
        // dampening edit below is unambiguous
        let strategy =
            StopAtDepth { cfg: UnlearnConfig { alpha: 1.0, ..Default::default() }, depth: 2 };
        let report =
            run_strategy(&model, &mut params, &x, &labels, &global, &fimd, &damp, &strategy)
                .unwrap();
        assert_eq!(report.stop_depth, Some(2));
        assert_eq!(report.segments_edited, 2);
        // front-end untouched: the default stages honored the custom stop
        for k in 0..meta.num_segments() - 2 {
            for (a, b) in before.seg[k].iter().zip(&params.seg[k]) {
                assert_eq!(a.data, b.data, "segment {k} was modified");
            }
        }
        // and the inherited default dampening actually edited the head
        let head = meta.seg_index(1);
        assert!(
            before.seg[head].iter().zip(&params.seg[head]).any(|(a, b)| a.data != b.data),
            "depth-1 segment should have been dampened"
        );
    }
}
