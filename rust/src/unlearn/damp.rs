//! Dampening engine: streams parameter bursts through the compiled Pallas
//! Dampening IP module — eq. (3) selection + eq. (4) strength, with the
//! Balanced-Dampening scaled `(alpha, lambda)` supplied per segment by the
//! coordinator (the IP itself is layer-agnostic, like the RTL).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::SharedMeta;
use crate::runtime::{Executable, ModuleSpec, Runtime};
use crate::tensor::Tensor;

pub struct DampEngine {
    exe: Arc<Executable>,
    pub tile: usize,
    /// Real elements streamed (tail padding excluded).
    pub elems_streamed: std::cell::Cell<u64>,
    /// Zero-pad lanes of tail bursts (cost IP cycles, never move DDR).
    pub pad_elems: std::cell::Cell<u64>,
}

/// Result of one segment-level dampening pass — what the
/// [`Strategy`](crate::unlearn::Strategy) dampening stage returns, so a
/// custom strategy can react to how aggressive the edit was.
#[derive(Debug, Clone, Default)]
pub struct DampStats {
    pub selected: u64,
    pub total: u64,
}

impl DampStats {
    /// Fraction of the segment's parameters the selection rule picked
    /// (0.0 for an empty segment).
    pub fn selection_ratio(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.selected as f64 / self.total as f64
        }
    }
}

impl DampEngine {
    pub fn new(rt: &Runtime, shared: &SharedMeta) -> Result<DampEngine> {
        Ok(DampEngine {
            exe: rt.load(&ModuleSpec::Dampen { shared: shared.clone() })?,
            tile: shared.tile,
            elems_streamed: std::cell::Cell::new(0),
            pad_elems: std::cell::Cell::new(0),
        })
    }

    /// In-place dampening of a segment burst. `theta`, `i_df`, `i_d` are
    /// the segment's concatenated parameters / forget importance / global
    /// importance; returns the selection count.
    ///
    /// Tail padding uses `i_df = 0` so padded lanes are never selected
    /// (`0 > alpha * i_d_pad` is false for the `i_d_pad = 1` filler).
    pub fn dampen(
        &self,
        theta: &mut [f32],
        i_df: &[f32],
        i_d: &[f32],
        alpha: f32,
        lambda: f32,
    ) -> Result<DampStats> {
        if theta.len() != i_df.len() || theta.len() != i_d.len() {
            bail!(
                "dampen: mismatched lens {} / {} / {}",
                theta.len(),
                i_df.len(),
                i_d.len()
            );
        }
        let t = self.tile;
        let alpha_t = Tensor::vec1(vec![alpha]);
        let lambda_t = Tensor::vec1(vec![lambda]);
        let mut stats = DampStats { selected: 0, total: theta.len() as u64 };
        // burst buffers hoisted out of the tile loop: only the tail tile
        // rewrites its padding lanes
        let mut tb = Tensor::vec1(vec![0.0f32; t]);
        let mut fb = Tensor::vec1(vec![0.0f32; t]);
        let mut db = Tensor::vec1(vec![1.0f32; t]);
        let mut off = 0;
        while off < theta.len() {
            let n = t.min(theta.len() - off);
            tb.data[..n].copy_from_slice(&theta[off..off + n]);
            fb.data[..n].copy_from_slice(&i_df[off..off + n]);
            db.data[..n].copy_from_slice(&i_d[off..off + n]);
            if n < t {
                tb.data[n..].fill(0.0);
                fb.data[n..].fill(0.0); // pad I_Df = 0 -> unselected
                db.data[n..].fill(1.0); // pad I_D = 1
            }
            let out = self.exe.run(&[&tb, &fb, &db, &alpha_t, &lambda_t])?;
            theta[off..off + n].copy_from_slice(&out[0].data[..n]);
            stats.selected += out[1].data[..n].iter().map(|&m| m as u64).sum::<u64>();
            self.elems_streamed.set(self.elems_streamed.get() + n as u64);
            self.pad_elems.set(self.pad_elems.get() + (t - n) as u64);
            off += n;
        }
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> (Runtime, DampEngine) {
        let rt = Runtime::cpu().unwrap();
        let shared = SharedMeta::builtin();
        let eng = DampEngine::new(&rt, &shared).unwrap();
        (rt, eng)
    }

    #[test]
    fn selective_dampening_semantics() {
        let (_rt, eng) = engine();
        let n = eng.tile + 100; // exercise tail padding
        let mut theta = vec![4.0f32; n];
        // every third param has forget-importance 20x global
        let i_df: Vec<f32> = (0..n).map(|i| if i % 3 == 0 { 20.0 } else { 0.5 }).collect();
        let i_d = vec![1.0f32; n];
        let stats = eng.dampen(&mut theta, &i_df, &i_d, 10.0, 1.0).unwrap();
        let want_sel = (0..n).filter(|i| i % 3 == 0).count() as u64;
        assert_eq!(stats.selected, want_sel);
        assert_eq!(stats.total, n as u64);
        // selected: beta = min(1/20, 1) = 0.05 -> 0.2
        assert!((theta[0] - 0.2).abs() < 1e-5);
        assert_eq!(theta[1], 4.0);
        assert_eq!(theta[n - 1], if (n - 1) % 3 == 0 { 0.2 } else { 4.0 });
    }

    #[test]
    fn alpha_lambda_scaling_changes_selection() {
        let (_rt, eng) = engine();
        let n = 2048;
        let i_df: Vec<f32> = (0..n).map(|i| i as f32 / n as f32 * 10.0).collect();
        let i_d = vec![1.0f32; n];
        let mut t1 = vec![1.0f32; n];
        let s1 = eng.dampen(&mut t1, &i_df, &i_d, 1.0, 1.0).unwrap();
        let mut t2 = vec![1.0f32; n];
        let s2 = eng.dampen(&mut t2, &i_df, &i_d, 5.0, 1.0).unwrap();
        assert!(s2.selected < s1.selected, "{} vs {}", s2.selected, s1.selected);
    }

    #[test]
    fn selection_ratio_guards_empty_segments() {
        let s = DampStats { selected: 3, total: 12 };
        assert_eq!(s.selection_ratio(), 0.25);
        assert_eq!(DampStats::default().selection_ratio(), 0.0);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let (_rt, eng) = engine();
        let mut theta = vec![0.0; 8];
        assert!(eng.dampen(&mut theta, &[0.0; 7], &[0.0; 8], 1.0, 1.0).is_err());
    }
}
