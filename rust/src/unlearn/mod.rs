//! The paper's method space: typed forget requests ([`ForgetSpec`])
//! executed by pluggable [`Strategy`] implementations — SSD substrate,
//! Context-Adaptive early stop, Balanced Dampening — over one
//! decomposed stage engine.
//!
//! ```
//! use ficabu::unlearn::{ForgetSpec, Ssd, Strategy};
//!
//! // what to forget: typed, canonicalizable, parseable
//! let spec = ForgetSpec::parse("classes:4,1,4")?;
//! assert_eq!(spec.canonical(), ForgetSpec::Classes(vec![1, 4]));
//! assert_eq!(spec.key(), ForgetSpec::Classes(vec![1, 4]).key());
//!
//! // how to forget: a strategy over the stage engine
//! let strategy = Ssd::new(10.0, 1.0);
//! assert!(strategy.config().checkpoints.is_empty());
//! # anyhow::Ok(())
//! ```

pub mod damp;
pub mod engine;
pub mod schedule;
pub mod spec;
pub mod strategy;

pub use damp::{DampEngine, DampStats};
pub use engine::{
    default_checkpoints, forget_accuracy, make_onehot, run_strategy, run_unlearning, Pass,
    StopVerdict, UnlearnConfig, UnlearnReport,
};
pub use schedule::Schedule;
pub use spec::{ForgetSpec, SpecKey};
pub use strategy::{Bd, Cau, Ficabu, Ssd, Strategy};
