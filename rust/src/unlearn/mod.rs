//! The paper's method: SSD substrate + Context-Adaptive Unlearning +
//! Balanced Dampening, unified in one configurable engine.

pub mod damp;
pub mod engine;
pub mod schedule;

pub use damp::{DampEngine, DampStats};
pub use engine::{
    default_checkpoints, forget_accuracy, make_onehot, run_unlearning, UnlearnConfig,
    UnlearnReport,
};
pub use schedule::Schedule;
