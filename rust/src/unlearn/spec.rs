//! Typed forget requests: what to unlearn, decoupled from how.
//!
//! The paper evaluates single-class events, but real edge deployments
//! need multi-class and per-example forgetting too (Xia et al., "Edge
//! Unlearning is Not 'on Edge'!"). [`ForgetSpec`] is the request
//! grammar every serving surface speaks — [`crate::coordinator`]'s
//! session/fleet, the CLI (`--forget class:3`, `--forget classes:1,4,7`,
//! `--forget samples:@file`), and the benches — while the *method* that
//! executes it stays behind [`crate::unlearn::Strategy`].
//!
//! Coalescing in the fleet dispatcher is keyed on [`SpecKey`], the
//! canonical (sorted, deduped, variant-collapsed) form of a spec plus a
//! precomputed hash: `classes:4,1,1` and `classes:1,4` are one queue
//! entry, and `classes:3` is the same request as `class:3`.

use std::fmt;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::util::json::Json;

/// What one unlearning event must forget.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ForgetSpec {
    /// Forget one class (the paper's per-event shape).
    Class(usize),
    /// Forget several classes in one event.
    Classes(Vec<usize>),
    /// Forget specific training samples by dataset index.
    Samples(Vec<usize>),
}

impl ForgetSpec {
    /// Canonical form: id lists sorted and deduped, and a single-class
    /// `Classes` collapsed to `Class` — two specs describe the same
    /// request exactly when their canonical forms are equal.
    pub fn canonical(&self) -> ForgetSpec {
        let sorted = |ids: &[usize]| {
            let mut v = ids.to_vec();
            v.sort_unstable();
            v.dedup();
            v
        };
        match self {
            ForgetSpec::Class(c) => ForgetSpec::Class(*c),
            ForgetSpec::Classes(ids) => {
                let v = sorted(ids);
                match v.as_slice() {
                    [one] => ForgetSpec::Class(*one),
                    _ => ForgetSpec::Classes(v),
                }
            }
            ForgetSpec::Samples(ids) => ForgetSpec::Samples(sorted(ids)),
        }
    }

    /// The dispatcher's coalescing / reply-routing key.
    pub fn key(&self) -> SpecKey {
        SpecKey::of(self)
    }

    /// Parse the CLI grammar: `class:3`, `classes:1,4,7`,
    /// `samples:0,9,44`, or `samples:@path` (file of whitespace/comma
    /// separated indices, `#` comments allowed).
    pub fn parse(s: &str) -> Result<ForgetSpec> {
        let (tag, body) = s
            .split_once(':')
            .with_context(|| format!("forget spec `{s}`: expected `kind:ids`"))?;
        let ids = |body: &str| -> Result<Vec<usize>> {
            let v: Vec<usize> = body
                .split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|t| {
                    t.parse()
                        .with_context(|| format!("forget spec `{s}`: bad index `{t}`"))
                })
                .collect::<Result<_>>()?;
            if v.is_empty() {
                bail!("forget spec `{s}`: no indices");
            }
            Ok(v)
        };
        match tag.trim() {
            "class" => Ok(ForgetSpec::Class(
                body.trim()
                    .parse()
                    .with_context(|| format!("forget spec `{s}`: bad class id"))?,
            )),
            "classes" => Ok(ForgetSpec::Classes(ids(body)?)),
            "samples" => {
                let body = body.trim();
                if let Some(path) = body.strip_prefix('@') {
                    let text = std::fs::read_to_string(path)
                        .with_context(|| format!("forget spec `{s}`: reading {path}"))?;
                    let cleaned: String = text
                        .lines()
                        .map(|l| l.split('#').next().unwrap_or(""))
                        .collect::<Vec<_>>()
                        .join(",");
                    Ok(ForgetSpec::Samples(ids(&cleaned.replace(char::is_whitespace, ","))?))
                } else {
                    Ok(ForgetSpec::Samples(ids(body)?))
                }
            }
            other => bail!("forget spec `{s}`: unknown kind `{other}` (class | classes | samples)"),
        }
    }

    /// Wire form of the spec: `{"class":3}`, `{"classes":[1,4]}`, or
    /// `{"samples":[0,9]}` — the JSON view of the CLI grammar, used by
    /// the HTTP `/forget` contract and [`Summary`](crate::coordinator::Summary)
    /// bodies. [`ForgetSpec::from_json`] inverts it.
    pub fn to_json(&self) -> Json {
        let nums = |ids: &[usize]| Json::Arr(ids.iter().map(|&i| Json::from(i)).collect());
        match self {
            ForgetSpec::Class(c) => Json::obj(vec![("class", Json::from(*c))]),
            ForgetSpec::Classes(ids) => Json::obj(vec![("classes", nums(ids))]),
            ForgetSpec::Samples(ids) => Json::obj(vec![("samples", nums(ids))]),
        }
    }

    /// Parse the wire form: either the [`ForgetSpec::to_json`] object
    /// shape or a JSON string holding the CLI grammar (`"classes:1,4"`)
    /// — the two are one typed API. The result is canonical (sorted,
    /// deduped, variant-collapsed), mirroring what admission keys on.
    pub fn from_json(j: &Json) -> Result<ForgetSpec> {
        let ids = |v: &Json, what: &str| -> Result<Vec<usize>> {
            let arr = v
                .as_arr()
                .with_context(|| format!("forget spec: `{what}` must be an array of indices"))?;
            if arr.is_empty() {
                bail!("forget spec: `{what}` is empty");
            }
            arr.iter()
                .map(|x| {
                    x.as_i64()
                        .filter(|&i| i >= 0)
                        .map(|i| i as usize)
                        .with_context(|| format!("forget spec: `{what}` has a non-index entry {x}"))
                })
                .collect()
        };
        let spec = match j {
            Json::Str(s) => ForgetSpec::parse(s)?,
            Json::Obj(kv) => match kv.as_slice() {
                [(k, v)] if k.as_str() == "class" => ForgetSpec::Class(
                    v.as_i64()
                        .filter(|&c| c >= 0)
                        .map(|c| c as usize)
                        .with_context(|| format!("forget spec: `class` must be an index, got {v}"))?,
                ),
                [(k, v)] if k.as_str() == "classes" => ForgetSpec::Classes(ids(v, "classes")?),
                [(k, v)] if k.as_str() == "samples" => ForgetSpec::Samples(ids(v, "samples")?),
                _ => bail!(
                    "forget spec: expected exactly one of `class`, `classes`, `samples`, got {j}"
                ),
            },
            other => bail!("forget spec: expected a string or object, got {other}"),
        };
        Ok(spec.canonical())
    }

    /// Check ids against the serving model/dataset bounds.
    pub fn validate(&self, num_classes: usize, num_samples: usize) -> Result<()> {
        match self {
            ForgetSpec::Class(c) => {
                if *c >= num_classes {
                    bail!("forget {self}: class {c} out of range ({num_classes} classes)");
                }
            }
            ForgetSpec::Classes(ids) => {
                if ids.is_empty() {
                    bail!("forget {self}: empty class list");
                }
                if let Some(c) = ids.iter().find(|&&c| c >= num_classes) {
                    bail!("forget {self}: class {c} out of range ({num_classes} classes)");
                }
            }
            ForgetSpec::Samples(ids) => {
                if ids.is_empty() {
                    bail!("forget {self}: empty sample list");
                }
                if let Some(i) = ids.iter().find(|&&i| i >= num_samples) {
                    bail!("forget {self}: sample {i} out of range ({num_samples} samples)");
                }
            }
        }
        Ok(())
    }

    /// The forget set D_f: dataset indices this spec designates.
    pub fn pool(&self, ds: &Dataset) -> Result<Vec<usize>> {
        self.validate(ds.num_classes, ds.len())?;
        let pool = match self.canonical() {
            ForgetSpec::Class(c) => ds.class_indices(c),
            ForgetSpec::Classes(ids) => (0..ds.len())
                .filter(|&i| ids.binary_search(&ds.labels[i]).is_ok())
                .collect(),
            ForgetSpec::Samples(ids) => ids,
        };
        if pool.is_empty() {
            bail!("forget {self}: no samples in the dataset match");
        }
        Ok(pool)
    }

    /// The retain set D_r: the complement of [`ForgetSpec::pool`].
    pub fn retain(&self, ds: &Dataset) -> Result<Vec<usize>> {
        Ok(Self::retain_of(&self.pool(ds)?, ds.len()))
    }

    /// The retain complement of an already-computed forget pool —
    /// callers that hold the [`ForgetSpec::pool`] result avoid a second
    /// full-dataset scan. `pool` must be sorted (every canonical
    /// variant's pool is).
    pub fn retain_of(pool: &[usize], num_samples: usize) -> Vec<usize> {
        debug_assert!(pool.windows(2).all(|w| w[0] < w[1]), "pool must be sorted/deduped");
        (0..num_samples).filter(|i| pool.binary_search(i).is_err()).collect()
    }
}

impl fmt::Display for ForgetSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let join = |ids: &[usize]| {
            ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",")
        };
        match self {
            ForgetSpec::Class(c) => write!(f, "class:{c}"),
            ForgetSpec::Classes(ids) => write!(f, "classes:{}", join(ids)),
            ForgetSpec::Samples(ids) => write!(f, "samples:{}", join(ids)),
        }
    }
}

/// Canonical queue/coalescing key of a [`ForgetSpec`]: the canonical
/// spec plus its FNV-1a hash, precomputed so dispatcher queue scans
/// compare a `u64` first and fall back to the exact spec (no false
/// coalescing on hash collision).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SpecKey {
    hash: u64,
    spec: ForgetSpec,
}

impl SpecKey {
    pub fn of(spec: &ForgetSpec) -> SpecKey {
        let spec = spec.canonical();
        let (tag, ids): (u64, &[usize]) = match &spec {
            ForgetSpec::Class(c) => (1, std::slice::from_ref(c)),
            ForgetSpec::Classes(ids) => (2, ids),
            ForgetSpec::Samples(ids) => (3, ids),
        };
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        mix(tag);
        for &i in ids {
            mix(i as u64);
        }
        SpecKey { hash: h, spec }
    }

    /// The precomputed FNV-1a hash (also usable as a cheap shard key).
    pub fn hash64(&self) -> u64 {
        self.hash
    }

    /// The canonical spec this key routes.
    pub fn spec(&self) -> &ForgetSpec {
        &self.spec
    }
}

impl fmt::Display for SpecKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}#{:016x}", self.spec, self.hash)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetCfg;
    use crate::util::json::Json;

    fn ds() -> Dataset {
        let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
        crate::data::cifar20_like(&cfg).0
    }

    #[test]
    fn canonical_sorts_dedupes_and_collapses() {
        assert_eq!(
            ForgetSpec::Classes(vec![4, 1, 4, 1]).canonical(),
            ForgetSpec::Classes(vec![1, 4])
        );
        assert_eq!(ForgetSpec::Classes(vec![3, 3]).canonical(), ForgetSpec::Class(3));
        assert_eq!(
            ForgetSpec::Samples(vec![9, 2, 9]).canonical(),
            ForgetSpec::Samples(vec![2, 9])
        );
    }

    #[test]
    fn keys_identify_equivalent_requests() {
        assert_eq!(ForgetSpec::Classes(vec![4, 1]).key(), ForgetSpec::Classes(vec![1, 4, 4]).key());
        assert_eq!(ForgetSpec::Classes(vec![7]).key(), ForgetSpec::Class(7).key());
        assert_ne!(ForgetSpec::Class(1).key(), ForgetSpec::Class(2).key());
        // same ids, different kind: distinct requests
        assert_ne!(ForgetSpec::Classes(vec![1, 4]).key(), ForgetSpec::Samples(vec![1, 4]).key());
    }

    #[test]
    fn parse_grammar() {
        assert_eq!(ForgetSpec::parse("class:3").unwrap(), ForgetSpec::Class(3));
        assert_eq!(
            ForgetSpec::parse("classes:1,4,7").unwrap(),
            ForgetSpec::Classes(vec![1, 4, 7])
        );
        assert_eq!(
            ForgetSpec::parse("samples: 0, 9 ,44").unwrap(),
            ForgetSpec::Samples(vec![0, 9, 44])
        );
        assert!(ForgetSpec::parse("class:x").is_err());
        assert!(ForgetSpec::parse("bogus:1").is_err());
        assert!(ForgetSpec::parse("classes:").is_err());
        assert!(ForgetSpec::parse("noseparator").is_err());
    }

    #[test]
    fn parse_samples_from_file() {
        let dir = std::env::temp_dir().join("ficabu_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("idx.txt");
        std::fs::write(&p, "0 5\n9, 12 # keep these\n").unwrap();
        let spec = ForgetSpec::parse(&format!("samples:@{}", p.display())).unwrap();
        assert_eq!(spec, ForgetSpec::Samples(vec![0, 5, 9, 12]));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn validate_bounds() {
        assert!(ForgetSpec::Class(19).validate(20, 100).is_ok());
        assert!(ForgetSpec::Class(20).validate(20, 100).is_err());
        assert!(ForgetSpec::Classes(vec![]).validate(20, 100).is_err());
        assert!(ForgetSpec::Samples(vec![99]).validate(20, 100).is_ok());
        assert!(ForgetSpec::Samples(vec![100]).validate(20, 100).is_err());
    }

    #[test]
    fn pools_partition_the_dataset() {
        let ds = ds();
        let spec = ForgetSpec::Classes(vec![2, 5]);
        let pool = spec.pool(&ds).unwrap();
        assert_eq!(pool.len(), 8, "4 per class x 2 classes");
        assert!(pool.iter().all(|&i| ds.labels[i] == 2 || ds.labels[i] == 5));
        let retain = spec.retain(&ds).unwrap();
        assert_eq!(pool.len() + retain.len(), ds.len());
        assert!(retain.iter().all(|&i| ds.labels[i] != 2 && ds.labels[i] != 5));
    }

    #[test]
    fn sample_pool_is_the_id_list() {
        let ds = ds();
        let spec = ForgetSpec::Samples(vec![7, 3, 3]);
        assert_eq!(spec.pool(&ds).unwrap(), vec![3, 7]);
        assert_eq!(spec.retain(&ds).unwrap().len(), ds.len() - 2);
    }

    #[test]
    fn json_roundtrips_canonically() {
        // property: from_json(to_json(s)) == s.canonical(), across shapes
        // including non-canonical id lists
        for spec in [
            ForgetSpec::Class(3),
            ForgetSpec::Classes(vec![1, 4, 7]),
            ForgetSpec::Classes(vec![4, 1, 4, 1]),
            ForgetSpec::Classes(vec![9]),
            ForgetSpec::Samples(vec![9, 2, 9]),
            ForgetSpec::Samples(vec![0]),
        ] {
            let j = spec.to_json();
            assert_eq!(ForgetSpec::from_json(&j).unwrap(), spec.canonical(), "via {j}");
            // and the emitted text re-parses to the same wire object
            let text = j.to_string();
            let j2 = Json::parse(&text).unwrap();
            assert_eq!(ForgetSpec::from_json(&j2).unwrap(), spec.canonical(), "via text {text}");
        }
    }

    #[test]
    fn from_json_accepts_the_cli_grammar_as_a_string() {
        let j = Json::parse(r#""classes:4,1,4""#).unwrap();
        assert_eq!(ForgetSpec::from_json(&j).unwrap(), ForgetSpec::Classes(vec![1, 4]));
        let j = Json::parse(r#""class:7""#).unwrap();
        assert_eq!(ForgetSpec::from_json(&j).unwrap(), ForgetSpec::Class(7));
    }

    #[test]
    fn from_json_rejects_malformed_shapes() {
        for bad in [
            "42",                           // not a string/object
            "{}",                           // no variant key
            r#"{"class": "three"}"#,        // class not an index
            r#"{"class": -1}"#,             // negative index
            r#"{"class": 1.5}"#,            // fractional index
            r#"{"classes": []}"#,           // empty id list
            r#"{"classes": 3}"#,            // ids not an array
            r#"{"samples": [1, "x"]}"#,     // non-index entry
            r#"{"class": 1, "classes": [2]}"#, // ambiguous
            r#""bogus:1""#,                 // unknown CLI kind
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ForgetSpec::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn display_roundtrips_through_parse() {
        for spec in [
            ForgetSpec::Class(3),
            ForgetSpec::Classes(vec![1, 4, 7]),
            ForgetSpec::Samples(vec![0, 9]),
        ] {
            assert_eq!(ForgetSpec::parse(&spec.to_string()).unwrap(), spec);
        }
    }
}
