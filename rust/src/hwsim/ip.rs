//! The two specialized IPs (Fig. 5a/5b): double-buffered element-streaming
//! pipelines, plus their measured speedups over running the same loop on
//! the Rocket core (11.7x FIMD, 7.9x Dampening — §IV-A).

/// A double-buffered element pipeline: 1 element/cycle once full, `stages`
/// cycles of fill per burst; the double buffer hides the LOAD/STORE of the
/// next/previous burst behind compute.
#[derive(Debug, Clone)]
pub struct StreamingIp {
    pub name: &'static str,
    pub stages: u64,
    /// Burst (tile) size in elements — matches the Pallas TILE.
    pub burst: u64,
    /// Cycles/element when the same computation runs on the core.
    pub core_cycles_per_elem: f64,
}

impl StreamingIp {
    pub fn fimd(burst: u64) -> StreamingIp {
        // LOAD -> SQUARE -> ACCUMULATE -> STORE; 11.7x faster than core
        StreamingIp { name: "FIMD", stages: 4, burst, core_cycles_per_elem: 11.7 }
    }

    pub fn dampening(burst: u64) -> StreamingIp {
        // LOAD -> COMPARE -> bCALC -> MULTIPLY -> STORE; 7.9x over core
        StreamingIp { name: "DAMP", stages: 5, burst, core_cycles_per_elem: 7.9 }
    }

    /// Cycles to stream `elems` through the IP.
    pub fn ip_cycles(&self, elems: u64) -> u64 {
        if elems == 0 {
            return 0;
        }
        let bursts = elems.div_ceil(self.burst);
        // one fill per burst train (double buffering overlaps the rest)
        elems + self.stages * bursts.min(1) + (bursts - 1)
    }

    /// Cycles for the same work executed on the Rocket core (baseline
    /// processor, no IP).
    pub fn core_cycles(&self, elems: u64) -> u64 {
        (elems as f64 * self.core_cycles_per_elem).ceil() as u64
    }

    /// Effective speedup on a given stream length.
    pub fn speedup(&self, elems: u64) -> f64 {
        self.core_cycles(elems) as f64 / self.ip_cycles(elems).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fimd_speedup_approaches_11_7() {
        let ip = StreamingIp::fimd(8192);
        let s = ip.speedup(1 << 20);
        assert!((s - 11.7).abs() < 0.1, "speedup {s}");
    }

    #[test]
    fn dampening_speedup_approaches_7_9() {
        let ip = StreamingIp::dampening(8192);
        let s = ip.speedup(1 << 20);
        assert!((s - 7.9).abs() < 0.1, "speedup {s}");
    }

    #[test]
    fn zero_elems_zero_cycles() {
        assert_eq!(StreamingIp::fimd(8192).ip_cycles(0), 0);
    }

    #[test]
    fn fill_amortized() {
        let ip = StreamingIp::fimd(8192);
        // long streams: cycles/elem -> 1
        let c = ip.ip_cycles(1 << 22);
        assert!((c as f64 / (1 << 22) as f64 - 1.0).abs() < 0.01);
    }
}
