//! DDR traffic model: bytes moved per unlearning phase and the cycles they
//! cost when the pipeline is bandwidth-bound.
//!
//! The prototype streams operands from DRAM through the custom DMA into
//! the 64 KB scratchpad (§IV-A). We model a 64-bit DDR interface at the
//! system clock: 8 bytes/cycle sustained.

#[derive(Debug, Clone)]
pub struct DdrModel {
    pub bytes_per_cycle: f64,
}

impl Default for DdrModel {
    fn default() -> Self {
        DdrModel { bytes_per_cycle: 8.0 }
    }
}

/// Traffic for one unlearning run, in bytes.
#[derive(Debug, Clone, Default)]
pub struct Traffic {
    /// Activations written once (Step-0 cache) and re-read at checkpoints.
    pub activations: u64,
    /// Parameters read for GEMM/bwd, read+written by dampening.
    pub params: u64,
    /// Gradients streamed GEMM -> FIMD.
    pub grads: u64,
    /// Stored global importance read by dampening.
    pub importance: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.activations + self.params + self.grads + self.importance
    }
}

impl DdrModel {
    pub fn cycles(&self, t: &Traffic) -> u64 {
        (t.total() as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// Element precision of the modeled data streams. Since PR 3 this is
/// the runtime's own [`Precision`] — the coordinator *executes* int8
/// forwards, so the hwsim shares the enum instead of assuming a
/// deployment mode (`UnlearnReport::precision` carries what actually
/// ran).
pub use crate::runtime::Precision;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_sums() {
        let t = Traffic { activations: 10, params: 20, grads: 30, importance: 40 };
        assert_eq!(t.total(), 100);
        let ddr = DdrModel::default();
        assert_eq!(ddr.cycles(&t), 13); // ceil(100/8)
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Int8.bytes(), 1);
        assert_eq!(Precision::F32.bytes(), 4);
    }
}
