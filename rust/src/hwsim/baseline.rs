//! Baseline processor (§IV-B): identical platform — Rocket core, VTA GEMM,
//! memory subsystem — but WITHOUT the specialized unlearning IPs. Fisher
//! estimation and dampening execute as software loops on the core
//! (11.7x / 7.9x more cycles per element) and do NOT overlap the GEMM
//! stream; SSD runs here as the energy reference of Table IV.

use crate::hwsim::ip::StreamingIp;
use crate::hwsim::mem::{DdrModel, Precision, Traffic};
use crate::hwsim::pipeline::{PhaseTimes, RunCost};
use crate::hwsim::power::PowerModel;
use crate::hwsim::vta::VtaGemm;
use crate::hwsim::cycles_to_seconds;
use crate::unlearn::UnlearnReport;

#[derive(Debug, Clone)]
pub struct BaselineProcessor {
    pub vta: VtaGemm,
    pub fimd_sw: StreamingIp,
    pub damp_sw: StreamingIp,
    pub ddr: DdrModel,
    pub power: PowerModel,
    pub precision: Precision,
}

impl BaselineProcessor {
    pub fn new(tile: usize, precision: Precision) -> BaselineProcessor {
        BaselineProcessor {
            vta: VtaGemm::default(),
            fimd_sw: StreamingIp::fimd(tile as u64),
            damp_sw: StreamingIp::dampening(tile as u64),
            ddr: DdrModel::default(),
            power: PowerModel::default(),
            precision,
        }
    }

    fn traffic(&self, report: &UnlearnReport) -> Traffic {
        let eb = crate::hwsim::pipeline::effective_precision(self.precision, report).bytes();
        Traffic {
            activations: 2 * report.act_cache_bytes as u64 / 4 * eb,
            params: 3 * report.damp_elems * eb,
            grads: 4 * report.fimd_elems,
            importance: 4 * report.damp_elems,
        }
    }

    /// Cost of a run on the IP-less platform: GEMM on VTA, elementwise
    /// phases serialized on the core. The software Fisher/dampening
    /// loops iterate real elements only — no burst padding on the core.
    pub fn cost(&self, report: &UnlearnReport) -> RunCost {
        let gemm = crate::hwsim::pipeline::gemm_cycles(&self.vta, report);
        let fimd = self.fimd_sw.core_cycles(report.fimd_elems);
        let damp = self.damp_sw.core_cycles(report.damp_elems);
        let mem = self.ddr.cycles(&self.traffic(report));
        // no IP overlap: compute phases serialize; memory still overlaps
        let compute = gemm + fimd + damp;
        let total = compute.max(mem);
        let seconds = cycles_to_seconds(total);
        let power = self.power.baseline_total_mw();
        RunCost {
            phases: PhaseTimes {
                gemm_cycles: gemm,
                fimd_cycles: fimd,
                damp_cycles: damp,
                mem_cycles: mem,
                total_cycles: total,
            },
            seconds,
            energy_mj: PowerModel::energy_mj(power, seconds),
            power_mw: power,
        }
    }
}

/// Energy savings (Table IV "ES"): fraction of the reference energy saved.
pub fn energy_savings(ficabu: &RunCost, ssd_on_baseline: &RunCost) -> f64 {
    1.0 - ficabu.energy_mj / ssd_on_baseline.energy_mj
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::FicabuProcessor;
    use crate::model::macs::MacLedger;
    use crate::unlearn::UnlearnReport;

    fn report(fwd: u64, bwd: u64, fimd: u64, damp: u64) -> UnlearnReport {
        UnlearnReport {
            ledger: MacLedger { forward: fwd, backward: bwd, ..Default::default() },
            fimd_elems: fimd,
            damp_elems: damp,
            act_cache_bytes: 1 << 20,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_slower_than_ficabu_same_work() {
        let r = report(1 << 28, 1 << 29, 1 << 22, 1 << 22);
        let fic = FicabuProcessor::new(8192, Precision::Int8).cost(&r);
        let base = BaselineProcessor::new(8192, Precision::Int8).cost(&r);
        assert!(base.phases.total_cycles > fic.phases.total_cycles);
        // serialized elementwise work shows up in the total
        assert_eq!(
            base.phases.total_cycles,
            base.phases.gemm_cycles + base.phases.fimd_cycles + base.phases.damp_cycles
        );
    }

    #[test]
    fn energy_savings_positive_for_smaller_run() {
        let fic = FicabuProcessor::new(8192, Precision::Int8)
            .cost(&report(1 << 26, 1 << 27, 1 << 18, 1 << 18));
        let ssd = BaselineProcessor::new(8192, Precision::Int8)
            .cost(&report(1 << 29, 1 << 30, 1 << 22, 1 << 22));
        let es = energy_savings(&fic, &ssd);
        assert!(es > 0.8 && es < 1.0, "es = {es}");
    }

    #[test]
    fn baseline_power_excludes_ips() {
        let b = BaselineProcessor::new(8192, Precision::Int8);
        let p = PowerModel::default();
        assert!((b.power.baseline_total_mw() - (p.total_mw() - 0.81)).abs() < 1e-9);
    }
}
