//! Power/resource model — Table III.
//!
//! The per-block mW (45 nm Design Compiler) and FPGA LUT/FF counts are the
//! paper's own report, used here as calibrated constants; energies follow
//! from these constants times the *simulated* phase durations, so relative
//! results (ES) are derived from workload, not copied.

/// One row of Table III.
#[derive(Debug, Clone)]
pub struct PowerRow {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    pub mw: f64,
}

#[derive(Debug, Clone)]
pub struct PowerModel {
    pub rows: Vec<PowerRow>,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Table III (FiCABU processor, 45 nm / Kintex-7)
        let rows = vec![
            PowerRow { name: "RISC-V Rocket core", luts: 15_246, ffs: 9_756, mw: 11.20 },
            PowerRow { name: "On-chip SRAM (64KB)", luts: 354, ffs: 653, mw: 1.71 },
            PowerRow { name: "Peripherals", luts: 1_556, ffs: 951, mw: 4.07 },
            PowerRow { name: "uNoC / interconnect", luts: 4_329, ffs: 7_562, mw: 5.68 },
            PowerRow { name: "DDR controller", luts: 8_102, ffs: 7_514, mw: 88.62 },
            PowerRow { name: "AXI DMA", luts: 5_234, ffs: 652, mw: 33.90 },
            PowerRow { name: "VTA (GEMM)", luts: 34_529, ffs: 7_186, mw: 39.90 },
            PowerRow { name: "Specialized IPs (FIMD+Damp)", luts: 2_185, ffs: 785, mw: 0.81 },
        ];
        PowerModel { rows }
    }
}

impl PowerModel {
    pub fn total_mw(&self) -> f64 {
        self.rows.iter().map(|r| r.mw).sum()
    }

    pub fn total_luts(&self) -> u64 {
        self.rows.iter().map(|r| r.luts).sum()
    }

    pub fn total_ffs(&self) -> u64 {
        self.rows.iter().map(|r| r.ffs).sum()
    }

    pub fn block_mw(&self, name: &str) -> f64 {
        self.rows
            .iter()
            .find(|r| r.name.contains(name))
            .map(|r| r.mw)
            .unwrap_or(0.0)
    }

    /// The Unlearning Engine aggregate (VTA + specialized IPs + DMA), as
    /// grouped in the paper's Table III discussion.
    pub fn unlearning_engine_mw(&self) -> f64 {
        self.block_mw("VTA") + self.block_mw("Specialized IPs")
    }

    /// Baseline processor (same components minus the specialized IPs).
    pub fn baseline_total_mw(&self) -> f64 {
        self.total_mw() - self.block_mw("Specialized IPs")
    }

    /// Energy in millijoules for a duration at a given power.
    pub fn energy_mj(mw: f64, seconds: f64) -> f64 {
        mw * seconds // mW * s = mJ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_table_iii() {
        let p = PowerModel::default();
        assert!((p.total_mw() - 185.89).abs() < 0.02, "{}", p.total_mw());
        // paper: total LUTs 71,535 / FFs 35,059
        assert_eq!(p.total_luts(), 71_535);
        assert_eq!(p.total_ffs(), 35_059);
    }

    #[test]
    fn ip_share_is_tiny() {
        let p = PowerModel::default();
        let share = p.block_mw("Specialized IPs") / p.total_mw();
        assert!((share - 0.0044).abs() < 0.001, "share {share}"); // 0.44%
    }

    #[test]
    fn engine_share() {
        let p = PowerModel::default();
        // paper: Unlearning Engine 40.71 mW (21.9%)
        assert!((p.unlearning_engine_mw() - 40.71).abs() < 0.01);
        let share = p.unlearning_engine_mw() / p.total_mw();
        assert!((share - 0.219).abs() < 0.005);
    }
}
