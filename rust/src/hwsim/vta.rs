//! VTA-like GEMM backbone: fixed-size patch (tile) streaming engine.
//!
//! The open-source VTA configuration used for bring-up (§IV-A) computes a
//! 16x16x16 INT8 patch GEMM per cycle-group; we model throughput as a
//! 16x16 PE array retiring 256 MACs/cycle once the pipeline is full, with
//! a per-patch fill overhead folded into an efficiency factor.

#[derive(Debug, Clone)]
pub struct VtaGemm {
    /// PE array edge (patch is `pe x pe`).
    pub pe: u64,
    /// Fraction of peak sustained on real layer shapes (load/store queue
    /// stalls, edge patches). 0.85 is typical of streaming VTA workloads.
    pub efficiency: f64,
}

impl Default for VtaGemm {
    fn default() -> Self {
        VtaGemm { pe: 16, efficiency: 0.85 }
    }
}

impl VtaGemm {
    pub fn macs_per_cycle(&self) -> f64 {
        (self.pe * self.pe) as f64 * self.efficiency
    }

    pub fn cycles_for_macs(&self, macs: u64) -> u64 {
        (macs as f64 / self.macs_per_cycle()).ceil() as u64
    }

    /// Patch count for a given GEMM problem (used by the pipeline trace).
    pub fn patches(&self, m: u64, n: u64, k: u64) -> u64 {
        let ceil = |a: u64, b: u64| a.div_ceil(b);
        ceil(m, self.pe) * ceil(n, self.pe) * ceil(k, self.pe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_scales() {
        let v = VtaGemm::default();
        assert_eq!(v.cycles_for_macs(0), 0);
        let c1 = v.cycles_for_macs(1_000_000);
        let c2 = v.cycles_for_macs(2_000_000);
        assert!((c2 as f64 / c1 as f64 - 2.0).abs() < 0.01);
        // 256 MACs/cycle peak, 0.85 efficiency
        assert!((v.macs_per_cycle() - 217.6).abs() < 1e-9);
    }

    #[test]
    fn patch_counting() {
        let v = VtaGemm::default();
        assert_eq!(v.patches(16, 16, 16), 1);
        assert_eq!(v.patches(17, 16, 16), 2);
        assert_eq!(v.patches(64, 64, 64), 64);
    }
}
