//! End-to-end streaming pipeline (Fig. 5c) and whole-run cost model.
//!
//! The three engines align at patch cadence: GEMM -> FIMD -> DAMPENING.
//! With double-buffered IPs whose per-segment work is far smaller than the
//! GEMM window (MAC ledger test), the steady-state run time is the
//! max of the three streams, bounded below by DDR bandwidth.

use crate::hwsim::ip::StreamingIp;
use crate::hwsim::mem::{DdrModel, Precision, Traffic};
use crate::hwsim::power::PowerModel;
use crate::hwsim::vta::VtaGemm;
use crate::hwsim::cycles_to_seconds;
use crate::unlearn::UnlearnReport;

#[derive(Debug, Clone, Default)]
pub struct PhaseTimes {
    pub gemm_cycles: u64,
    pub fimd_cycles: u64,
    pub damp_cycles: u64,
    pub mem_cycles: u64,
    pub total_cycles: u64,
}

#[derive(Debug, Clone, Default)]
pub struct RunCost {
    pub phases: PhaseTimes,
    pub seconds: f64,
    pub energy_mj: f64,
    pub power_mw: f64,
}

/// The FiCABU processor: VTA + FIMD IP + Dampening IP, streaming pipeline.
#[derive(Debug, Clone)]
pub struct FicabuProcessor {
    pub vta: VtaGemm,
    pub fimd: StreamingIp,
    pub damp: StreamingIp,
    pub ddr: DdrModel,
    pub power: PowerModel,
    /// Deployment assumption used when a report did not execute int8
    /// (legacy fake-quant mode); an int8-*executed* report overrides it.
    pub precision: Precision,
}

/// Data-stream precision to charge for a report: what actually executed
/// (int8-served run) wins over the processor's deployment assumption.
pub(crate) fn effective_precision(assumed: Precision, report: &UnlearnReport) -> Precision {
    if report.precision == Precision::Int8 {
        Precision::Int8
    } else {
        assumed
    }
}

/// MAC-stream cycles on the int8 PE array. For an int8-*executed*
/// report, the forward/checkpoint MACs really streamed as int8 and the
/// f32 gradient chain occupies 4 lanes per MAC; otherwise every MAC is
/// charged at PE rate (the legacy deployment assumption).
pub(crate) fn gemm_cycles(vta: &VtaGemm, report: &UnlearnReport) -> u64 {
    let l = &report.ledger;
    match report.precision {
        Precision::Int8 => vta.cycles_for_macs(l.forward + l.checkpoint + 4 * l.backward),
        Precision::F32 => vta.cycles_for_macs(l.forward + l.backward + l.checkpoint),
    }
}

impl FicabuProcessor {
    pub fn new(tile: usize, precision: Precision) -> FicabuProcessor {
        FicabuProcessor {
            vta: VtaGemm::default(),
            fimd: StreamingIp::fimd(tile as u64),
            damp: StreamingIp::dampening(tile as u64),
            ddr: DdrModel::default(),
            power: PowerModel::default(),
            precision,
        }
    }

    /// DDR traffic estimate from an engine report (see mem.rs). Charged
    /// from the precision the report *executed* (int8 activations and
    /// parameters move 1 byte/element), falling back to the processor's
    /// deployment assumption for legacy f32 reports. Pad lanes of IP
    /// bursts never appear here — they cost cycles, not bandwidth.
    pub fn traffic(&self, report: &UnlearnReport) -> Traffic {
        let eb = effective_precision(self.precision, report).bytes();
        Traffic {
            // step-0 cache write + checkpoint re-reads (counted once: the
            // dominant term is the single write of every segment input)
            activations: 2 * report.act_cache_bytes as u64 / 4 * eb,
            // bwd read + dampen read/write of every edited parameter
            params: 3 * report.damp_elems * eb,
            // gradient stream GEMM -> FIMD is internal f32
            grads: 4 * report.fimd_elems,
            // stored global importance read once per edited parameter (f32)
            importance: 4 * report.damp_elems,
        }
    }

    /// Cost of one unlearning run on this processor, from the live
    /// engine's measured report.
    pub fn cost(&self, report: &UnlearnReport) -> RunCost {
        let gemm = gemm_cycles(&self.vta, report);
        // the IPs clock every burst lane, padding included
        let fimd = self.fimd.ip_cycles(report.fimd_elems + report.fimd_pad_elems);
        let damp = self.damp.ip_cycles(report.damp_elems + report.damp_pad_elems);
        let mem = self.ddr.cycles(&self.traffic(report));
        // streaming pipeline: engines overlap; memory overlaps compute via
        // the double-buffered DMA, so the run is bound by the slowest stream
        let total = gemm.max(fimd).max(damp).max(mem);
        let seconds = cycles_to_seconds(total);
        let power = self.power.total_mw();
        RunCost {
            phases: PhaseTimes {
                gemm_cycles: gemm,
                fimd_cycles: fimd,
                damp_cycles: damp,
                mem_cycles: mem,
                total_cycles: total,
            },
            seconds,
            energy_mj: PowerModel::energy_mj(power, seconds),
            power_mw: power,
        }
    }

    /// Fig. 5c: schedule `n_patches` patches through the 3-stage pipeline;
    /// returns (stage, patch, start_cycle, end_cycle) events. `per_patch`
    /// gives each stage's cycles per patch.
    pub fn trace(&self, n_patches: usize, per_patch: [u64; 3]) -> Vec<(usize, usize, u64, u64)> {
        let mut end = [[0u64; 3]; 2]; // rolling per-stage previous end
        let mut prev_end_same_patch;
        let mut events = Vec::with_capacity(n_patches * 3);
        let mut stage_free = [0u64; 3];
        for p in 0..n_patches {
            prev_end_same_patch = 0;
            for s in 0..3 {
                let start = stage_free[s].max(prev_end_same_patch);
                let endc = start + per_patch[s];
                events.push((s, p, start, endc));
                stage_free[s] = endc;
                prev_end_same_patch = endc;
            }
            end[p % 2] = stage_free;
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::macs::MacLedger;

    fn report(fwd: u64, bwd: u64, fimd: u64, damp: u64) -> UnlearnReport {
        UnlearnReport {
            ledger: MacLedger { forward: fwd, backward: bwd, ..Default::default() },
            fimd_elems: fimd,
            damp_elems: damp,
            act_cache_bytes: 1 << 20,
            ..Default::default()
        }
    }

    #[test]
    fn gemm_bound_when_ips_light() {
        let p = FicabuProcessor::new(8192, Precision::Int8);
        let r = report(1 << 30, 1 << 31, 1 << 18, 1 << 18);
        let c = p.cost(&r);
        assert_eq!(c.phases.total_cycles, c.phases.gemm_cycles);
        assert!(c.phases.fimd_cycles < c.phases.gemm_cycles / 10);
        assert!(c.seconds > 0.0 && c.energy_mj > 0.0);
    }

    #[test]
    fn fewer_macs_less_energy() {
        let p = FicabuProcessor::new(8192, Precision::Int8);
        let full = p.cost(&report(1 << 30, 1 << 31, 1 << 20, 1 << 20));
        let early = p.cost(&report(1 << 27, 1 << 28, 1 << 17, 1 << 17));
        assert!(early.energy_mj < full.energy_mj * 0.2);
    }

    #[test]
    fn pipeline_trace_overlaps() {
        let p = FicabuProcessor::new(8192, Precision::Int8);
        let ev = p.trace(4, [100, 30, 20]);
        assert_eq!(ev.len(), 12);
        // patch 1 GEMM starts while patch 0 FIMD/DAMP still pending or done;
        // GEMM stage is busy back-to-back (cadence = GEMM window)
        let gemm_events: Vec<_> = ev.iter().filter(|e| e.0 == 0).collect();
        assert_eq!(gemm_events[1].2, 100);
        assert_eq!(gemm_events[3].3, 400);
        // FIMD of patch 0 runs inside GEMM window of patch 1
        let fimd0 = ev.iter().find(|e| e.0 == 1 && e.1 == 0).unwrap();
        assert!(fimd0.2 >= 100 && fimd0.3 <= 200);
    }

    #[test]
    fn int8_traffic_smaller_than_fp32() {
        let r = report(1 << 20, 1 << 21, 1 << 16, 1 << 16);
        let p8 = FicabuProcessor::new(8192, Precision::Int8);
        let p32 = FicabuProcessor::new(8192, Precision::F32);
        assert!(p8.traffic(&r).total() < p32.traffic(&r).total());
    }

    #[test]
    fn executed_int8_overrides_deployment_assumption() {
        // an int8-*executed* report charges int8 traffic even on an
        // f32-assumed processor, and its f32 gradient chain costs 4
        // PE lanes per MAC
        let mut r = report(1 << 20, 1 << 21, 1 << 16, 1 << 16);
        let p32 = FicabuProcessor::new(8192, Precision::F32);
        let t_f32 = p32.traffic(&r).total();
        let g_f32 = p32.cost(&r).phases.gemm_cycles;
        r.precision = Precision::Int8;
        assert!(p32.traffic(&r).total() < t_f32);
        let g_i8 = p32.cost(&r).phases.gemm_cycles;
        // fwd + 4*bwd > fwd + bwd for this ledger (bwd dominates)
        assert!(g_i8 > g_f32);
    }

    #[test]
    fn pad_elems_cost_cycles_not_bandwidth() {
        let base = report(1 << 20, 1 << 21, 1 << 16, 1 << 16);
        let mut padded = base.clone();
        padded.fimd_pad_elems = 1 << 15;
        padded.damp_pad_elems = 1 << 15;
        let p = FicabuProcessor::new(8192, Precision::Int8);
        assert_eq!(p.traffic(&base).total(), p.traffic(&padded).total());
        assert!(p.cost(&padded).phases.fimd_cycles > p.cost(&base).phases.fimd_cycles);
        assert!(p.cost(&padded).phases.damp_cycles > p.cost(&base).phases.damp_cycles);
    }
}
