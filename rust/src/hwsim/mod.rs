//! FiCABU processor simulator (DESIGN.md §2 substitution for the FPGA/45nm
//! prototype).
//!
//! Cycle-approximate models of the blocks in Fig. 6: the VTA-like GEMM
//! backbone, the FIMD and Dampening IPs with their pipeline depths and
//! core-execution ratios (11.7x / 7.9x, §IV-A), a DDR traffic model, and a
//! power model whose per-block mW are the paper's own Table III 45 nm
//! numbers. Workload inputs (MACs, streamed elements, bytes moved) come
//! from the measured `UnlearnReport` of the live engine, so relative
//! energy (Table IV ES) is derived, not asserted.

pub mod baseline;
pub mod ip;
pub mod mem;
pub mod pipeline;
pub mod power;
pub mod vta;

pub use baseline::BaselineProcessor;
pub use pipeline::{FicabuProcessor, PhaseTimes, RunCost};
pub use power::{PowerModel, PowerRow};

/// System clock of the prototype (50 MHz Kintex-7, §IV-A).
pub const CLOCK_HZ: f64 = 50.0e6;

pub fn cycles_to_seconds(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_HZ
}
