//! Experiment harness: shared preparation (train/load model, compute or
//! load global importance) and the table/figure generators that the CLI,
//! examples and benches all drive. Each paper artifact (Tables I/II/IV,
//! Figs 3/4/5c) has a generator here — see DESIGN.md §4 for the index.

pub mod prepare;
pub mod tables;

pub use prepare::{prepare, DatasetKind, PrepareOpts, Prepared};
pub use tables::{mode_config, mode_strategy, run_mode, run_spec, ClassResult, Mode};
