//! Shared experiment setup: synthesize data, train the model from the
//! Rust binary via the `train_step` module, compute the stored global
//! importance `I_D`, cache both on disk so table runs are reproducible
//! without retraining. Model/engine inventories resolve to the built-in
//! topologies when no artifacts are exported, so everything here runs on
//! the default CpuBackend with no Python step.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{artifacts_root, ModelMeta, SharedMeta};
use crate::data::{cifar20_like, pinsface_like, Dataset, DatasetCfg};
use crate::fisher::{compute_global_importance, FimdEngine, Importance};
use crate::model::{Model, ParamStore};
use crate::runtime::{Precision, Runtime};
use crate::unlearn::{make_onehot, DampEngine};
use crate::util::prng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    Cifar20,
    PinsFace,
}

impl DatasetKind {
    pub fn tag(self) -> &'static str {
        match self {
            DatasetKind::Cifar20 => "cifar20",
            DatasetKind::PinsFace => "pinsface",
        }
    }

    pub fn cfg(self) -> DatasetCfg {
        match self {
            DatasetKind::Cifar20 => DatasetCfg::cifar20(),
            DatasetKind::PinsFace => DatasetCfg::pinsface(),
        }
    }

    /// Random-guess forget-accuracy target tau (paper: 5% CIFAR-20, 1%
    /// PinsFace).
    pub fn tau(self) -> f64 {
        match self {
            DatasetKind::Cifar20 => 0.05,
            DatasetKind::PinsFace => 0.01,
        }
    }

    /// SSD hyperparameters (alpha, lambda).
    ///
    /// The paper's values — (10,1) RN/CIFAR-20, (25,1) ViT/CIFAR-20,
    /// (50,0.1) PinsFace — are calibrated to an `I_D` computed over the
    /// full mixed dataset, whose scale is far below per-class Fisher. Our
    /// stored `I_D` is the class-balanced mean of class-conditional
    /// Fisher (see `global_importance`), which bounds the selection ratio
    /// `I_Df / I_D` by roughly `num_classes`; alphas above that select
    /// nothing. We keep the paper's *ordering* (face task more selective
    /// + stronger dampening) but rescale into the valid range. Override
    /// with FICABU_ALPHA / FICABU_LAMBDA for ablations.
    pub fn ssd_params(self, model: &str) -> (f64, f64) {
        let (a, l) = match (self, model) {
            (DatasetKind::Cifar20, "vitslim") => (12.0, 1.0),
            (DatasetKind::Cifar20, _) => (10.0, 1.0),
            (DatasetKind::PinsFace, _) => (12.0, 0.1),
        };
        let env = |k: &str, d: f64| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        (env("FICABU_ALPHA", a), env("FICABU_LAMBDA", l))
    }
}

#[derive(Debug, Clone)]
pub struct PrepareOpts {
    pub train_steps: usize,
    pub lr: f32,
    pub importance_batches: usize,
    pub seed: u64,
    /// Ignore cached checkpoints and retrain.
    pub retrain: bool,
    /// Serve the model in true INT8 after training (Table IV mode):
    /// weights quantized per output channel, forwards/evals execute the
    /// int8 GEMM path, the gradient chain stays f32 over the snapped
    /// masters.
    pub int8: bool,
    pub verbose: bool,
}

impl Default for PrepareOpts {
    fn default() -> Self {
        PrepareOpts {
            train_steps: 240,
            lr: 0.08,
            importance_batches: 4,
            seed: 17,
            retrain: false,
            int8: false,
            verbose: false,
        }
    }
}

/// Everything a table/figure run needs, ready to go.
pub struct Prepared {
    pub rt: Runtime,
    pub model: Model,
    pub params: ParamStore,
    pub global: Importance,
    pub train: Dataset,
    pub test: Dataset,
    pub fimd: FimdEngine,
    pub damp: DampEngine,
    pub kind: DatasetKind,
    pub loss_curve: Vec<f32>,
    /// Serving precision (int8 when the store is quantized).
    pub precision: Precision,
}

fn runs_dir() -> PathBuf {
    artifacts_root().join("runs")
}

/// Train (or load) a model on the given dataset and compute (or load) its
/// stored global importance.
pub fn prepare(model_name: &str, kind: DatasetKind, opts: &PrepareOpts) -> Result<Prepared> {
    let rt = Runtime::from_env()?;
    let meta = ModelMeta::resolve(model_name)?;
    let shared = SharedMeta::resolve()?;
    let model = Model::load(&rt, meta)?;
    let fimd = FimdEngine::new(&rt, &shared)?;
    let damp = DampEngine::new(&rt, &shared)?;

    let (train, test) = match kind {
        DatasetKind::Cifar20 => cifar20_like(&kind.cfg()),
        DatasetKind::PinsFace => pinsface_like(&kind.cfg()),
    };

    let tag = format!("{model_name}_{}{}", kind.tag(), if opts.int8 { "_int8" } else { "" });
    let ckpt = runs_dir().join(format!("{tag}.fcb"));
    let imp_path = runs_dir().join(format!("{tag}.imp"));

    let (mut params, global, loss_curve) = if !opts.retrain && ckpt.exists() && imp_path.exists() {
        let params = ParamStore::load(&ckpt)?;
        params.validate(&model.meta)?;
        (params, Importance::load(&imp_path)?, vec![])
    } else {
        let (mut params, curve) = train_model(&model, &train, opts)?;
        if opts.int8 {
            // true int8 store: per-channel weights + snapped f32
            // masters, so I_D below sees the deployed model
            params.quantize_int8(&model.meta);
        }
        let global = global_importance(&model, &params, &train, &fimd, opts)?;
        params.save(&ckpt)?;
        global.save(&imp_path)?;
        (params, global, curve)
    };
    if opts.int8 && !params.is_quantized() {
        // cache-hit path: the checkpoint stores the snapped f32 masters;
        // re-deriving the int8 copies is exact on the saved grid
        params.quantize_int8(&model.meta);
    }

    Ok(Prepared {
        rt,
        model,
        params,
        global,
        train,
        test,
        fimd,
        damp,
        kind,
        loss_curve,
        precision: if opts.int8 { Precision::Int8 } else { Precision::F32 },
    })
}

/// SGD training loop driven entirely from Rust through the compiled
/// `train_step` module (the e2e-driver requirement: all layers compose).
pub fn train_model(
    model: &Model,
    train: &Dataset,
    opts: &PrepareOpts,
) -> Result<(ParamStore, Vec<f32>)> {
    let meta = &model.meta;
    let mut params = ParamStore::init(meta, opts.seed);
    let mut rng = Pcg32::seeded(opts.seed ^ 0x7ea1);
    let mut order: Vec<usize> = (0..train.len()).collect();
    let mut curve = Vec::with_capacity(opts.train_steps);
    let mut cursor = train.len(); // trigger shuffle on first step
    for step in 0..opts.train_steps {
        if cursor + meta.batch > order.len() {
            rng.shuffle(&mut order);
            cursor = 0;
        }
        let idx = &order[cursor..cursor + meta.batch];
        cursor += meta.batch;
        let (x, labels) = train.batch(idx, meta.batch);
        let onehot = make_onehot(&labels, meta.num_classes)?;
        // cosine-ish decay keeps late training stable on the tiny corpus
        let frac = step as f32 / opts.train_steps.max(1) as f32;
        let lr = opts.lr * (1.0 - 0.9 * frac);
        let loss = model.train_step(&mut params, &x, &onehot, lr)?;
        curve.push(loss);
        if opts.verbose && step % 20 == 0 {
            eprintln!("  step {step:4}  loss {loss:.4}  lr {lr:.4}");
        }
    }
    Ok((params, curve))
}

/// Stored global importance I_D (paper §II: computed once after training
/// and stored). One class-conditional batch per class: microbatch
/// gradients of a single class are coherent, exactly like the forget
/// batches the selection rule compares against — mixing classes in a
/// microbatch would cancel gradients and deflate `I_D` relative to
/// `I_Df`, over-selecting shared parameters.
pub fn global_importance(
    model: &Model,
    params: &ParamStore,
    train: &Dataset,
    fimd: &FimdEngine,
    opts: &PrepareOpts,
) -> Result<Importance> {
    let meta = &model.meta;
    let mut rng = Pcg32::seeded(opts.seed ^ 0x91d);
    let mut batches = Vec::with_capacity(meta.num_classes);
    for class in 0..meta.num_classes {
        let (x, labels) = train.forget_batch(class, meta.batch, &mut rng);
        batches.push((x, make_onehot(&labels, meta.num_classes)?));
    }
    let mut imp = compute_global_importance(model, params, fimd, &batches)?;
    imp.floor(1e-12);
    Ok(imp)
}
