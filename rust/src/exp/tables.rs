//! Per-request unlearning evaluation in every paper mode, with the
//! metric set of Tables I/II/IV (Dr, Df, MIA, MACs, dDr, RPR, ES).

use anyhow::Result;

use crate::hwsim::mem::Precision;
use crate::hwsim::{baseline::energy_savings, BaselineProcessor, FicabuProcessor};
use crate::metrics::{eval_accuracy, mia_accuracy, per_sample_losses};
use crate::model::macs::ssd_ledger;
use crate::unlearn::{
    default_checkpoints, run_strategy, Bd, Cau, Ficabu, ForgetSpec, Schedule, Ssd, Strategy,
    UnlearnConfig, UnlearnReport,
};
use crate::util::prng::Pcg32;

use super::prepare::Prepared;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Baseline, // pre-trained model, no unlearning
    Ssd,
    Cau,
    Bd,
    Ficabu,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Baseline => "Baseline",
            Mode::Ssd => "SSD",
            Mode::Cau => "CAU",
            Mode::Bd => "BD",
            Mode::Ficabu => "FiCABU",
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClassResult {
    /// The canonical forget request this cell executed.
    pub spec: ForgetSpec,
    pub mode: Mode,
    /// Retain accuracy (train retain split) in [0,1].
    pub dr: f64,
    /// Forget accuracy in [0,1].
    pub df: f64,
    /// MIA member-rate on the forget set in [0,1].
    pub mia: f64,
    /// Total MACs of the unlearning procedure (0 for Baseline).
    pub macs: u64,
    /// MACs relative to SSD, percent.
    pub macs_vs_ssd_pct: f64,
    pub stop_depth: Option<usize>,
    pub report: Option<UnlearnReport>,
}

/// The checkpoint stride per model (paper: every 4 of 16 convs = 2 block
/// segments for RN; every 3 encoder segments for ViT).
pub fn checkpoint_stride(model_name: &str) -> usize {
    if model_name.starts_with("vit") {
        3
    } else {
        2
    }
}

/// Build the strategy for a mode, calibrating the BD sigmoid from an
/// SSD selection profile when needed (paper §III-B procedure). The
/// forward/eval precision follows the prepared store (int8-served when
/// `prepare` ran with `int8`).
pub fn mode_strategy(
    prep: &Prepared,
    mode: Mode,
    ssd_selection: Option<&[u64]>,
) -> Box<dyn Strategy> {
    let (alpha, lambda) = prep.kind.ssd_params(&prep.model.meta.name);
    let tau = prep.kind.tau();
    let big_l = prep.model.meta.num_segments();
    let cps = default_checkpoints(big_l, checkpoint_stride(&prep.model.meta.name));
    let schedule = |sel: Option<&[u64]>| match sel {
        Some(s) => Schedule::from_selection_distribution(s, 10.0),
        None => Schedule::Sigmoid { cm: (big_l as f64 + 1.0) / 2.0, br: 10.0 },
    };
    let p = prep.precision;
    match mode {
        // Baseline never runs; SSD's bag doubles as its placeholder.
        Mode::Baseline | Mode::Ssd => Box::new(Ssd::new(alpha, lambda).with_precision(p)),
        Mode::Cau => Box::new(Cau::new(alpha, lambda, cps, tau).with_precision(p)),
        Mode::Bd => Box::new(Bd::new(alpha, lambda, schedule(ssd_selection)).with_precision(p)),
        Mode::Ficabu => Box::new(
            Ficabu::new(alpha, lambda, schedule(ssd_selection), cps, tau).with_precision(p),
        ),
    }
}

/// The mode's serializable parameter bag — what travels to fleet
/// replicas in a `WorkerSpec` (the strategy is rebuilt in-thread).
pub fn mode_config(prep: &Prepared, mode: Mode, ssd_selection: Option<&[u64]>) -> UnlearnConfig {
    mode_strategy(prep, mode, ssd_selection).config().clone()
}

/// Run one (spec, mode) cell: clone the trained parameters, unlearn,
/// evaluate Dr / Df / MIA / MACs. The forget/retain splits follow the
/// spec (class, multi-class, or sample-level).
pub fn run_spec(
    prep: &Prepared,
    spec: &ForgetSpec,
    mode: Mode,
    ssd_selection: Option<&[u64]>,
) -> Result<ClassResult> {
    let meta = &prep.model.meta;
    let spec = spec.canonical();
    // bounds vs the *model head*; pool() below checks dataset bounds
    spec.validate(meta.num_classes, prep.train.len())?;
    let mut params = prep.params.clone();
    let ssd_total = ssd_ledger(meta, meta.batch).editing_total();
    let forget_idx = spec.pool(&prep.train)?;
    let retain_idx = ForgetSpec::retain_of(&forget_idx, prep.train.len());

    let report = if mode == Mode::Baseline {
        None
    } else {
        let strategy = mode_strategy(prep, mode, ssd_selection);
        let mut rng = Pcg32::seeded(0xc1a55 ^ spec.key().hash64());
        let (x, labels) = prep.train.batch_from_pool(&forget_idx, meta.batch, &mut rng)?;
        Some(run_strategy(
            &prep.model,
            &mut params,
            &x,
            &labels,
            &prep.global,
            &prep.fimd,
            &prep.damp,
            strategy.as_ref(),
        )?)
    };

    // evaluation splits
    let dr = eval_accuracy(&prep.model, &params, &prep.train, &retain_idx)?;
    let df = eval_accuracy(&prep.model, &params, &prep.train, &forget_idx)?;

    // MIA: members = retain train subsample, nonmembers = test set
    let member_idx: Vec<usize> = retain_idx.iter().copied().step_by(3).collect();
    let nonmember_idx: Vec<usize> = (0..prep.test.len()).collect();
    let member = per_sample_losses(&prep.model, &params, &prep.train, &member_idx)?;
    let nonmember = per_sample_losses(&prep.model, &params, &prep.test, &nonmember_idx)?;
    let forget = per_sample_losses(&prep.model, &params, &prep.train, &forget_idx)?;
    let mia = mia_accuracy(&member, &nonmember, &forget);

    let macs = report.as_ref().map(|r| r.ledger.editing_total()).unwrap_or(0);
    Ok(ClassResult {
        spec,
        mode,
        dr,
        df,
        mia,
        macs,
        macs_vs_ssd_pct: 100.0 * macs as f64 / ssd_total as f64,
        stop_depth: report.as_ref().and_then(|r| r.stop_depth),
        report,
    })
}

/// [`run_spec`] for the paper's per-event shape: one class — what the
/// table/figure examples iterate.
pub fn run_mode(
    prep: &Prepared,
    class: usize,
    mode: Mode,
    ssd_selection: Option<&[u64]>,
) -> Result<ClassResult> {
    run_spec(prep, &ForgetSpec::Class(class), mode, ssd_selection)
}

/// Hardware cost of a result on the FiCABU processor vs SSD on the
/// baseline processor (Table IV: ES).
pub fn hardware_cost(
    prep: &Prepared,
    ours: &UnlearnReport,
    ssd: &UnlearnReport,
    precision: Precision,
) -> (f64, f64, f64) {
    let tile = prep.model.meta.tile;
    let fic = FicabuProcessor::new(tile, precision).cost(ours);
    let base = BaselineProcessor::new(tile, precision).cost(ssd);
    (fic.energy_mj, base.energy_mj, energy_savings(&fic, &base))
}

/// Format helpers shared by the table printers.
pub fn pct(x: f64) -> String {
    format!("{:6.2}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_strides() {
        assert_eq!(checkpoint_stride("rn18slim"), 2);
        assert_eq!(checkpoint_stride("vitslim"), 3);
    }

    #[test]
    fn mode_names() {
        assert_eq!(Mode::Ficabu.name(), "FiCABU");
        assert_eq!(Mode::Baseline.name(), "Baseline");
    }
}
