//! Synthetic dataset substrates (DESIGN.md §2).
//!
//! No network access and no CIFAR/Kaggle archives in this environment, so
//! both evaluation datasets are synthesized with the *properties the paper
//! leans on*:
//!
//! * `cifar20_like` — 20 well-separated classes: each class owns a
//!   low-frequency structure plus mid/high-frequency detail on top of a
//!   weak shared base. A slim net trains to high accuracy quickly.
//! * `pinsface_like` — 20 "identities" that share a single strong base
//!   pattern (high inter-class similarity — the property the paper cites
//!   to explain the 99.9% MAC savings on faces): discriminative detail is
//!   a small high-frequency perturbation.

pub mod gen;

pub use gen::{cifar20_like, pinsface_like, Dataset, DatasetCfg};
