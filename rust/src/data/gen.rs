//! Procedural image synthesis: smooth fields via bilinear-upsampled noise
//! grids, class identity split between low- and high-frequency components.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::prng::Pcg32;

#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Vec<Vec<f32>>, // each img_shape.iter().product() long
    pub labels: Vec<usize>,
    pub num_classes: usize,
    pub img_shape: Vec<usize>, // e.g. [32, 32, 3]
}

#[derive(Clone, Debug)]
pub struct DatasetCfg {
    pub num_classes: usize,
    pub img: usize,
    pub channels: usize,
    pub train_per_class: usize,
    pub test_per_class: usize,
    /// amplitude of the dataset-wide shared base pattern
    pub shared_amp: f32,
    /// amplitude of the class low-frequency component
    pub low_amp: f32,
    /// amplitude of the class high-frequency component
    pub high_amp: f32,
    /// per-sample noise
    pub noise: f32,
    pub seed: u64,
}

/// Optional env override for dataset tuning experiments
/// (e.g. `FICABU_DS_NOISE=0.9 ficabu train ...`).
fn env_f32(name: &str, default: f32) -> f32 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

impl DatasetCfg {
    pub fn cifar20() -> DatasetCfg {
        DatasetCfg {
            num_classes: 20,
            img: 32,
            channels: 3,
            train_per_class: 48,
            test_per_class: 16,
            shared_amp: env_f32("FICABU_DS_SHARED", 0.8),
            low_amp: env_f32("FICABU_DS_LOW", 0.45),
            high_amp: env_f32("FICABU_DS_HIGH", 0.3),
            noise: env_f32("FICABU_DS_NOISE", 0.9),
            seed: 2026,
        }
    }

    /// High inter-class similarity: strong shared base, weak class detail
    /// concentrated in high frequencies.
    pub fn pinsface() -> DatasetCfg {
        DatasetCfg {
            num_classes: 20,
            img: 32,
            channels: 3,
            train_per_class: 48,
            test_per_class: 16,
            shared_amp: 1.2,
            low_amp: 0.12,
            high_amp: 0.45,
            noise: 0.5,
            seed: 4052,
        }
    }
}

/// Bilinear upsample of a `g x g x c` noise grid to `img x img x c` —
/// a cheap smooth random field.
fn smooth_field(rng: &mut Pcg32, g: usize, img: usize, c: usize, amp: f32) -> Vec<f32> {
    let grid = rng.normal_vec(g * g * c, amp);
    let mut out = vec![0.0f32; img * img * c];
    let scale = g as f32 / img as f32;
    for y in 0..img {
        for x in 0..img {
            let fy = (y as f32 + 0.5) * scale - 0.5;
            let fx = (x as f32 + 0.5) * scale - 0.5;
            let y0 = fy.floor().max(0.0) as usize;
            let x0 = fx.floor().max(0.0) as usize;
            let y1 = (y0 + 1).min(g - 1);
            let x1 = (x0 + 1).min(g - 1);
            let wy = (fy - y0 as f32).clamp(0.0, 1.0);
            let wx = (fx - x0 as f32).clamp(0.0, 1.0);
            for ch in 0..c {
                let v00 = grid[(y0 * g + x0) * c + ch];
                let v01 = grid[(y0 * g + x1) * c + ch];
                let v10 = grid[(y1 * g + x0) * c + ch];
                let v11 = grid[(y1 * g + x1) * c + ch];
                let v0 = v00 * (1.0 - wx) + v01 * wx;
                let v1 = v10 * (1.0 - wx) + v11 * wx;
                out[(y * img + x) * c + ch] = v0 * (1.0 - wy) + v1 * wy;
            }
        }
    }
    out
}

fn generate(cfg: &DatasetCfg) -> (Dataset, Dataset) {
    let n = cfg.img * cfg.img * cfg.channels;
    let mut rng = Pcg32::seeded(cfg.seed);

    // dataset-wide shared base (low frequency)
    let base = smooth_field(&mut rng, 4, cfg.img, cfg.channels, cfg.shared_amp);

    // per-class prototypes: low-freq + high-freq components
    let mut protos = Vec::with_capacity(cfg.num_classes);
    for _ in 0..cfg.num_classes {
        let low = smooth_field(&mut rng, 4, cfg.img, cfg.channels, cfg.low_amp);
        let high = rng.normal_vec(n, cfg.high_amp);
        let proto: Vec<f32> = (0..n).map(|i| base[i] + low[i] + high[i]).collect();
        protos.push(proto);
    }

    let make = |per_class: usize, stream: u64| -> Dataset {
        let mut rng = Pcg32::new(cfg.seed ^ 0x5eed, stream);
        let mut images = Vec::with_capacity(per_class * cfg.num_classes);
        let mut labels = Vec::with_capacity(per_class * cfg.num_classes);
        for c in 0..cfg.num_classes {
            for _ in 0..per_class {
                let img: Vec<f32> = protos[c]
                    .iter()
                    .map(|&v| v + rng.normal() * cfg.noise)
                    .collect();
                images.push(img);
                labels.push(c);
            }
        }
        Dataset {
            images,
            labels,
            num_classes: cfg.num_classes,
            img_shape: vec![cfg.img, cfg.img, cfg.channels],
        }
    };

    (make(cfg.train_per_class, 1), make(cfg.test_per_class, 2))
}

pub fn cifar20_like(cfg: &DatasetCfg) -> (Dataset, Dataset) {
    generate(cfg)
}

pub fn pinsface_like(cfg: &DatasetCfg) -> (Dataset, Dataset) {
    generate(cfg)
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Indices of all samples with the given label.
    pub fn class_indices(&self, class: usize) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.labels[i] == class).collect()
    }

    /// All samples except the given class — the retain set D_r (eq. 1).
    pub fn without_class(&self, class: usize) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.labels[i] != class).collect()
    }

    /// Assemble a batched tensor `[batch, ...img_shape]` from sample
    /// indices, repeating the tail to fill (padding masked out by caller).
    pub fn batch(&self, idx: &[usize], batch: usize) -> (Tensor, Vec<usize>) {
        let samples: Vec<&[f32]> = idx.iter().map(|&i| self.images[i].as_slice()).collect();
        let t = Tensor::stack_pad(&samples, &self.img_shape, batch).expect("batch");
        let labels = idx.iter().map(|&i| self.labels[i]).collect();
        (t, labels)
    }

    /// A forget batch: `batch` samples of one class (sampled with
    /// replacement if the class has fewer).
    pub fn forget_batch(&self, class: usize, batch: usize, rng: &mut Pcg32) -> (Tensor, Vec<usize>) {
        self.batch_from_pool(&self.class_indices(class), batch, rng)
            .unwrap_or_else(|e| panic!("forget_batch class {class}: {e}"))
    }

    /// A forget batch over an explicit index set (sampled with
    /// replacement): the sampling primitive behind every
    /// `unlearn::ForgetSpec` variant — single-class, multi-class, and
    /// per-sample forgetting all reduce to an index pool.
    pub fn batch_from_pool(
        &self,
        pool: &[usize],
        batch: usize,
        rng: &mut Pcg32,
    ) -> Result<(Tensor, Vec<usize>)> {
        if pool.is_empty() {
            bail!("forget pool is empty");
        }
        if let Some(&i) = pool.iter().find(|&&i| i >= self.len()) {
            bail!("forget pool index {i} out of range ({} samples)", self.len());
        }
        let idx: Vec<usize> = (0..batch).map(|_| pool[rng.below(pool.len())]).collect();
        Ok(self.batch(&idx, batch))
    }

    /// Mean pairwise prototype correlation between class means — the
    /// inter-class-similarity measure that separates the two datasets.
    pub fn interclass_similarity(&self) -> f32 {
        let n = self.images[0].len();
        let mut means = vec![vec![0.0f32; n]; self.num_classes];
        let mut counts = vec![0usize; self.num_classes];
        for (img, &l) in self.images.iter().zip(&self.labels) {
            for (m, v) in means[l].iter_mut().zip(img) {
                *m += v;
            }
            counts[l] += 1;
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        let mut sum = 0.0;
        let mut pairs = 0;
        for a in 0..self.num_classes {
            for b in (a + 1)..self.num_classes {
                sum += cosine(&means[a], &means[b]);
                pairs += 1;
            }
        }
        sum / pairs as f32
    }
}

fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
    let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
    dot / (na * nb).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_counts() {
        let cfg = DatasetCfg { train_per_class: 4, test_per_class: 2, ..DatasetCfg::cifar20() };
        let (train, test) = cifar20_like(&cfg);
        assert_eq!(train.len(), 80);
        assert_eq!(test.len(), 40);
        assert_eq!(train.images[0].len(), 32 * 32 * 3);
        for c in 0..20 {
            assert_eq!(train.class_indices(c).len(), 4);
        }
        assert_eq!(train.without_class(0).len(), 76);
    }

    #[test]
    fn deterministic() {
        let cfg = DatasetCfg { train_per_class: 2, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (a, _) = cifar20_like(&cfg);
        let (b, _) = cifar20_like(&cfg);
        assert_eq!(a.images[7], b.images[7]);
    }

    #[test]
    fn faces_more_similar_than_cifar() {
        let c1 = DatasetCfg { train_per_class: 6, test_per_class: 1, ..DatasetCfg::cifar20() };
        let c2 = DatasetCfg { train_per_class: 6, test_per_class: 1, ..DatasetCfg::pinsface() };
        let (cifar, _) = cifar20_like(&c1);
        let (faces, _) = pinsface_like(&c2);
        let sc = cifar.interclass_similarity();
        let sf = faces.interclass_similarity();
        assert!(
            sf > sc + 0.2,
            "faces similarity {sf} should exceed cifar {sc}"
        );
        assert!(sf > 0.5, "faces should be strongly correlated: {sf}");
    }

    #[test]
    fn forget_batch_single_class() {
        let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (train, _) = cifar20_like(&cfg);
        let mut rng = Pcg32::seeded(3);
        let (x, labels) = train.forget_batch(5, 16, &mut rng);
        assert_eq!(x.shape, vec![16, 32, 32, 3]);
        assert!(labels.iter().all(|&l| l == 5));
    }

    #[test]
    fn batch_from_pool_samples_only_the_pool() {
        let cfg = DatasetCfg { train_per_class: 4, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (train, _) = cifar20_like(&cfg);
        let mut rng = Pcg32::seeded(9);
        // mixed-class pool: two samples of class 0, one of class 3
        let pool = vec![0, 1, 3 * 4];
        let (x, labels) = train.batch_from_pool(&pool, 16, &mut rng).unwrap();
        assert_eq!(x.shape[0], 16);
        assert_eq!(labels.len(), 16);
        assert!(labels.iter().all(|&l| l == 0 || l == 3), "labels: {labels:?}");
        assert!(labels.contains(&0) && labels.contains(&3), "replacement should hit both");
    }

    #[test]
    fn batch_from_pool_rejects_bad_pools() {
        let cfg = DatasetCfg { train_per_class: 2, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (train, _) = cifar20_like(&cfg);
        let mut rng = Pcg32::seeded(9);
        assert!(train.batch_from_pool(&[], 8, &mut rng).is_err());
        assert!(train.batch_from_pool(&[train.len()], 8, &mut rng).is_err());
    }

    #[test]
    fn batch_pads_with_repeats() {
        let cfg = DatasetCfg { train_per_class: 2, test_per_class: 1, ..DatasetCfg::cifar20() };
        let (train, _) = cifar20_like(&cfg);
        let (x, labels) = train.batch(&[0, 1, 2], 8);
        assert_eq!(x.shape[0], 8);
        assert_eq!(labels.len(), 3);
        // padded rows repeat the last sample
        assert_eq!(x.row(2), x.row(7));
    }
}
