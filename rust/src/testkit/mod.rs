//! Test instrumentation compiled into the library: a seeded
//! property-testing harness (no `proptest` in the vendor tree) and the
//! [`faults`] deterministic fault-injection seam used by chaos tests,
//! the chaos bench arm, and CI's degraded-health smoke.
//!
//! `prop::check(name, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it reports the seed + case index so the exact input
//! can be replayed, and performs a simple halving shrink when the
//! generator supports resizing.

pub mod faults;

pub mod prop {
    use crate::util::prng::Pcg32;

    /// Run a property over `cases` random inputs. `gen` receives an RNG and
    /// a size hint in [1, 100]; `prop` returns `Err(reason)` on violation.
    pub fn check<T: std::fmt::Debug>(
        name: &str,
        cases: usize,
        mut gen: impl FnMut(&mut Pcg32, usize) -> T,
        mut prop: impl FnMut(&T) -> Result<(), String>,
    ) {
        let seed = 0xf1ca_b0u64;
        for case in 0..cases {
            let mut rng = Pcg32::new(seed, case as u64);
            let size = 1 + (case * 100 / cases.max(1));
            let input = gen(&mut rng, size);
            if let Err(reason) = prop(&input) {
                // shrink: retry with smaller size hints from the same stream
                let mut smallest = None;
                for s in [size / 2, size / 4, 2, 1] {
                    if s == 0 {
                        continue;
                    }
                    let mut rng2 = Pcg32::new(seed, case as u64);
                    let cand = gen(&mut rng2, s);
                    if prop(&cand).is_err() {
                        smallest = Some((s, cand));
                    }
                }
                if let Some((s, cand)) = smallest {
                    panic!(
                        "property `{name}` failed (case {case}, seed {seed:#x}):\n  {reason}\n  shrunk input (size {s}): {cand:?}"
                    );
                }
                panic!(
                    "property `{name}` failed (case {case}, seed {seed:#x}):\n  {reason}\n  input: {input:?}"
                );
            }
        }
    }

    /// Assert two f32 slices are elementwise close.
    pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("length {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
                return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::prop;
    use crate::util::prng::Pcg32;

    #[test]
    fn passing_property() {
        prop::check(
            "reverse twice is identity",
            50,
            |rng: &mut Pcg32, size| {
                (0..size).map(|_| rng.next_u32()).collect::<Vec<_>>()
            },
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v {
                    Ok(())
                } else {
                    Err("mismatch".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_context() {
        prop::check(
            "always fails",
            5,
            |rng: &mut Pcg32, _| rng.next_u32(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn assert_close_works() {
        assert!(prop::assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5).is_ok());
        assert!(prop::assert_close(&[1.0], &[1.1], 1e-5).is_err());
        assert!(prop::assert_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
