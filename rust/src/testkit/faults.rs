//! Deterministic fault injection for chaos tests and benches.
//!
//! Production stage code calls [`hit`] at well-known *sites* (stage
//! boundaries such as `"forget_fisher"`, `"dampen"`, `"early_stop"`,
//! and the fleet's `"respawn"` build path). When no plan is armed the
//! call is a single relaxed atomic load — effectively free — so the
//! seam can stay compiled into release builds.
//!
//! A plan is a `;`-separated list of faults in a tiny grammar:
//!
//! ```text
//! site:TRIGGER:ACTION
//!
//! TRIGGER  ::=  <n>        fire once, on the n-th hit of the site (1-based)
//!           |   every<n>   fire on every n-th hit of the site
//! ACTION   ::=  panic      panic! at the site
//!           |   error      return an injected anyhow error
//!           |   delay:<ms> sleep for <ms> milliseconds, then continue
//! ```
//!
//! Examples: `dampen:3:panic` (panic at the 3rd dampened segment),
//! `early_stop:2:error` (error from the 2nd early-stop check),
//! `forget_fisher:1:delay:50`, `dampen:every4:panic;respawn:every1:error`.
//!
//! The plan and its per-site hit counters are **process-global**:
//! tests that arm a plan must serialize against each other (see
//! `tests/chaos_e2e.rs`) and [`clear`] it when done. The serve CLI
//! arms a plan from the `FICABU_FAULTS` environment variable via
//! [`arm_from_env`] so CI can drive a server into degraded states.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use anyhow::{bail, Result};

/// Environment variable read by [`arm_from_env`].
pub const ENV_VAR: &str = "FICABU_FAULTS";

/// Every site compiled into the codebase. [`arm`] rejects a plan naming
/// any other site (a typo'd `FICABU_FAULTS` must not silently become a
/// fault-free chaos run); sites starting with `test_` are exempt so
/// unit tests can use scratch sites. Keep in sync with the `hit` call
/// sites: engine stages (`forget_fisher`, `dampen`, `early_stop`), the
/// fleet's `respawn` build path, the durability seams
/// (`wal_append`, `checkpoint`, `replay`), and the audit seams
/// (`audit_append` in the chain's durable append path, `audit_verify`
/// in offline chain verification).
pub const SITES: &[&str] = &[
    "forget_fisher",
    "dampen",
    "early_stop",
    "respawn",
    "wal_append",
    "checkpoint",
    "replay",
    "audit_append",
    "audit_verify",
];

// Fast-path gate: `hit` is a relaxed load of this flag unless a plan is
// armed. The plan itself lives behind a Mutex (hits are rare and slow
// by design once armed).
static ARMED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Plan>> = Mutex::new(None);

#[derive(Debug, Clone, Copy, PartialEq)]
enum Action {
    Panic,
    Error,
    DelayMs(u64),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Trigger {
    /// Fire exactly once, on the n-th hit (1-based).
    Nth(u64),
    /// Fire on every n-th hit.
    Every(u64),
}

impl Trigger {
    fn fires(self, hit_count: u64) -> bool {
        match self {
            Trigger::Nth(n) => hit_count == n,
            Trigger::Every(n) => hit_count % n == 0,
        }
    }
}

#[derive(Debug, Clone)]
struct Fault {
    site: String,
    trigger: Trigger,
    action: Action,
}

#[derive(Debug)]
struct Plan {
    faults: Vec<Fault>,
    /// Per-site hit counters, shared by every fault on that site.
    hits: HashMap<String, u64>,
}

// Injected panics deliberately poison nothing (the guard is dropped
// before the panic fires), but a panic elsewhere while the lock is held
// must not wedge the whole seam — recover the inner value.
fn lock() -> MutexGuard<'static, Option<Plan>> {
    PLAN.lock().unwrap_or_else(PoisonError::into_inner)
}

fn parse(plan: &str) -> Result<Vec<Fault>> {
    let mut faults = Vec::new();
    for clause in plan.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let parts: Vec<&str> = clause.split(':').collect();
        if parts.len() < 3 {
            bail!("fault clause `{clause}`: expected site:TRIGGER:ACTION");
        }
        let site = parts[0].trim();
        if site.is_empty() {
            bail!("fault clause `{clause}`: empty site");
        }
        if !SITES.contains(&site) && !site.starts_with("test_") {
            bail!(
                "fault clause `{clause}`: unknown site `{site}` (valid sites: {}; `test_*` names \
                 are reserved for tests)",
                SITES.join(", ")
            );
        }
        let trig = parts[1].trim();
        let trigger = if let Some(n) = trig.strip_prefix("every") {
            let n: u64 = n
                .parse()
                .map_err(|_| anyhow::anyhow!("fault clause `{clause}`: bad trigger `{trig}`"))?;
            if n == 0 {
                bail!("fault clause `{clause}`: `every0` never fires");
            }
            Trigger::Every(n)
        } else {
            let n: u64 = trig
                .parse()
                .map_err(|_| anyhow::anyhow!("fault clause `{clause}`: bad trigger `{trig}`"))?;
            if n == 0 {
                bail!("fault clause `{clause}`: hit counts are 1-based");
            }
            Trigger::Nth(n)
        };
        let action = match (parts[2].trim(), parts.get(3)) {
            ("panic", None) => Action::Panic,
            ("error", None) => Action::Error,
            ("delay", Some(ms)) => Action::DelayMs(ms.trim().parse().map_err(|_| {
                anyhow::anyhow!("fault clause `{clause}`: bad delay `{ms}` (want ms)")
            })?),
            _ => bail!(
                "fault clause `{clause}`: unknown action `{}` (want panic|error|delay:<ms>)",
                parts[2..].join(":")
            ),
        };
        faults.push(Fault { site: site.to_string(), trigger, action });
    }
    if faults.is_empty() {
        bail!("fault plan `{plan}` contains no clauses");
    }
    Ok(faults)
}

/// Arm a fault plan for the whole process, replacing any previous plan
/// and resetting all hit counters. See the module docs for the grammar.
pub fn arm(plan: &str) -> Result<()> {
    let faults = parse(plan)?;
    *lock() = Some(Plan { faults, hits: HashMap::new() });
    ARMED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Arm from the `FICABU_FAULTS` environment variable. Returns the plan
/// string when one was armed, `None` when the variable is unset/empty,
/// and an error when it is set but unparsable (a typo'd chaos run must
/// not silently become a fault-free one).
pub fn arm_from_env() -> Result<Option<String>> {
    match std::env::var(ENV_VAR) {
        Ok(s) if !s.trim().is_empty() => {
            arm(&s)?;
            Ok(Some(s))
        }
        _ => Ok(None),
    }
}

/// Disarm: drop the plan and counters. `hit` goes back to its
/// single-atomic-load fast path.
pub fn clear() {
    ARMED.store(false, Ordering::SeqCst);
    *lock() = None;
}

/// How many times `site` has been hit under the current plan (0 when
/// disarmed). Lets tests assert a seam was actually exercised.
pub fn hits(site: &str) -> u64 {
    lock().as_ref().and_then(|p| p.hits.get(site).copied()).unwrap_or(0)
}

/// Fault seam: call at a stage boundary. Free when disarmed; when a
/// plan is armed, counts the hit and performs the first matching
/// fault's action — `Err` for `error`, `panic!` for `panic` (with the
/// plan lock released first, so the plan is never poisoned), a sleep
/// for `delay`.
#[inline]
pub fn hit(site: &str) -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    hit_armed(site)
}

#[cold]
fn hit_armed(site: &str) -> Result<()> {
    let action = {
        let mut guard = lock();
        let Some(plan) = guard.as_mut() else { return Ok(()) };
        let count = plan.hits.entry(site.to_string()).or_insert(0);
        *count += 1;
        let n = *count;
        plan.faults
            .iter()
            .find(|f| f.site == site && f.trigger.fires(n))
            .map(|f| (f.action, n))
    };
    match action {
        None => Ok(()),
        Some((Action::DelayMs(ms), _)) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Some((Action::Error, n)) => bail!("injected fault: error at `{site}` (hit {n})"),
        Some((Action::Panic, n)) => panic!("injected fault: panic at `{site}` (hit {n})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The plan is process-global; every test in this module serializes
    // on one lock and clears the plan before releasing it.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn disarmed_is_a_no_op() {
        let _g = serial();
        clear();
        assert!(hit("dampen").is_ok());
        assert_eq!(hits("dampen"), 0);
    }

    #[test]
    fn nth_trigger_fires_once() {
        let _g = serial();
        arm("dampen:2:error").unwrap();
        assert!(hit("dampen").is_ok());
        let e = hit("dampen").unwrap_err();
        assert!(format!("{e:#}").contains("injected fault"), "{e:#}");
        assert!(hit("dampen").is_ok(), "Nth is one-shot");
        assert!(hit("forget_fisher").is_ok(), "other sites untouched");
        assert_eq!(hits("dampen"), 3);
        clear();
    }

    #[test]
    fn every_trigger_repeats() {
        let _g = serial();
        arm("test_s:every2:error").unwrap();
        let fired: Vec<bool> = (0..6).map(|_| hit("test_s").is_err()).collect();
        assert_eq!(fired, [false, true, false, true, false, true]);
        clear();
    }

    #[test]
    fn panic_action_panics_without_poisoning_the_plan() {
        let _g = serial();
        arm("test_s:1:panic;test_s:3:error").unwrap();
        let p = std::panic::catch_unwind(|| hit("test_s")).unwrap_err();
        let msg = p.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("injected fault: panic at `test_s`"), "{msg}");
        // the seam stays usable after the panic: hit 2 passes, hit 3 errors
        assert!(hit("test_s").is_ok());
        assert!(hit("test_s").is_err());
        clear();
    }

    #[test]
    fn delay_action_sleeps_then_continues() {
        let _g = serial();
        arm("test_s:1:delay:30").unwrap();
        let t0 = std::time::Instant::now();
        assert!(hit("test_s").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(25));
        clear();
    }

    #[test]
    fn plan_grammar_rejects_garbage() {
        for bad in [
            "",
            "dampen",
            "dampen:panic",
            "dampen:0:panic",
            "dampen:every0:panic",
            "dampen:x:panic",
            "dampen:1:explode",
            "dampen:1:delay:soon",
            ":1:panic",
        ] {
            assert!(parse(bad).is_err(), "`{bad}` should not parse");
        }
        assert_eq!(
            parse("test_a:1:panic; test_b:every3:delay:50 ;dampen:2:error").unwrap().len(),
            3
        );
    }

    #[test]
    fn unknown_sites_are_rejected_with_the_valid_list() {
        let e = parse("dampenn:1:panic").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("unknown site `dampenn`"), "{msg}");
        for site in SITES {
            assert!(msg.contains(site), "error must list `{site}`: {msg}");
        }
        // every registered site parses; test_ names stay available
        for site in SITES {
            assert!(parse(&format!("{site}:1:error")).is_ok());
        }
        assert!(parse("test_anything:1:error").is_ok());
    }
}
