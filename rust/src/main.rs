//! `ficabu` — the edge unlearning coordinator CLI.
//!
//! Commands:
//!   train      train a model on a synthetic dataset and cache the
//!              checkpoint + stored global importance
//!   unlearn    run one unlearning event (ssd | cau | bd | ficabu)
//!   serve      edge request-loop demo (threads + channels), or — with
//!              `--http ADDR` — a wire-facing HTTP/1.1 front-end
//!              (`POST /forget`, `GET /stats`, `GET /healthz`)
//!   audit      inspect/verify a durable directory's hash-chained audit
//!              log offline (`list | verify | prove --spec class:3`)
//!   info       runtime/platform and artifact inventory
//!
//! Table/figure regeneration lives in `examples/` (see DESIGN.md §4).

use anyhow::Result;
use ficabu::config::{artifacts_root, SharedMeta};
use ficabu::coordinator::{
    DurabilityConfig, Fleet, FleetConfig, HttpConfig, HttpServer, Pacing, Reply, WorkerSpec,
};
use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::runtime::Runtime;
use ficabu::unlearn::ForgetSpec;
use ficabu::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dataset_kind(s: &str) -> Result<DatasetKind> {
    match s {
        "cifar20" => Ok(DatasetKind::Cifar20),
        "pinsface" => Ok(DatasetKind::PinsFace),
        _ => anyhow::bail!("unknown dataset `{s}` (cifar20 | pinsface)"),
    }
}

fn mode_of(s: &str) -> Result<Mode> {
    Ok(match s {
        "ssd" => Mode::Ssd,
        "cau" => Mode::Cau,
        "bd" => Mode::Bd,
        "ficabu" => Mode::Ficabu,
        "baseline" => Mode::Baseline,
        _ => anyhow::bail!("unknown mode `{s}`"),
    })
}

fn prepare_opts(a: &Args) -> Result<PrepareOpts> {
    Ok(PrepareOpts {
        train_steps: a.usize_or("steps", 240)?,
        lr: a.f64_or("lr", 0.08)? as f32,
        importance_batches: a.usize_or("imp-batches", 4)?,
        seed: a.usize_or("seed", 17)? as u64,
        retrain: a.flag("retrain"),
        int8: a.flag("int8"),
        verbose: a.flag("verbose"),
    })
}

/// The request of an `unlearn`/`serve` invocation: `--forget <spec>`
/// (the typed grammar), with `--class C` kept as shorthand for
/// `--forget class:C`.
fn forget_specs(a: &Args, default: &str) -> Result<Vec<ForgetSpec>> {
    if let Some(s) = a.get("forget") {
        let specs: Vec<ForgetSpec> = s
            .split(';')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(ForgetSpec::parse)
            .collect::<Result<_>>()?;
        if specs.is_empty() {
            anyhow::bail!("--forget: no specs given");
        }
        return Ok(specs);
    }
    if let Some(c) = a.get("class") {
        return Ok(vec![ForgetSpec::parse(&format!("class:{c}"))?]);
    }
    Ok(vec![ForgetSpec::parse(default)?])
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    // `audit` takes a positional action (`list | verify | prove`) ahead
    // of the flag grammar; peel it off so `Args::parse` sees only
    // `--key value` pairs.
    let mut audit_action = "list".to_string();
    if argv.first().map(String::as_str) == Some("audit")
        && argv.get(1).is_some_and(|t| !t.starts_with("--"))
    {
        audit_action = argv.remove(1);
    }
    let mut args = Args::parse(argv)?;
    args.declare(&[
        "model", "dataset", "mode", "class", "forget", "steps", "lr", "imp-batches",
        "seed", "retrain", "int8", "verbose", "requests", "clients", "workers",
        "queue-cap", "deadline-ms", "batch-max", "pace-sim", "http", "http-threads",
        "durable", "checkpoint-every", "spec",
    ]);
    args.finish()?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "unlearn" => cmd_unlearn(&args),
        "serve" => cmd_serve(&args),
        "audit" => cmd_audit(&args, &audit_action),
        "info" => cmd_info(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
ficabu — Fisher-based Context-Adaptive Balanced Unlearning (edge coordinator)

USAGE: ficabu <command> [--key value] [--flag]

  train    --model rn18slim|vitslim --dataset cifar20|pinsface
           [--steps N --lr F --seed N --retrain --int8 --verbose]
  unlearn  --model M --dataset D --mode ssd|cau|bd|ficabu [--int8]
           --forget class:3 | classes:1,4,7 | samples:0,9,44 | samples:@file
           (--class C = shorthand for --forget class:C)
  serve    --model M --dataset D [--requests N --clients K]
           [--forget \"class:0;classes:1,4\" request cycle]
           [--workers N --queue-cap N --deadline-ms N --batch-max N --pace-sim]
           [--http ADDR [--http-threads N]  serve over HTTP instead of the
            in-process client loop; e.g. --http 127.0.0.1:8787]
           [--durable DIR [--checkpoint-every N]  crash-safe serving:
            write-ahead ledger + parameter checkpoints in DIR; on start,
            recover and replay unfinished requests]
  audit    list|verify|prove --durable DIR [--model M] [--spec class:3]
           offline inspection of the hash-chained audit log a durable
           fleet writes beside its ledger:
             list    print every verified chain link as JSON
             verify  re-check CRC frames, hash links, checkpoint anchors
             prove   print the verified links that executed --spec
  info     platform + artifact inventory

Tables/figures: cargo run --release --example table1 (table2, table4,
fig3, fig4, power_report, pipeline_trace, quickstart, e2e_unlearning,
edge_serving). See DESIGN.md for the experiment index.
";

fn cmd_info() -> Result<()> {
    let rt = Runtime::from_env()?;
    println!("backend: {}", rt.platform());
    let root = artifacts_root();
    println!(
        "artifacts root: {} ({})",
        root.display(),
        if root.exists() { "present" } else { "absent; using builtin inventories" }
    );
    for name in ["rn18slim", "vitslim"] {
        let source = if root.join(name).join("meta.json").exists() {
            "artifacts"
        } else {
            "builtin"
        };
        match ficabu::config::ModelMeta::resolve(name) {
            Ok(m) => println!(
                "  {name}: {} segments, {} params, batch {}, microbatch {} [{source}]",
                m.num_segments(),
                m.total_params(),
                m.batch,
                m.microbatch
            ),
            Err(e) => println!("  {name}: unavailable ({e:#})"),
        }
    }
    Ok(())
}

fn cmd_train(a: &Args) -> Result<()> {
    let model = a.str_or("model", "rn18slim");
    let kind = dataset_kind(&a.str_or("dataset", "cifar20"))?;
    let mut opts = prepare_opts(a)?;
    opts.retrain = true;
    let t0 = std::time::Instant::now();
    let prep = exp::prepare(&model, kind, &opts)?;
    let train_acc = ficabu::metrics::eval_accuracy(
        &prep.model,
        &prep.params,
        &prep.train,
        &(0..prep.train.len()).collect::<Vec<_>>(),
    )?;
    let test_acc = ficabu::metrics::eval_accuracy(
        &prep.model,
        &prep.params,
        &prep.test,
        &(0..prep.test.len()).collect::<Vec<_>>(),
    )?;
    println!(
        "trained {model} on {}: train acc {:.2}% test acc {:.2}% ({:.1}s, {} steps)",
        kind.tag(),
        100.0 * train_acc,
        100.0 * test_acc,
        t0.elapsed().as_secs_f64(),
        opts.train_steps,
    );
    if let (Some(first), Some(last)) = (prep.loss_curve.first(), prep.loss_curve.last()) {
        println!("loss: {first:.4} -> {last:.4}");
    }
    Ok(())
}

fn cmd_unlearn(a: &Args) -> Result<()> {
    let model = a.str_or("model", "rn18slim");
    let kind = dataset_kind(&a.str_or("dataset", "cifar20"))?;
    let mode = mode_of(&a.str_or("mode", "ficabu"))?;
    let specs = forget_specs(a, "class:0")?;
    let spec = match specs.as_slice() {
        [one] => one.clone(),
        _ => anyhow::bail!("unlearn runs one event; give a single --forget spec"),
    };
    let opts = prepare_opts(a)?;
    let prep = exp::prepare(&model, kind, &opts)?;

    // calibrate BD schedule from an SSD pass when needed
    let ssd_sel = if matches!(mode, Mode::Bd | Mode::Ficabu) {
        let ssd = exp::run_spec(&prep, &spec, Mode::Ssd, None)?;
        ssd.report.map(|r| r.selected_per_depth)
    } else {
        None
    };
    let res = exp::run_spec(&prep, &spec, mode, ssd_sel.as_deref())?;
    println!(
        "{} {}: Dr {:.2}% Df {:.2}% MIA {:.2}% MACs {:.2}% of SSD",
        mode.name(),
        res.spec,
        100.0 * res.dr,
        100.0 * res.df,
        100.0 * res.mia,
        res.macs_vs_ssd_pct
    );
    if let Some(l) = res.stop_depth {
        println!("early stop at depth l = {l}");
    }
    if let Some(r) = &res.report {
        println!(
            "ledger: fwd {} bwd {} fisher {} dampen {} checkpoint {}",
            r.ledger.forward, r.ledger.backward, r.ledger.fisher, r.ledger.dampen,
            r.ledger.checkpoint
        );
    }
    Ok(())
}

/// `audit list|verify|prove --durable DIR`: offline verification of a
/// durable directory's audit chain — no fleet, no model, just the files.
fn cmd_audit(a: &Args, action: &str) -> Result<()> {
    use ficabu::audit;
    let dir = match a.get("durable") {
        Some(d) => std::path::PathBuf::from(d),
        None => anyhow::bail!("audit needs --durable DIR (the directory a durable fleet wrote)"),
    };
    let model = match a.get("model") {
        Some(m) => Some(ficabu::coordinator::ModelId::new(m)?),
        None => None,
    };
    match action {
        "list" => {
            let report = audit::verify_dir(&dir)?;
            for rec in report
                .records
                .iter()
                .filter(|r| model.as_ref().map(|m| r.model == *m).unwrap_or(true))
            {
                println!("{}", rec.to_json());
            }
            Ok(())
        }
        "verify" => {
            let report = audit::verify_dir(&dir)?;
            for head in &report.heads {
                println!(
                    "{}: chain ok, {} link(s), head {:016x}",
                    head.model, head.chain_len, head.head_hash
                );
            }
            if report.heads.is_empty() {
                println!("audit log is empty (no completed forgets recorded)");
            }
            println!(
                "checkpoint anchors: {}",
                if report.checkpoint_checked { "verified" } else { "no checkpoint present" }
            );
            Ok(())
        }
        "prove" => {
            let spec = match a.get("spec") {
                Some(s) => ForgetSpec::parse(s)?,
                None => anyhow::bail!("audit prove needs --spec (e.g. --spec class:3)"),
            };
            let links = audit::prove(&dir, model.as_ref(), &spec)?;
            println!(
                "proved: {} verified link(s) executed `{}`",
                links.len(),
                spec.canonical()
            );
            for rec in &links {
                println!("{}", rec.to_json());
            }
            Ok(())
        }
        other => anyhow::bail!("unknown audit action `{other}` (list | verify | prove)"),
    }
}

fn cmd_serve(a: &Args) -> Result<()> {
    let model = a.str_or("model", "rn18slim");
    let kind = dataset_kind(&a.str_or("dataset", "cifar20"))?;
    let n_requests = a.usize_or("requests", 4)?;
    let n_clients = a.usize_or("clients", 2)?.max(1);
    let workers = a.usize_or("workers", 1)?;
    let queue_cap = a.usize_or("queue-cap", 32)?;
    let deadline_ms = a.usize_or("deadline-ms", 0)?;
    let batch_max = a.usize_or("batch-max", 4)?;
    // Chaos/CI seam: arm an injected-fault plan before any request is
    // served (e.g. FICABU_FAULTS="dampen:1:panic;respawn:every1:error"
    // drives /healthz into its degraded 503 state).
    if let Some(plan) = ficabu::testkit::faults::arm_from_env()? {
        println!("fault plan armed from {}: {plan}", ficabu::testkit::faults::ENV_VAR);
    }
    let opts = prepare_opts(a)?;
    let prep = exp::prepare(&model, kind, &opts)?;

    let cfg = exp::tables::mode_config(&prep, Mode::Ficabu, None);
    let num_classes = prep.model.meta.num_classes;
    let num_samples = prep.train.len();
    // Request cycle: --forget specs if given, else one spec per class.
    let cycle: Vec<ForgetSpec> = if a.get("forget").is_some() {
        forget_specs(a, "class:0")?
    } else {
        (0..num_classes).map(ForgetSpec::Class).collect()
    };
    let wspec = WorkerSpec {
        meta: prep.model.meta.clone(),
        shared: SharedMeta::resolve()?,
        params: prep.params,
        global: prep.global,
        train: prep.train,
        cfg,
        precision: prep.precision,
    };
    let fleet_cfg = FleetConfig {
        workers,
        queue_cap,
        deadline: match deadline_ms {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms as u64)),
        },
        batch_max,
        pacing: if a.flag("pace-sim") {
            Pacing::SimDevice { floor_ms: 0.0 }
        } else {
            Pacing::Host
        },
        ..FleetConfig::default()
    };
    println!(
        "serving fleet: {workers} worker(s), queue cap {queue_cap}, deadline {}, batch max {batch_max}",
        if deadline_ms == 0 { "none".to_string() } else { format!("{deadline_ms} ms") },
    );
    let fleet = match a.get("durable") {
        Some(dir) => {
            let dcfg = DurabilityConfig {
                dir: std::path::PathBuf::from(dir),
                checkpoint_every: a.usize_or("checkpoint-every", 1)?.max(1) as u64,
            };
            println!(
                "durable: ledger + checkpoints in {} (checkpoint every {} completions)",
                dcfg.dir.display(),
                dcfg.checkpoint_every
            );
            if workers > 1 {
                println!(
                    "durable: {workers} workers — replicas drift independently, so \
                     checkpoints are disabled and recovery replays the full ledger"
                );
            }
            let fleet = Fleet::start_durable(wspec, fleet_cfg, dcfg)?;
            if let Some(d) = fleet.stats().durability {
                println!(
                    "durable: generation {} wal seq {} replayed {}",
                    d.generation, d.wal_seq, d.replayed
                );
            }
            fleet
        }
        None => Fleet::start(wspec, fleet_cfg)?,
    };

    // Wire mode: put the fleet on a socket and serve until the process
    // is stopped (^C / kill). Requests arrive over HTTP, so the
    // in-process client loop below does not run.
    if let Some(addr) = a.get("http") {
        let fleet = std::sync::Arc::new(fleet);
        let http_cfg = HttpConfig {
            threads: a.usize_or("http-threads", 2)?.max(1),
            bounds: Some((num_classes, num_samples)),
            ..HttpConfig::default()
        };
        let srv = HttpServer::bind(addr, std::sync::Arc::clone(&fleet), http_cfg)?;
        println!(
            "http: listening on {} (POST /forget | GET /stats | GET /healthz)",
            srv.local_addr()
        );
        loop {
            std::thread::park();
        }
    }

    // Each client bursts its share of the request stream, then drains
    // replies — exercising queueing, coalescing, and backpressure.
    std::thread::scope(|s| {
        let fleet = &fleet;
        let cycle = &cycle;
        for c in 0..n_clients {
            s.spawn(move || {
                let pending: Vec<(ForgetSpec, _)> = (0..n_requests)
                    .skip(c)
                    .step_by(n_clients)
                    .map(|r| {
                        let spec = cycle[r % cycle.len()].clone();
                        (spec.clone(), fleet.submit(spec))
                    })
                    .collect();
                for (spec, rx) in pending {
                    match rx.recv() {
                        Ok(Reply::Done(sm)) => println!(
                            "{spec}: Df {:.1}% Dr {:.1}% stop l={:?} MACs {:.2}% energy {:.3} mJ ({:.2}% of SSD) sim {:.0} ms [queue {:.0} ms service {:.0} ms]",
                            100.0 * sm.forget_acc,
                            100.0 * sm.retain_acc,
                            sm.stop_depth,
                            sm.macs_vs_ssd_pct,
                            sm.sim_energy_mj,
                            sm.sim_energy_vs_ssd_pct,
                            sm.sim_ms,
                            sm.timing.queue_ms,
                            sm.timing.service_ms
                        ),
                        Ok(Reply::Failed(e)) => println!("{spec}: FAILED ({e})"),
                        Ok(Reply::Backpressure { queue_len, queue_cap }) => println!(
                            "{spec}: BACKPRESSURE (queue {queue_len}/{queue_cap}) — retry later"
                        ),
                        Ok(Reply::Expired { missed_by_ms }) => println!(
                            "{spec}: EXPIRED (deadline missed by {missed_by_ms:.0} ms)"
                        ),
                        // engine panics are caught and answered, so a
                        // dropped channel means the worker thread itself
                        // died without answering
                        Err(_) => println!(
                            "{spec}: WORKER LOST (reply channel dropped before an answer)"
                        ),
                    }
                }
            });
        }
    });

    let stats = fleet.shutdown()?;
    let total = stats.merged();
    println!(
        "\nfleet: admitted {} coalesced {} backpressure-shed {} deadline-shed {} alive {}/{}",
        stats.admitted,
        stats.coalesced,
        stats.shed_backpressure,
        total.shed_deadline,
        stats.alive,
        stats.workers
    );
    println!(
        "totals: served {} failures {} panics {} respawns {} passes {} (max batch {})",
        total.served, total.failures, total.panics, total.respawns, total.batches, total.max_batch
    );
    if let Some(d) = &stats.durability {
        println!(
            "durable: generation {} wal seq {} replayed {} checkpoints {}",
            d.generation, d.wal_seq, d.replayed, d.checkpoints
        );
    }
    println!(
        "queue   latency: mean {:7.1} ms  p50 {:7.1}  p95 {:7.1}  p99 {:7.1}  max {:7.1}",
        total.mean_queue_ms(),
        total.queue_hist.p50_ms(),
        total.queue_hist.p95_ms(),
        total.queue_hist.p99_ms(),
        total.max_queue_ms
    );
    println!(
        "service latency: mean {:7.1} ms  p50 {:7.1}  p95 {:7.1}  p99 {:7.1}  max {:7.1}",
        total.mean_service_ms(),
        total.service_hist.p50_ms(),
        total.service_hist.p95_ms(),
        total.service_hist.p99_ms(),
        total.max_service_ms
    );
    for (w, q) in stats.per_worker.iter().enumerate() {
        println!(
            "  worker {w}: served {:3} failed {:2} shed {:2} passes {:3}  service p50 {:7.1} ms p99 {:7.1} ms",
            q.served,
            q.failures,
            q.shed_deadline,
            q.batches,
            q.service_hist.p50_ms(),
            q.service_hist.p99_ms()
        );
    }
    Ok(())
}
