//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. checkpoint placement (stride sweep): MAC cost vs verification
//!    granularity trade-off of Algorithm 1's checkpoint set C;
//! 2. b_r sweep for the Balanced-Dampening profile: front-end protection
//!    strength vs forgetting efficacy;
//! 3. alpha sweep: selection-threshold sensitivity of SSD (the knife-edge
//!    the paper's layer-agnostic hyperparameters sit on);
//! 4. INT8 vs FP32 deployment: quantization's effect on unlearning quality
//!    and simulated traffic/energy.

mod harness;

use ficabu::exp::{self, DatasetKind, Mode, PrepareOpts};
use ficabu::hwsim::mem::Precision;
use ficabu::hwsim::{BaselineProcessor, FicabuProcessor};
use ficabu::unlearn::{default_checkpoints, run_strategy, Bd, Cau, Schedule, Ssd};
use ficabu::util::prng::Pcg32;
use harness::Bench;

fn main() {
    // cargo runs bench executables with cwd = package root (rust/)
    std::env::set_var(
        "FICABU_ARTIFACTS",
        concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"),
    );
    let b = Bench::new("ablation");
    let prep = b.bench_once("prepare rn18slim/cifar20 (cached)", || {
        exp::prepare("rn18slim", DatasetKind::Cifar20, &PrepareOpts::default()).unwrap()
    });
    let meta = prep.model.meta.clone();
    let (alpha, lambda) = prep.kind.ssd_params(&meta.name);
    let tau = prep.kind.tau();

    // --- 1. checkpoint stride sweep -------------------------------------
    println!("\n[ablation] checkpoint stride sweep (class 0):");
    println!("stride  checkpoints           stop_l  editing-MACs%  Df%");
    for stride in [1usize, 2, 4, 8] {
        let cps = default_checkpoints(meta.num_segments(), stride);
        let mut params = prep.params.clone();
        let mut rng = Pcg32::seeded(0xab1);
        let (x, labels) = prep.train.forget_batch(0, meta.batch, &mut rng);
        let strat = Cau::new(alpha, lambda, cps.clone(), tau);
        let r = run_strategy(
            &prep.model, &mut params, &x, &labels, &prep.global, &prep.fimd, &prep.damp, &strat,
        )
        .unwrap();
        let ssd_macs = ficabu::model::macs::ssd_ledger(&meta, meta.batch).editing_total();
        let df = r
            .checkpoint_trace
            .last()
            .map(|(_, a)| 100.0 * a)
            .unwrap_or(f64::NAN);
        println!(
            "{stride:6}  {:20} {:7}  {:12.4}  {df:5.1}",
            format!("{cps:?}"),
            format!("{:?}", r.stop_depth),
            100.0 * r.ledger.editing_total() as f64 / ssd_macs as f64,
        );
    }

    // --- 2. b_r sweep ----------------------------------------------------
    println!("\n[ablation] b_r sweep (BD, class 1): front-end selections vs b_r");
    for br in [1.0f64, 2.0, 5.0, 10.0, 20.0] {
        let mut params = prep.params.clone();
        let mut rng = Pcg32::seeded(0xab2);
        let (x, labels) = prep.train.forget_batch(1, meta.batch, &mut rng);
        let strat = Bd::new(
            alpha,
            lambda,
            Schedule::Sigmoid { cm: (meta.num_segments() as f64 + 1.0) / 2.0, br },
        );
        let r = run_strategy(
            &prep.model, &mut params, &x, &labels, &prep.global, &prep.fimd, &prep.damp, &strat,
        )
        .unwrap();
        let half = meta.num_segments() / 2;
        let front: u64 = r.selected_per_depth[half..].iter().sum();
        let back: u64 = r.selected_per_depth[..half].iter().sum();
        println!("  b_r {br:5.1}: back-end selected {back:7}, front-end selected {front:7}");
    }

    // --- 3. alpha sweep --------------------------------------------------
    println!("\n[ablation] alpha sweep (SSD, class 2): selected params + Df");
    for a in [2.0f64, 5.0, 10.0, 15.0, 20.0] {
        let mut params = prep.params.clone();
        let mut rng = Pcg32::seeded(0xab3);
        let (x, labels) = prep.train.forget_batch(2, meta.batch, &mut rng);
        let strat = Ssd::new(a, lambda);
        let r = run_strategy(
            &prep.model, &mut params, &x, &labels, &prep.global, &prep.fimd, &prep.damp, &strat,
        )
        .unwrap();
        let sel: u64 = r.selected_per_depth.iter().sum();
        let logits = prep
            .model
            .logits(&params, &x)
            .unwrap();
        let df = ficabu::unlearn::forget_accuracy(&logits, &labels).unwrap();
        println!(
            "  alpha {a:5.1}: selected {sel:7} ({:.3}% of params), forget-batch acc {:.1}%",
            100.0 * sel as f64 / meta.total_params() as f64,
            100.0 * df
        );
    }

    // --- 4. INT8 vs FP32 hardware cost ----------------------------------
    println!("\n[ablation] precision: simulated cost of one FiCABU run");
    let strat = exp::tables::mode_strategy(&prep, Mode::Ficabu, None);
    let mut params = prep.params.clone();
    let mut rng = Pcg32::seeded(0xab4);
    let (x, labels) = prep.train.forget_batch(3, meta.batch, &mut rng);
    let r = run_strategy(
        &prep.model, &mut params, &x, &labels, &prep.global, &prep.fimd, &prep.damp,
        strat.as_ref(),
    )
    .unwrap();
    for precision in [Precision::Int8, Precision::F32] {
        let fic = FicabuProcessor::new(meta.tile, precision).cost(&r);
        let base = BaselineProcessor::new(meta.tile, precision).cost(&r);
        println!(
            "  {precision:?}: FiCABU {:.4} mJ / {:.1} ms vs same-work-on-baseline {:.4} mJ",
            fic.energy_mj,
            fic.seconds * 1e3,
            base.energy_mj
        );
    }
    println!("\n[ablation] complete");
}
